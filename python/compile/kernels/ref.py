"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
ground truth. Every kernel in this package has a reference here, and
python/tests asserts allclose between the two across hypothesis-driven
shape/dtype sweeps."""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.result_type(x.dtype, y.dtype))


def shifted_compress_ref(g, h, mask, scale):
    return h + mask * (g - h) * jnp.asarray(scale, dtype=g.dtype)


def nat_dither_quantize_ref(x, u, norm, *, s: int):
    """Reference natural dithering (vectorized jnp, mirrors the definition
    in the paper's cited Horváth et al. 2019a construction)."""
    sign = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    ax = jnp.abs(x)
    t = jnp.where(norm > 0, ax / norm, 0.0)
    tiny = 2.0 ** (1 - s)
    safe_t = jnp.maximum(t, 1e-300)
    e = jnp.clip(jnp.floor(jnp.log2(safe_t)), 1 - s, 0)
    lo_grid = jnp.exp2(e)
    below = t < tiny
    lo = jnp.where(below, 0.0, lo_grid)
    hi = jnp.where(below, tiny, jnp.minimum(2.0 * lo_grid, 1.0))
    width = hi - lo
    p_hi = jnp.where(width > 0, (t - lo) / jnp.where(width > 0, width, 1.0), 0.0)
    q = jnp.where(u < p_hi, hi, lo)
    q = jnp.where(t == 0.0, 0.0, q)
    q = jnp.where(t >= 1.0, 1.0, q)
    return sign * norm * q.astype(x.dtype)
