"""Layer-1 Pallas kernels for the paper's compression hot-spot.

Two kernels:

* ``shifted_compress`` — the fused shifted-compression update at the heart
  of DCGD-SHIFT:  ``out = h + mask * (g - h) * scale``.  On a worker this
  runs immediately after the gradient while the tile is still in VMEM,
  fusing the shift subtraction, sparsification mask and Rand-K rescale into
  one pass (one HBM read of g/h/mask, one write) instead of three.

* ``nat_dither_quantize`` — Natural-Dithering quantization of ``x/norm`` to
  the binary level grid {0, 2^(1-s), ..., 1}, with external uniform
  randomness ``u`` (the AOT artifact must be deterministic: the Rust
  coordinator supplies the random draws, same as it does for its own native
  compressors).

Both are element-wise 1-D kernels tiled over VMEM-sized blocks; both have
pure-jnp oracles in ``ref.py`` that pytest compares against.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shifted_compress_kernel(g_ref, h_ref, mask_ref, scale_ref, o_ref):
    scale = scale_ref[0]
    g = g_ref[...]
    h = h_ref[...]
    m = mask_ref[...]
    o_ref[...] = h + m * (g - h) * scale


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def shifted_compress(g, h, mask, scale, *, block: int = 1024, interpret: bool = True):
    """``h + mask * (g - h) * scale`` — the decoded form of
    ``h + Q(g - h)`` for masked sparsifiers (Rand-K: mask = indicator of the
    kept subset, scale = d/K)."""
    (d,) = g.shape
    assert h.shape == (d,) and mask.shape == (d,)
    dp = -(-d // block) * block
    pad = dp - d
    gp = jnp.pad(g, (0, pad))
    hp = jnp.pad(h, (0, pad))
    mp = jnp.pad(mask, (0, pad))
    scale_arr = jnp.asarray([scale], dtype=g.dtype)
    out = pl.pallas_call(
        _shifted_compress_kernel,
        grid=(dp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), g.dtype),
        interpret=interpret,
    )(gp, hp, mp, scale_arr)
    return out[:d]


def _nat_dither_kernel(x_ref, u_ref, norm_ref, o_ref, *, s: int):
    norm = norm_ref[0]
    x = x_ref[...]
    u = u_ref[...]
    sign = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    ax = jnp.abs(x)
    # normalized magnitude in [0, 1]
    t = jnp.where(norm > 0, ax / norm, 0.0)
    # bracketing binary levels: lo = 2^floor(log2 t) clamped to the grid,
    # hi = min(2*lo, 1); below the smallest level the bracket is [0, 2^(1-s)].
    tiny = 2.0 ** (1 - s)
    safe_t = jnp.maximum(t, 1e-300)
    e = jnp.floor(jnp.log2(safe_t))
    e = jnp.clip(e, 1 - s, 0)
    lo_grid = jnp.exp2(e)
    below = t < tiny
    lo = jnp.where(below, 0.0, lo_grid)
    hi = jnp.where(below, tiny, jnp.minimum(2.0 * lo_grid, 1.0))
    width = hi - lo
    p_hi = jnp.where(width > 0, (t - lo) / jnp.where(width > 0, width, 1.0), 0.0)
    q = jnp.where(u < p_hi, hi, lo)
    q = jnp.where(t == 0.0, 0.0, q)
    q = jnp.where(t >= 1.0, 1.0, q)
    o_ref[...] = sign * norm * q.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("s", "block", "interpret"))
def nat_dither_quantize(x, u, norm, *, s: int, block: int = 1024, interpret: bool = True):
    """Natural dithering of ``x`` onto ``norm * {0, 2^(1-s), …, 1}`` using
    uniform draws ``u`` in [0,1): unbiased randomized rounding between the
    bracketing levels."""
    (d,) = x.shape
    assert u.shape == (d,)
    dp = -(-d // block) * block
    pad = dp - d
    xp = jnp.pad(x, (0, pad))
    up = jnp.pad(u, (0, pad))
    norm_arr = jnp.asarray([norm], dtype=x.dtype)
    out = pl.pallas_call(
        functools.partial(_nat_dither_kernel, s=s),
        grid=(dp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(xp, up, norm_arr)
    return out[:d]
