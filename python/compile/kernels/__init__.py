"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from compile.kernels.compress import nat_dither_quantize, shifted_compress
from compile.kernels.matmul import matmul, matmul_ad

__all__ = ["matmul", "matmul_ad", "shifted_compress", "nat_dither_quantize"]
