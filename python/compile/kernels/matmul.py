"""Layer-1 Pallas kernel: tiled matmul.

The compute hot-spot of every workload in this repo (per-worker gradients
and the transformer LM) is matmul-shaped. This kernel expresses the paper's
distributed-compute substrate the way a TPU deployment would: HBM->VMEM
tiles via BlockSpec, an MXU-shaped inner matmul, and a grid that walks
(M/bm, N/bn, K/bk) with accumulation in the output tile.

TPU sizing rationale (see DESIGN.md "Hardware adaptation"):
  * default tiles 128x128x128 = three f32 tiles of 64 KiB each, comfortably
    inside the ~16 MiB VMEM with double-buffering room;
  * the MXU is a 128x128 systolic array, so bm = bn = bk = 128 keeps it
    fully fed (bf16 inputs would double the effective rate).

On this image Pallas MUST run with interpret=True (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted against `ref.py` oracles in
python/tests, and TPU efficiency is estimated analytically in
EXPERIMENTS.md section Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )
    del n_k  # grid bound is encoded in the BlockSpec grid


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = True):
    """`x @ y` via the tiled Pallas kernel, any shapes (zero-padded to tiles).

    Padding is mathematically exact for matmul (zero rows/cols contribute
    nothing) and mirrors what Mosaic does for ragged edges on real TPUs.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    out_dtype = jnp.result_type(x.dtype, y.dtype)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y, bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul_ad(x, y):
    """Differentiable wrapper: forward AND backward run the Pallas kernel
    (dX = dC @ Yᵀ and dY = Xᵀ @ dC are themselves matmuls)."""
    return matmul(x, y)


def _matmul_fwd(x, y):
    return matmul(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return matmul(g, y.T), matmul(x.T, g)


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, bytes_per_el: int = 4) -> int:
    """VMEM footprint of one grid step (x-tile + y-tile + o-tile), used by
    the section-Perf roofline estimate."""
    return bytes_per_el * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    return (m * n * k) / (mp * np_ * kp)
