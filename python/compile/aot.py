"""AOT compile path: lower every Layer-2 entry point to HLO **text** and
write `artifacts/manifest.json` describing shapes/dtypes/param layout for
the Rust runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via `make artifacts` (no-op when inputs are unchanged):
    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import nat_dither_quantize, shifted_compress  # noqa: E402

# ---------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def describe(args_specs, out_specs):
    def one(s):
        return {"shape": list(s.shape), "dtype": jnp.dtype(s.dtype).name}

    return {
        "inputs": [one(s) for s in args_specs],
        "outputs": [one(s) for s in out_specs],
    }


# ----------------------------------------------------------------- entries

# Paper-shaped ridge worker: m=100 rows over 10 workers -> m_i = 10, d = 80.
RIDGE_MI, RIDGE_D, RIDGE_N = 10, 80, 10
# w2a-shaped logistic worker: 3470 rows over 10 workers -> 347, d = 300.
LOGREG_MI, LOGREG_D = 347, 300
# LM config for the end-to-end example.
LM_CFG = model.LmConfig()
LM_BATCH = 8


def build_entries():
    """(name, jitted fn, example specs, extra-manifest) tuples."""
    f64 = jnp.float64
    f32 = jnp.float32
    i32 = jnp.int32

    def ridge(x, a, y, lam, n):
        return (model.ridge_grad(x, a, y, lam[0], n[0]),)

    def logreg(x, a, y, lam):
        return (model.logreg_grad(x, a, y, lam[0]),)

    def lm(params, tokens):
        loss, grads = model.lm_step(params, tokens, LM_CFG)
        return (loss, grads)

    fast_cfg = LM_CFG._replace(matmul="xla")

    def lm_fast(params, tokens):
        loss, grads = model.lm_step(params, tokens, fast_cfg)
        return (loss, grads)

    def fused_compress(g, h, mask, scale):
        return (shifted_compress(g, h, mask, scale[0]),)

    def nat_dither(x, u, norm):
        return (nat_dither_quantize(x, u, norm[0], s=8),)

    lm_p = model.lm_param_count(LM_CFG)

    entries = [
        (
            "ridge_grad",
            ridge,
            [
                spec((RIDGE_D,), f64),
                spec((RIDGE_MI, RIDGE_D), f64),
                spec((RIDGE_MI,), f64),
                spec((1,), f64),
                spec((1,), f64),
            ],
            {"m_i": RIDGE_MI, "d": RIDGE_D, "n_workers": RIDGE_N},
        ),
        (
            "logreg_grad",
            logreg,
            [
                spec((LOGREG_D,), f64),
                spec((LOGREG_MI, LOGREG_D), f64),
                spec((LOGREG_MI,), f64),
                spec((1,), f64),
            ],
            {"m_i": LOGREG_MI, "d": LOGREG_D},
        ),
        (
            "lm_step",
            lm,
            [spec((lm_p,), f32), spec((LM_BATCH, LM_CFG.seq + 1), i32)],
            {
                "param_count": lm_p,
                "batch": LM_BATCH,
                "config": LM_CFG._asdict(),
                "param_layout": [
                    {"name": n, "shape": list(s)} for n, s in model.lm_param_shapes(LM_CFG)
                ],
            },
        ),
        (
            "lm_step_fast",
            lm_fast,
            [spec((lm_p,), f32), spec((LM_BATCH, LM_CFG.seq + 1), i32)],
            {
                "param_count": lm_p,
                "batch": LM_BATCH,
                "config": fast_cfg._asdict(),
                "param_layout": [
                    {"name": n, "shape": list(s)} for n, s in model.lm_param_shapes(LM_CFG)
                ],
            },
        ),
        (
            "shifted_compress",
            fused_compress,
            [
                spec((RIDGE_D,), f64),
                spec((RIDGE_D,), f64),
                spec((RIDGE_D,), f64),
                spec((1,), f64),
            ],
            {"d": RIDGE_D},
        ),
        (
            "nat_dither_quantize",
            nat_dither,
            [spec((RIDGE_D,), f64), spec((RIDGE_D,), f64), spec((1,), f64)],
            {"d": RIDGE_D, "s": 8},
        ),
    ]
    return entries


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--skip-lm", action="store_true", help="skip the (slow) LM entry"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"entries": {}}
    for name, fn, specs, extra in build_entries():
        if args.skip_lm and name.startswith("lm_step"):
            continue
        print(f"lowering {name} …", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = [
            jax.ShapeDtypeStruct(o.shape, o.dtype) for o in lowered.out_info
        ]
        entry = {"file": fname, **describe(specs, out_specs), **extra}
        manifest["entries"][name] = entry
        print(f"  wrote {fname} ({len(text)} chars)")

    # initial LM parameters for the Rust trainer
    if not args.skip_lm:
        print("initializing LM parameters …", flush=True)
        params = model.lm_init_params(LM_CFG, jax.random.PRNGKey(0))
        raw = bytes(jnp.asarray(params, jnp.float32).tobytes())
        with open(os.path.join(args.out_dir, "lm_init.bin"), "wb") as f:
            f.write(raw)
        manifest["entries"]["lm_step"]["init_file"] = "lm_init.bin"
        if "lm_step_fast" in manifest["entries"]:
            manifest["entries"]["lm_step_fast"]["init_file"] = "lm_init.bin"
        print(f"  wrote lm_init.bin ({len(raw)} bytes)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
