"""Layer-2 JAX models — the differentiable workloads the Rust coordinator
distributes. All dense contractions route through the Layer-1 Pallas matmul
(`kernels.matmul_ad`), so lowering any entry point bakes the kernel into the
same HLO module.

Entry points (AOT-exported by aot.py):
  * ridge_grad   — per-worker gradient of the paper's ridge objective
  * logreg_grad  — per-worker gradient of the l2-regularized logistic loss
  * lm_loss / lm_step — a small GPT-style causal LM: loss and flat-gradient,
    the workload of the end-to-end distributed-compressed-training example
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels import matmul_ad


# --------------------------------------------------------------------- ridge


def ridge_grad(x, a, y, lam, n_workers):
    """∇f_i for f_i(x) = n/2 ||A_i x − y_i||² + λ/2 ||x||².

    Matches `rust/src/problems/ridge.rs` exactly (the runtime integration
    test cross-checks the two implementations through PJRT).
    """
    resid = matmul_ad(a, x[:, None])[:, 0] - y
    ata_r = matmul_ad(a.T, resid[:, None])[:, 0]
    return n_workers * ata_r + lam * x


# ------------------------------------------------------------------ logistic


def logreg_grad(x, a, y, lam):
    """∇f_i for f_i(x) = (1/m)Σ log(1+exp(−y_l·a_lᵀx)) + λ/2 ||x||²."""
    m = a.shape[0]
    t = y * (matmul_ad(a, x[:, None])[:, 0])
    coeff = -y * jax.nn.sigmoid(-t) / m
    return matmul_ad(a.T, coeff[:, None])[:, 0] + lam * x


# ------------------------------------------------------------ transformer LM


class LmConfig(NamedTuple):
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq: int = 128
    # dense-layer backend: "pallas" = the Layer-1 tiled kernel (the real-TPU
    # artifact; interpret-mode on CPU), "xla" = XLA's native dot (the
    # CPU-optimized artifact — see EXPERIMENTS.md section Perf)
    matmul: str = "pallas"


def lm_param_shapes(cfg: LmConfig):
    """Ordered (name, shape) list — the flat-vector layout contract with the
    Rust trainer (also recorded in the AOT manifest)."""
    shapes = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        shapes += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return shapes


def lm_param_count(cfg: LmConfig) -> int:
    total = 0
    for _, shape in lm_param_shapes(cfg):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


def lm_init_params(cfg: LmConfig, key) -> jnp.ndarray:
    """Flat f32 parameter vector, GPT-2-style init."""
    chunks = []
    for name, shape in lm_param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        elif name.endswith("_b") or name.endswith("b1") or name.endswith("b2"):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            std = 0.02
            chunks.append((jax.random.normal(sub, shape, jnp.float32) * std).ravel())
    return jnp.concatenate(chunks)


def _unflatten(flat, cfg: LmConfig):
    params = {}
    offset = 0
    for name, shape in lm_param_shapes(cfg):
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[offset : offset + size].reshape(shape)
        offset += size
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _dense(x2d, w, impl="pallas"):
    """[T, in] @ [in, out] — Pallas kernel or XLA dot per the config."""
    if impl == "pallas":
        return matmul_ad(x2d, w)
    return jnp.dot(x2d, w)


def lm_logits(flat_params, tokens, cfg: LmConfig):
    """Causal-LM logits. tokens: i32 [B, S]."""
    p = _unflatten(flat_params, cfg)
    b, s = tokens.shape
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.asarray(-1e9, jnp.float32)
    hd = cfg.d_model // cfg.n_heads
    mm = cfg.matmul
    for layer in range(cfg.n_layers):
        pre = f"l{layer}."
        x = _layer_norm(h, p[pre + "ln1_g"], p[pre + "ln1_b"])
        qkv = _dense(x.reshape(b * s, cfg.d_model), p[pre + "wqkv"], mm).reshape(
            b, s, 3, cfg.n_heads, hd
        )
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # [b, heads, s, hd]
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        scores = jnp.where(mask[None, None] > 0, scores, neg)
        att = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        h = h + _dense(ctx, p[pre + "wo"], mm).reshape(b, s, cfg.d_model)

        x = _layer_norm(h, p[pre + "ln2_g"], p[pre + "ln2_b"])
        y = _dense(x.reshape(b * s, cfg.d_model), p[pre + "w1"], mm) + p[pre + "b1"]
        y = jax.nn.gelu(y)
        y = _dense(y, p[pre + "w2"], mm) + p[pre + "b2"]
        h = h + y.reshape(b, s, cfg.d_model)

    h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
    # tied output head: logits = h @ tok_embᵀ
    logits = _dense(h.reshape(b * s, cfg.d_model), p["tok_emb"].T, cfg.matmul)
    return logits.reshape(b, s, cfg.vocab)


def lm_loss(flat_params, tokens, cfg: LmConfig):
    """Next-token cross-entropy. tokens: i32 [B, S+1]."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = lm_logits(flat_params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg",))
def lm_step(flat_params, tokens, cfg: LmConfig):
    """(loss, flat_grads) — the unit of work one worker executes per round."""
    loss, grads = jax.value_and_grad(lm_loss)(flat_params, tokens, cfg)
    return loss, grads
