"""Layer-2 model checks: gradient entries vs hand formulas, LM shapes,
loss sanity, and trainability on a tiny config."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model


# ----------------------------------------------------------- ridge gradient


def test_ridge_grad_matches_formula():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    m_i, d, n, lam = 10, 80, 10, 0.01
    a = jax.random.normal(k1, (m_i, d), jnp.float64)
    y = jax.random.normal(k2, (m_i,), jnp.float64)
    x = jax.random.normal(k3, (d,), jnp.float64)
    got = model.ridge_grad(x, a, y, lam, n)
    want = n * a.T @ (a @ x - y) + lam * x
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


def test_ridge_grad_is_gradient_of_loss():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    m_i, d, n, lam = 7, 12, 4, 0.05
    a = jax.random.normal(k1, (m_i, d), jnp.float64)
    y = jax.random.normal(k2, (m_i,), jnp.float64)
    x = jax.random.normal(k3, (d,), jnp.float64)

    def loss(x):
        r = a @ x - y
        return 0.5 * n * jnp.sum(r * r) + 0.5 * lam * jnp.sum(x * x)

    want = jax.grad(loss)(x)
    got = model.ridge_grad(x, a, y, lam, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


# -------------------------------------------------------- logistic gradient


def test_logreg_grad_is_gradient_of_loss():
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    m_i, d, lam = 30, 15, 0.1
    a = jax.random.normal(k1, (m_i, d), jnp.float64)
    y = jnp.sign(jax.random.normal(k2, (m_i,), jnp.float64))
    x = jax.random.normal(k3, (d,), jnp.float64) * 0.3

    def loss(x):
        t = y * (a @ x)
        return jnp.mean(jnp.logaddexp(0.0, -t)) + 0.5 * lam * jnp.sum(x * x)

    want = jax.grad(loss)(x)
    got = model.logreg_grad(x, a, y, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9, atol=1e-10)


# ------------------------------------------------------------ transformer LM

TINY = model.LmConfig(vocab=61, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq=16)


def test_lm_param_count_matches_layout():
    count = model.lm_param_count(TINY)
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(0))
    assert flat.shape == (count,)
    # layout covers the vector exactly
    total = 0
    for _, shape in model.lm_param_shapes(TINY):
        size = 1
        for s in shape:
            size *= s
        total += size
    assert total == count


def test_lm_logits_shape_and_finiteness():
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (3, TINY.seq), 0, TINY.vocab)
    logits = model.lm_logits(flat, tokens, TINY)
    assert logits.shape == (3, TINY.seq, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lm_initial_loss_near_uniform():
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (4, TINY.seq + 1), 0, TINY.vocab)
    loss = model.lm_loss(flat, tokens, TINY)
    expected = float(jnp.log(TINY.vocab))
    assert abs(float(loss) - expected) < 0.5, f"{float(loss)} vs ln V = {expected}"


def test_lm_causality():
    # Changing a future token must not change past logits.
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(5))
    tokens = jax.random.randint(jax.random.PRNGKey(6), (1, TINY.seq), 0, TINY.vocab)
    logits1 = model.lm_logits(flat, tokens, TINY)
    tokens2 = tokens.at[0, -1].set((tokens[0, -1] + 1) % TINY.vocab)
    logits2 = model.lm_logits(flat, tokens2, TINY)
    np.testing.assert_allclose(
        np.asarray(logits1[0, :-1]), np.asarray(logits2[0, :-1]), rtol=1e-5, atol=1e-6
    )


def test_lm_step_grads_shape_and_descent():
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(7))
    tokens = jax.random.randint(jax.random.PRNGKey(8), (4, TINY.seq + 1), 0, TINY.vocab)
    loss0, grads = model.lm_step(flat, tokens, TINY)
    assert grads.shape == flat.shape
    assert bool(jnp.all(jnp.isfinite(grads)))
    # one SGD step on the same batch must reduce the loss
    loss1, _ = model.lm_step(flat - 0.5 * grads, tokens, TINY)
    assert float(loss1) < float(loss0)


def test_lm_training_reduces_loss_on_fixed_batch():
    flat = model.lm_init_params(TINY, jax.random.PRNGKey(9))
    tokens = jax.random.randint(jax.random.PRNGKey(10), (4, TINY.seq + 1), 0, TINY.vocab)
    losses = []
    for _ in range(12):
        loss, grads = model.lm_step(flat, tokens, TINY)
        losses.append(float(loss))
        flat = flat - 0.5 * grads
    assert losses[-1] < losses[0] - 0.3, f"no training progress: {losses}"
