"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/seeds; numpy.testing.assert_allclose is the
verdict. All kernels run interpret=True (CPU image; see DESIGN.md)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, nat_dither_quantize, shifted_compress
from compile.kernels.ref import (
    matmul_ref,
    nat_dither_quantize_ref,
    shifted_compress_ref,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------------- matmul


@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_small_shapes(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    y = jax.random.normal(k2, (k, n), jnp.float32)
    got = matmul(x, y, bm=32, bn=32, bk=32)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize(
    "shape", [(1, 1, 1), (128, 128, 128), (130, 70, 257), (5, 300, 2)]
)
def test_matmul_dtypes_and_ragged_tiles(dtype, shape):
    m, k, n = shape
    key = jax.random.PRNGKey(m * 7 + k * 3 + n)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), dtype)
    y = jax.random.normal(k2, (k, n), dtype)
    got = matmul(x, y)
    want = matmul_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("tiles", [(16, 16, 16), (32, 64, 16), (128, 128, 128)])
def test_matmul_tile_invariance(tiles):
    bm, bn, bk = tiles
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (77, 45), jnp.float32)
    y = jax.random.normal(k2, (45, 91), jnp.float32)
    got = matmul(x, y, bm=bm, bn=bn, bk=bk)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_matmul_ad_gradients_match_autodiff():
    from compile.kernels import matmul_ad

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (17, 9), jnp.float32)
    y = jax.random.normal(k2, (9, 13), jnp.float32)

    def f_pallas(x, y):
        return jnp.sum(jnp.sin(matmul_ad(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(x @ y))

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gy_p), np.asarray(gy_r), rtol=1e-4, atol=1e-5)


# -------------------------------------------------------- shifted compress


@given(
    d=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 50.0),
)
def test_shifted_compress_matches_ref(d, seed, scale):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    g = jax.random.normal(k1, (d,), jnp.float64)
    h = jax.random.normal(k2, (d,), jnp.float64)
    mask = (jax.random.uniform(k3, (d,)) < 0.3).astype(jnp.float64)
    got = shifted_compress(g, h, mask, scale, block=128)
    want = shifted_compress_ref(g, h, mask, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_shifted_compress_is_exact_at_shift():
    # the defining property: g == h => output == h regardless of mask/scale
    d = 64
    h = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float64)
    mask = jnp.ones((d,), jnp.float64)
    out = shifted_compress(h, h, mask, 13.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), rtol=0, atol=0)


# -------------------------------------------------------- natural dithering


@given(d=st.integers(1, 400), s=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_nat_dither_matches_ref(d, s, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (d,), jnp.float64) * 3.0
    u = jax.random.uniform(k2, (d,), jnp.float64)
    norm = float(jnp.linalg.norm(x))
    got = nat_dither_quantize(x, u, norm, s=s, block=128)
    want = nat_dither_quantize_ref(x, u, norm, s=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_nat_dither_outputs_on_grid():
    d, s = 256, 6
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (d,), jnp.float64)
    u = jax.random.uniform(k2, (d,), jnp.float64)
    norm = float(jnp.linalg.norm(x))
    out = np.asarray(nat_dither_quantize(x, u, norm, s=s))
    mag = np.abs(out) / norm
    nz = mag[mag > 0]
    logs = np.log2(nz)
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-9)
    assert logs.min() >= 1 - s - 1e-9
    assert logs.max() <= 0 + 1e-9


def test_nat_dither_unbiased_monte_carlo():
    # E[quantized] == x (randomized rounding preserves expectations)
    d, s, trials = 32, 4, 4000
    x = jax.random.normal(jax.random.PRNGKey(5), (d,), jnp.float64)
    norm = float(jnp.linalg.norm(x))
    keys = jax.random.split(jax.random.PRNGKey(6), trials)
    u = jax.vmap(lambda k: jax.random.uniform(k, (d,), jnp.float64))(keys)
    ref = jax.vmap(lambda ui: nat_dither_quantize_ref(x, ui, norm, s=s))(u)
    mean = np.asarray(jnp.mean(ref, axis=0))
    np.testing.assert_allclose(mean, np.asarray(x), rtol=0, atol=0.12 * norm / np.sqrt(d))
