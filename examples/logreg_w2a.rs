//! Figure-4 style experiment: ℓ2-regularized logistic regression on the
//! w2a-like LibSVM dataset (κ = 100), DIANA vs Rand-DIANA.
//!
//! Pass a path to a real LibSVM file to run on actual data:
//! ```bash
//! cargo run --release --example logreg_w2a -- [path/to/w2a] [max_rounds]
//! ```

use shiftcomp::prelude::*;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let data_path = args.iter().find(|a| !a.chars().all(|c| c.is_ascii_digit()));
    let max_rounds: usize = args
        .iter()
        .find(|a| a.chars().all(|c| c.is_ascii_digit()) && !a.is_empty())
        .and_then(|s| s.parse().ok())
        .unwrap_or(30_000);

    let seed = 42;
    let problem = match data_path {
        Some(path) => {
            println!("loading LibSVM data from {path}");
            let ds = shiftcomp::data::libsvm::read_file(path).expect("parsing LibSVM file");
            Logistic::from_dataset(&ds, 10, 100.0, seed)
        }
        None => {
            println!("using the synthetic w2a stand-in (see DESIGN.md §Substitutions)");
            Logistic::w2a_default(10, seed)
        }
    };
    let d = problem.dim();
    println!(
        "logistic: d={d}, n={}, κ = {:.1} (λ = {:.3e})",
        problem.n_workers(),
        problem.kappa(),
        problem.lambda()
    );

    let opts = RunOpts {
        max_rounds,
        tol: 1e-10,
        record_every: 10,
        ..Default::default()
    };

    println!(
        "\n{:<24} {:>10} {:>14} {:>14}",
        "method", "rounds", "final err", "uplink bits"
    );
    for &q in &[0.1, 0.5, 0.9] {
        for (name, trace) in [
            (
                format!("DIANA rand-k q={q}"),
                DcgdShift::diana(&problem, RandK::with_q(d, q), None, seed).run(&problem, &opts),
            ),
            (
                format!("Rand-DIANA rand-k q={q}"),
                DcgdShift::rand_diana(&problem, RandK::with_q(d, q), None, seed)
                    .run(&problem, &opts),
            ),
        ] {
            println!(
                "{:<24} {:>10} {:>14.3e} {:>14}",
                name,
                trace.rounds(),
                trace.final_relative_error(),
                trace.total_bits_up(),
            );
            trace
                .save_csv(&format!(
                    "results/logreg_{}.csv",
                    name.replace([' ', '='], "_")
                ))
                .expect("writing CSV");
        }
    }
    println!("\ncurves written to results/logreg_*.csv");
}
