//! Heterogeneous workers over the threaded coordinator + simulated network.
//!
//! §3.2.1 of the paper: "one can use different compressors Q_i, which can be
//! particularly beneficial when different workers have various bandwidths …
//! the slower workers can compress more". This example builds a fleet whose
//! links degrade 4× from the fastest to the slowest worker and compares:
//!
//!   (a) homogeneous Rand-K on every worker,
//!   (b) bandwidth-matched Rand-K (aggressive on slow links),
//!
//! under identical round budgets, reporting accuracy AND simulated
//! wall-clock from the byte-priced network model.
//!
//! ```bash
//! cargo run --release --example heterogeneous_workers [-- --rounds 200]
//! ```
//!
//! `-- --kill-worker ROUND:ID` additionally crashes worker ID at the given
//! round (deterministic fault injection): the coordinator quarantines it at
//! the gather deadline and the surviving fleet finishes the run degraded —
//! the post-run health line shows who was lost and why.

use std::sync::Arc;

use shiftcomp::compressors::{Compressor, RandK, ValPrec};
use shiftcomp::coordinator::{ClusterConfig, DistributedRunner, FaultPlan, MethodKind, WorkerState};
use shiftcomp::net::LinkModel;
use shiftcomp::prelude::*;

fn run_fleet(
    name: &str,
    problem: Arc<Ridge>,
    qs: Vec<Box<dyn Compressor>>,
    rounds: usize,
    kill: Option<(usize, usize)>,
) {
    let n = problem.n_workers();
    let d = problem.dim();
    // links degrade with worker index (worker 9 is ~4x slower than worker
    // 0, in both bandwidth and latency — the spreads are independent knobs)
    let links = LinkModel::heterogeneous_fleet(
        n,
        LinkModel {
            up_bps: 20e6,
            down_bps: 100e6,
            latency: 1e-3,
        },
        0.35,
        0.35,
    );
    // DIANA across the mixed fleet: α from the *largest* ω in the fleet
    let max_omega = qs
        .iter()
        .map(|q| q.omega().expect("unbiased"))
        .fold(0.0f64, f64::max);
    let omegas: Vec<f64> = vec![max_omega; n];
    let ss = shiftcomp::theory::diana(problem.as_ref(), &omegas, &vec![0.0; n], 2.0);

    let mut runner = DistributedRunner::new(
        problem.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma: ss.gamma,
            prec: ValPrec::F64,
            seed: 42,
            links: Some(links),
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            // a crashed worker is only noticed at the gather deadline, so
            // tighten it when a kill is scheduled (healthy fleets keep the
            // generous default and never see a timeout)
            faults: kill.map(|(round, id)| FaultPlan::new().crash(id, round)),
            round_timeout_ms: if kill.is_some() { 500 } else { 30_000 },
            quarantine_after: 1,
            master_threads: None,
        },
    );
    let trace = runner.run(
        problem.as_ref(),
        &RunOpts {
            max_rounds: rounds,
            tol: 1e-10,
            record_every: 10,
            ..Default::default()
        },
    );
    println!(
        "{:<28} rounds {:>6}  err {:>10.3e}  uplink {:>12} bits  simulated time {:>8.3}s",
        name,
        trace.rounds(),
        trace.final_relative_error(),
        trace.total_bits_up(),
        runner.simulated_time(),
    );
    let health = runner.health();
    // Fleet memory: workers share one published snapshot, so private replica
    // bytes stay flat in n and the per-worker divergence is just the overlay.
    let private: u64 = health.replica_bytes.iter().sum();
    let max_nnz = health.overlay_nnz.iter().max().copied().unwrap_or(0);
    println!(
        "    replica memory: {} private bytes across {} workers, max overlay nnz {}",
        private,
        health.replica_bytes.len(),
        max_nnz,
    );
    if !health.all_healthy() {
        for (wi, state) in health.states.iter().enumerate() {
            if *state == WorkerState::Active {
                continue;
            }
            match runner.last_failure(wi) {
                Some(f) => println!("    lost worker: {f}"),
                None => println!("    lost worker {wi}: {state:?}"),
            }
        }
        println!(
            "    degraded rounds: {} (aggregate reweighted to {} survivors)",
            health.degraded_rounds, health.active_workers
        );
    }
}

fn main() {
    let problem = Arc::new(Ridge::paper_default(42));
    let n = problem.n_workers();
    let d = problem.dim();
    // `-- --rounds N` shrinks the round budget (the CI examples smoke job
    // runs a tiny config so the example can't silently rot)
    let rounds = std::env::args()
        .skip_while(|a| a != "--rounds")
        .nth(1)
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(8_000);
    // `-- --kill-worker ROUND:ID` schedules a deterministic crash
    let kill = std::env::args()
        .skip_while(|a| a != "--kill-worker")
        .nth(1)
        .and_then(|v| {
            let (round, id) = v.split_once(':')?;
            Some((round.parse::<usize>().ok()?, id.parse::<usize>().ok()?))
        });

    println!("fleet: worker 0 fastest → worker {} slowest (≈4× degradation)\n", n - 1);
    if let Some((round, id)) = kill {
        assert!(id < n, "--kill-worker: worker id {id} out of range (fleet of {n})");
        println!("fault injection: worker {id} crashes at round {round}\n");
    }

    // (a) homogeneous: everyone at q = 0.5
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.5)) as Box<dyn Compressor>)
        .collect();
    run_fleet("homogeneous rand-k(q=0.5)", problem.clone(), qs, rounds, kill);

    // (b) bandwidth-matched: fast workers send more, slow workers compress
    // harder — same *average* q, radically better straggler time.
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|i| {
            let q = 0.8 - 0.6 * (i as f64) / (n as f64 - 1.0); // 0.8 → 0.2
            Box::new(RandK::with_q(d, q)) as Box<dyn Compressor>
        })
        .collect();
    run_fleet("bandwidth-matched rand-k", problem.clone(), qs, rounds, kill);

    println!(
        "\nBandwidth-matching compresses harder exactly where the link is slow, \
         cutting the straggler-dominated round time while the shifted-compression \
         machinery keeps the method exact (Theorem 3 holds per-worker ω_i)."
    );
}
