//! Quickstart: the paper's core story in one run.
//!
//! On the paper's ridge problem (make_regression m=100, d=80, 10 workers,
//! NOT interpolating), plain DCGD with Rand-K stalls in a neighborhood of
//! the optimum; shifted-compression methods (DIANA, Rand-DIANA, DCGD-STAR)
//! drive the error to machine precision at a fraction of the bits.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use shiftcomp::prelude::*;

fn main() {
    let seed = 42;
    let problem = Ridge::paper_default(seed);
    let d = problem.dim();
    println!(
        "ridge: d={d}, n={} workers, κ = {:.1}, interpolating: {}",
        problem.n_workers(),
        problem.kappa(),
        problem.is_interpolating(1e-9),
    );

    let opts = RunOpts {
        max_rounds: 40_000,
        tol: 1e-12,
        record_every: 10,
        ..Default::default()
    };
    let q = 0.25; // Rand-K share: ω = 3

    let mut runs: Vec<(&str, Trace)> = Vec::new();
    runs.push((
        "DGD (no compression)",
        Gd::new(&problem, seed).run(&problem, &opts),
    ));
    runs.push((
        "DCGD",
        DcgdShift::dcgd(&problem, RandK::with_q(d, q), seed).run(&problem, &opts),
    ));
    runs.push((
        "DCGD-STAR",
        DcgdShift::star(&problem, RandK::with_q(d, q), None, seed).run(&problem, &opts),
    ));
    runs.push((
        "DIANA",
        DcgdShift::diana(&problem, RandK::with_q(d, q), None, seed).run(&problem, &opts),
    ));
    runs.push((
        "Rand-DIANA",
        DcgdShift::rand_diana(&problem, RandK::with_q(d, q), None, seed).run(&problem, &opts),
    ));

    println!(
        "\n{:<22} {:>10} {:>14} {:>14} {:>12}",
        "method", "rounds", "final err", "error floor", "uplink bits"
    );
    for (name, t) in &runs {
        println!(
            "{:<22} {:>10} {:>14.3e} {:>14.3e} {:>12}",
            name,
            t.rounds(),
            t.final_relative_error(),
            t.error_floor(),
            t.total_bits_up(),
        );
    }

    let dcgd_floor = runs[1].1.error_floor();
    let diana_floor = runs[3].1.error_floor();
    println!(
        "\nDCGD stalls at {:.1e}; DIANA reaches {:.1e} — the shift removes the \
         compression-variance neighborhood (Theorems 1 vs 3).",
        dcgd_floor, diana_floor
    );
}
