//! Figure-1 style comparison on the paper's ridge problem: DIANA vs
//! Rand-DIANA across Rand-K compression levels, plotted against
//! communicated bits (ASCII) and written to results/.
//!
//! ```bash
//! cargo run --release --example ridge_comparison -- [max_rounds]
//! ```

fn main() {
    let max_rounds = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40_000);
    let res = shiftcomp::harness::fig1_left("results", 42, max_rounds);
    println!("curve summaries:");
    for c in &res.curves {
        println!(
            "  {:<22} bits→1e-10: {:>12}  floor {:.2e}{}",
            c.label,
            c.bits_to_tol
                .map(|b| b.to_string())
                .unwrap_or_else(|| "—".into()),
            c.error_floor,
            if c.diverged { "  DIVERGED" } else { "" }
        );
    }
}
