//! End-to-end driver: distributed **compressed** training of a ~3.3M-param
//! GPT-style LM through the full three-layer stack.
//!
//!   L1: Pallas tiled matmul inside the model's dense layers
//!   L2: JAX forward+backward, AOT-lowered to artifacts/lm_step.hlo.txt
//!   L3: this Rust leader — PJRT execution, DIANA gradient compression,
//!       momentum SGD, bit accounting
//!
//! Requires `make artifacts` (builds the HLO + initial params).
//!
//! ```bash
//! cargo run --release --example train_lm -- [rounds] [workers] [q]
//! ```
//!
//! The loss curve is written to results/lm_loss.csv and summarized on
//! stdout; EXPERIMENTS.md records a reference run.

use shiftcomp::compressors::RandK;
use shiftcomp::lm::{LmTrainOpts, LmTrainer, MarkovCorpus};
use shiftcomp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let q: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.05);

    let engine = Engine::cpu("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;

    let corpus = MarkovCorpus::new(512, 4, 0.9, 0);
    let opts = LmTrainOpts {
        n_workers: workers,
        rounds,
        seed: 0,
        log_every: 10,
        ..Default::default()
    };
    let mut trainer = LmTrainer::new(
        &engine,
        corpus,
        |p| Box::new(RandK::with_q(p, q)),
        opts,
    )?;
    println!(
        "LM: {} parameters, {workers} workers, DIANA + rand-k(q={q}) gradient compression",
        trainer.param_count()
    );
    println!(
        "corpus entropy floor ≈ {:.3} nats (uniform start ≈ ln 512 = {:.3})\n",
        trainer.entropy_floor(),
        (512f64).ln()
    );

    trainer.train()?;

    // write the loss curve
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("round,loss,bits_up,bits_dense\n");
    for log in &trainer.history {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            log.round, log.mean_loss, log.bits_up, log.bits_dense
        ));
    }
    std::fs::write("results/lm_loss.csv", csv)?;

    let first = trainer.history.first().unwrap();
    let last = trainer.history.last().unwrap();
    let total_up: u64 = trainer.history.iter().map(|l| l.bits_up).sum();
    let total_dense: u64 = trainer.history.iter().map(|l| l.bits_dense).sum();
    println!(
        "\nloss {:.4} → {:.4} over {} rounds; uplink {:.2} MB vs {:.2} MB dense ({:.1}× saved)",
        first.mean_loss,
        last.mean_loss,
        trainer.history.len(),
        total_up as f64 / 8e6,
        total_dense as f64 / 8e6,
        total_dense as f64 / total_up.max(1) as f64,
    );
    println!("loss curve: results/lm_loss.csv");
    Ok(())
}
