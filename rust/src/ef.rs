//! Shared error-feedback core: the accumulator machinery behind both
//! lossy directions of the protocol.
//!
//! The EF21/EF-BV construction (Richtárik et al., 2021; Condat et al.,
//! 2022, arXiv:2205.04180) is direction-agnostic: keep an error
//! accumulator `e`, ship the contractive compression `c = C(e + m)` of the
//! pending message `m`, and retry the residual `e ← e + m − c` next round.
//! The *same* fold/compress/flush cycle drives
//!
//! * the **downlink** ([`crate::downlink::EfDownlink`]): `m` is the
//!   master's iterate step `Δ = x^{k+1} − x^k` and the invariant is
//!   `x_replica + e = x_master`;
//! * the **uplink** ([`EfUplink`]): `m` is the worker's shifted message
//!   `∇f_i(x^k) − h_i^k` and the invariant is `e_i = Σ_k (m_i^k − c_i^k)`
//!   — everything the worker's compressor has dropped so far and still
//!   owes the master. This is what lets the DCGD/DIANA family run Top-K
//!   (or any contractive `C_i`) on the worker → master path: the bias of
//!   each individual `c_i` is corrected over rounds instead of
//!   accumulating in the trajectory.
//!
//! Both wrap one [`EfCore`], so the fold order, the quantize-at-source
//! re-pack and the flush semantics can never drift apart between the two
//! directions — or between the threaded coordinator and the single-process
//! mirrors, which share this code by construction.
//!
//! The compressor output is always re-packed through
//! [`wire::build_update_packet`]'s exact bit accounting (one O(d) staging
//! pass): the wire frame takes the cheaper of the Sparse/Dense
//! representations, and values are pre-quantized to the wire precision so
//! the encode → decode round-trip is lossless and **both** ends fold the
//! identical packet — under f32 the quantization residual `m − c` stays in
//! the accumulator and is retried like any other dropped mass.

use crate::compressors::{Compressor, Packet, ValPrec};
use crate::util::rng::Pcg64;
use crate::wire;

/// The direction-agnostic error-feedback state: accumulator `e` plus the
/// recycled compress/re-pack scratch. Steady-state rounds never touch the
/// allocator once the compressed support has reached its working size
/// (enforced by `tests/alloc_free.rs` for both directions).
pub struct EfCore {
    /// error accumulator: everything compressed away so far
    e: Vec<f64>,
    /// raw compressor output scratch
    pkt: Packet,
    /// dense view of the compressor output (re-pack staging)
    dense_scratch: Vec<f64>,
    /// sparse/dense re-pack scratch — the shipped packet lives here
    repack: wire::DeltaScratch,
}

impl EfCore {
    pub fn new(d: usize) -> Self {
        Self {
            e: vec![0.0; d],
            pkt: Packet::Zero { dim: d as u32 },
            dense_scratch: vec![0.0; d],
            repack: wire::DeltaScratch::with_capacity(d),
        }
    }

    /// Fold a pending message given as a raw slice: `e += m`.
    pub fn fold_slice(&mut self, m: &[f64]) {
        crate::linalg::axpy(1.0, m, &mut self.e);
    }

    /// Fold a pending message given as a packet: `e += Δ` at O(nnz).
    pub fn fold_packet(&mut self, delta: &Packet) {
        delta.add_scaled_into(1.0, &mut self.e);
    }

    /// Compress the pending error with `comp`, keep the residual, and
    /// return the quantized wire packet `c = C(e)`; afterwards
    /// `e ← e − c`. `rng` is the caller's stream (deterministic
    /// compressors like Top-K and Identity never draw from it, but passing
    /// it through keeps randomized compressors reproducible and
    /// bit-identical across drivers).
    pub fn compress_pending(
        &mut self,
        comp: &dyn Compressor,
        rng: &mut Pcg64,
        prec: ValPrec,
    ) -> &Packet {
        comp.compress_into(rng, &self.e, &mut self.pkt);
        self.pkt.decode_into(&mut self.dense_scratch);
        let c = wire::build_update_packet(&self.dense_scratch, 1.0, prec, &mut self.repack);
        c.add_scaled_into(-1.0, &mut self.e);
        c
    }

    /// The packet returned by the last [`compress_pending`](Self::compress_pending).
    pub fn packet(&self) -> &Packet {
        self.repack.packet()
    }

    /// Zero the accumulator: nothing is pending any more. Called whenever
    /// the protocol re-establishes exact state out of band (a dense resync
    /// on the downlink; the worker receiving one on the uplink).
    pub fn flush(&mut self) {
        crate::linalg::zero(&mut self.e);
    }

    /// The error accumulator (tests, diagnostics).
    pub fn error(&self) -> &[f64] {
        &self.e
    }
}

// ------------------------------------------------------------------ uplink

/// Worker-side error feedback for the uplink (EF-BV): the worker folds the
/// shifted message it would normally compress into its accumulator, ships
/// `c_i = C_i(e_i + m_i)`, and retries the residual next round.
///
/// Unlike the downlink twin, the compressor and RNG stream are *not* owned
/// here — they are the worker's own `Q_i` slot and stream, passed through
/// [`fold_and_compress`](Self::fold_and_compress), so arming EF changes
/// what travels on the wire without re-deriving any randomness: the
/// threaded worker loop and the [`crate::algorithms::DcgdShift`] mirror
/// stay bit-identical by construction.
///
/// A dense resync re-establishes exact replica state, so workers
/// [`flush`](Self::flush) the accumulator when they receive one (mirrored
/// by `DcgdShift::set_x0`): after a resync nothing stale is retried.
pub struct EfUplink {
    core: EfCore,
}

impl EfUplink {
    pub fn new(d: usize) -> Self {
        Self {
            core: EfCore::new(d),
        }
    }

    /// One round of worker-side error feedback: fold the shifted message
    /// `m = ∇f_i − h_i` into the accumulator, compress `e + m` with the
    /// worker's own compressor and stream, keep the residual, and return
    /// the quantized wire packet.
    pub fn fold_and_compress(
        &mut self,
        comp: &dyn Compressor,
        rng: &mut Pcg64,
        m: &[f64],
        prec: ValPrec,
    ) -> &Packet {
        self.core.fold_slice(m);
        self.core.compress_pending(comp, rng, prec)
    }

    /// The packet returned by the last compress call.
    pub fn packet(&self) -> &Packet {
        self.core.packet()
    }

    /// Drop everything pending (dense resync received; see the type doc).
    pub fn flush(&mut self) {
        self.core.flush();
    }

    /// The accumulator `Σ (m − c)` (tests, diagnostics).
    pub fn error(&self) -> &[f64] {
        self.core.error()
    }
}

/// Compress one uplink message, shared verbatim by the threaded worker
/// loop and the single-process mirror so both drivers perform the
/// identical operations in the identical order:
///
/// * **EF armed** — fold `m` into the worker's accumulator and ship
///   `C(e + m)` (already quantized by the re-pack);
/// * **exact** — compress `m` directly into the recycled `scratch` packet
///   and quantize it at the source (the pre-EF protocol, unchanged).
pub fn compress_uplink<'a>(
    q: &dyn Compressor,
    rng: &mut Pcg64,
    ef: Option<&'a mut EfUplink>,
    m: &[f64],
    prec: ValPrec,
    scratch: &'a mut Packet,
) -> &'a Packet {
    match ef {
        Some(ef) => ef.fold_and_compress(q, rng, m, prec),
        None => {
            q.compress_into(rng, m, scratch);
            scratch.quantize(prec);
            scratch
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{Identity, RandK, TopK};
    use crate::linalg::nrm2_sq;

    fn rng() -> Pcg64 {
        Pcg64::with_stream(9, 0xef01)
    }

    #[test]
    fn identity_uplink_keeps_zero_error_and_matches_exact() {
        let d = 24;
        let q = Identity::new(d);
        let mut ef = EfUplink::new(d);
        let mut r1 = rng();
        let mut r2 = rng();
        let mut scratch = Packet::Zero { dim: d as u32 };
        let m: Vec<f64> = (0..d).map(|j| 0.25 * (j as f64 + 1.0)).collect();
        for prec in [ValPrec::F64, ValPrec::F32] {
            let c = ef.fold_and_compress(&q, &mut r1, &m, prec);
            let mut from_ef = vec![0.0; d];
            c.add_scaled_into(1.0, &mut from_ef);
            let exact = compress_uplink(&q, &mut r2, None, &m, prec, &mut scratch);
            let mut from_exact = vec![0.0; d];
            exact.add_scaled_into(1.0, &mut from_exact);
            for j in 0..d {
                assert_eq!(from_ef[j].to_bits(), from_exact[j].to_bits(), "coord {j}");
            }
            assert!(ef.error().iter().all(|&v| v == 0.0), "identity must keep e = 0");
        }
    }

    #[test]
    fn topk_uplink_contracts_and_retries_the_residual() {
        let d = 64;
        let k = 8;
        let q = TopK::new(d, k);
        let delta = q.delta().unwrap();
        let mut ef = EfUplink::new(d);
        let mut r = rng();
        let mut g = Pcg64::new(3);
        let mut shipped = vec![0.0; d];
        let mut sent_m = vec![0.0; d];
        for round in 0..40 {
            let m: Vec<f64> = (0..d).map(|_| g.normal()).collect();
            crate::linalg::axpy(1.0, &m, &mut sent_m);
            let u_sq = {
                let mut u = ef.error().to_vec();
                crate::linalg::axpy(1.0, &m, &mut u);
                nrm2_sq(&u)
            };
            let c = ef.fold_and_compress(&q, &mut r, &m, ValPrec::F64);
            assert_eq!(c.nnz(), k, "top-k ships exactly k coordinates");
            c.add_scaled_into(1.0, &mut shipped);
            // contraction: ‖e_new‖² ≤ (1 − δ)‖e_old + m‖²
            let e_sq = nrm2_sq(ef.error());
            let bound = (1.0 - delta) * u_sq;
            assert!(e_sq <= bound + 1e-12, "round {round}: {e_sq} > {bound}");
            // invariant: shipped + e = Σ m, to fp rounding
            for j in 0..d {
                let lhs = shipped[j] + ef.error()[j];
                assert!(
                    (lhs - sent_m[j]).abs() <= 1e-9 * sent_m[j].abs().max(1.0),
                    "round {round} coord {j}: {lhs} vs {}",
                    sent_m[j]
                );
            }
        }
        ef.flush();
        assert!(ef.error().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn compress_uplink_exact_path_is_quantized_at_source() {
        let d = 16;
        let q = RandK::new(d, 4);
        let mut r1 = rng();
        let mut r2 = rng();
        let m: Vec<f64> = (0..d).map(|j| 0.1 * (j as f64 + 0.3)).collect();
        let mut scratch = Packet::Zero { dim: d as u32 };
        let pkt = compress_uplink(&q, &mut r1, None, &m, ValPrec::F32, &mut scratch);
        // identical draws as the raw compressor; values f32-quantized
        let mut want = q.compress(&mut r2, &m);
        want.quantize(ValPrec::F32);
        assert_eq!(pkt, &want);
    }

    #[test]
    fn f32_residual_keeps_the_quantization_error() {
        // under f32 the shipped packet is quantized; the (f64) accumulator
        // must retain exactly m − c so nothing is silently lost
        let d = 8;
        let q = TopK::new(d, d); // keep everything: c = quantize(e + m)
        let mut ef = EfUplink::new(d);
        let mut r = rng();
        let m = vec![0.1; d]; // 0.1 is not representable in f32
        let c = ef.fold_and_compress(&q, &mut r, &m, ValPrec::F32);
        let mut shipped = vec![0.0; d];
        c.add_scaled_into(1.0, &mut shipped);
        for j in 0..d {
            let resid = m[j] - shipped[j];
            assert!(resid != 0.0, "f32 must round 0.1");
            assert_eq!(ef.error()[j], resid, "coord {j}");
        }
    }
}
