//! `shiftcomp-lint` — run the in-tree static lint over the repository.
//!
//! Usage: `cargo run --bin shiftcomp-lint [repo-root]`. With no argument
//! the repo root is found by walking up from the current directory until a
//! `rust/src` directory appears. Exits non-zero iff violations are found;
//! see [`shiftcomp::lint`] for the rule set and the `LINT-ALLOW` escape
//! hatch.

use std::path::PathBuf;
use std::process::ExitCode;

fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let root = match std::env::args_os().nth(1).map(PathBuf::from).or_else(find_repo_root) {
        Some(root) => root,
        None => {
            eprintln!("shiftcomp-lint: no repo root found (pass it as the first argument)");
            return ExitCode::FAILURE;
        }
    };
    match shiftcomp::lint::run_repo(&root) {
        Ok(report) if report.violations.is_empty() => {
            println!(
                "shiftcomp-lint: OK — {} files clean under {}",
                report.files_scanned,
                root.display()
            );
            ExitCode::SUCCESS
        }
        Ok(report) => {
            for v in &report.violations {
                eprintln!("{v}");
            }
            eprintln!(
                "shiftcomp-lint: {} violation(s) in {} files scanned",
                report.violations.len(),
                report.files_scanned
            );
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("shiftcomp-lint: {e}");
            ExitCode::FAILURE
        }
    }
}
