//! Biased / contractive compression operators `C ∈ B(δ)` (Definition 1):
//! `E‖C(x) − x‖² ≤ (1 − δ)‖x‖²`.

use crate::compressors::packet::Packet;
use crate::compressors::Compressor;
use crate::linalg::nrm1;
use crate::util::rng::Pcg64;

thread_local! {
    /// Selection scratch for [`TopK::compress_into`]: the d-length index
    /// permutation used by `select_nth_unstable_by`. Thread-local so the
    /// (immutable) compressor can recycle it across rounds — part of the
    /// zero-allocation round contract (see `compressors::packet`).
    static TOPK_ORDER: std::cell::RefCell<Vec<u32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

// ---------------------------------------------------------------------- Zero

/// The zero operator `O`: maps everything to 0. This is the `C_i` of plain
/// DCGD / DCGD-SHIFT in Table 2; the paper's convention is that its δ is
/// "interpreted as zero" in the step-size rules.
#[derive(Clone, Debug)]
pub struct ZeroCompressor {
    pub d: usize,
}

impl ZeroCompressor {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl Compressor for ZeroCompressor {
    fn name(&self) -> String {
        "zero".into()
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, _rng: &mut Pcg64, x: &[f64]) -> Packet {
        assert_eq!(x.len(), self.d);
        Packet::Zero { dim: self.d as u32 }
    }
    fn compress_into(&self, _rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        *out = Packet::Zero { dim: self.d as u32 };
    }
    fn omega(&self) -> Option<f64> {
        None // biased (E C(x) = 0 ≠ x)
    }
    fn delta(&self) -> Option<f64> {
        Some(0.0) // E‖0 − x‖² = ‖x‖² = (1 − 0)‖x‖²
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------------- Top-K

/// Greedy sparsification (Top-K): keeps the K coordinates of largest
/// magnitude. `C ∈ B(K/d)`.
#[derive(Clone, Debug)]
pub struct TopK {
    pub d: usize,
    pub k: usize,
}

impl TopK {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d, "Top-K needs 1 ≤ K ≤ d (got K={k}, d={d})");
        Self { d, k }
    }

    pub fn with_q(d: usize, q: f64) -> Self {
        let k = ((q * d as f64).round() as usize).clamp(1, d);
        Self::new(d, k)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top-k({}/{})", self.k, self.d)
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, _rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, indices, values, scale) = out.ensure_sparse();
        *dim = self.d as u32;
        *scale = 1.0;
        // Partial selection of the K largest |x_i| in recycled scratch.
        TOPK_ORDER.with(|o| {
            let mut order = o.borrow_mut();
            order.clear();
            order.extend(0..self.d as u32);
            // total_cmp gives a total order (descending by |x_i|): NaN
            // inputs rank above +inf deterministically instead of silently
            // tying with everything, which would make the selected support
            // depend on the partition's visit order.
            order.select_nth_unstable_by(self.k.saturating_sub(1), |&a, &b| {
                x[b as usize].abs().total_cmp(&x[a as usize].abs())
            });
            indices.clear();
            indices.extend_from_slice(&order[..self.k]);
        });
        indices.sort_unstable();
        values.clear();
        values.extend(indices.iter().map(|&i| x[i as usize]));
    }
    fn omega(&self) -> Option<f64> {
        None // biased
    }
    fn delta(&self) -> Option<f64> {
        Some(self.k as f64 / self.d as f64)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------- SignScaled

/// ℓ1-scaled sign quantization (Karimireddy et al., 2019):
/// `C(x) = (‖x‖₁/d) · sign(x)`. Contractive with
/// `E‖C(x) − x‖² = ‖x‖² − ‖x‖₁²/d`, i.e. δ(x) = ‖x‖₁²/(d‖x‖²) ∈ [1/d, 1];
/// we report the worst-case δ = 1/d.
#[derive(Clone, Debug)]
pub struct SignScaled {
    pub d: usize,
}

impl SignScaled {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl Compressor for SignScaled {
    fn name(&self) -> String {
        "sign-l1".into()
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, _rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, scale, signs) = out.ensure_signscale();
        *dim = self.d as u32;
        *scale = nrm1(x) / self.d as f64;
        signs.clear();
        signs.extend(x.iter().map(|&v| v >= 0.0));
    }
    fn omega(&self) -> Option<f64> {
        None
    }
    fn delta(&self) -> Option<f64> {
        Some(1.0 / self.d as f64)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::empirical_variance_ratio;
    use crate::linalg::nrm2_sq;

    fn test_vec(d: usize, seed: u64) -> Vec<f64> {
        let mut g = Pcg64::new(seed);
        (0..d).map(|_| g.normal() * 2.0).collect()
    }

    #[test]
    fn zero_maps_to_zero() {
        let c = ZeroCompressor::new(4);
        let mut rng = Pcg64::new(1);
        assert_eq!(c.compress(&mut rng, &[1.0, 2.0, 3.0, 4.0]).decode(), vec![0.0; 4]);
        assert_eq!(c.delta(), Some(0.0));
        assert_eq!(c.omega(), None);
    }

    #[test]
    fn topk_keeps_largest() {
        let c = TopK::new(6, 2);
        let x = [0.1, -5.0, 0.3, 4.0, -0.2, 0.05];
        let mut rng = Pcg64::new(2);
        let out = c.compress(&mut rng, &x).decode();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_contraction_bound_holds() {
        // E‖C(x)−x‖² ≤ (1−K/d)‖x‖², deterministically for Top-K.
        let d = 50;
        for k in [1usize, 5, 25, 49, 50] {
            let c = TopK::new(d, k);
            let x = test_vec(d, 3 + k as u64);
            let mut rng = Pcg64::new(4);
            let err = crate::linalg::dist_sq(&c.compress(&mut rng, &x).decode(), &x);
            let bound = (1.0 - c.delta().unwrap()) * nrm2_sq(&x);
            assert!(err <= bound + 1e-9, "k={k}: {err} > {bound}");
        }
    }

    #[test]
    fn topk_is_the_best_k_sparse_approx() {
        // Top-K error ≤ Rand-K(unscaled) error for the same K.
        let d = 30;
        let k = 6;
        let x = test_vec(d, 5);
        let top = TopK::new(d, k);
        let mut rng = Pcg64::new(6);
        let top_err = crate::linalg::dist_sq(&top.compress(&mut rng, &x).decode(), &x);
        // random K-sparse selection without scaling
        for trial in 0..20 {
            let mut r = Pcg64::new(100 + trial);
            let idx = r.subset(d, k);
            let mut approx = vec![0.0; d];
            for &i in &idx {
                approx[i as usize] = x[i as usize];
            }
            assert!(top_err <= crate::linalg::dist_sq(&approx, &x) + 1e-12);
        }
    }

    #[test]
    fn sign_contraction_bound() {
        let d = 40;
        let c = SignScaled::new(d);
        let x = test_vec(d, 7);
        let mut rng = Pcg64::new(8);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 10);
        // must satisfy the B(1/d) bound; typically far better
        assert!(ratio <= 1.0 - 1.0 / d as f64 + 1e-9, "ratio {ratio}");
        // exact identity: ‖C(x)−x‖² = ‖x‖² − ‖x‖₁²/d
        let expected = (nrm2_sq(&x) - nrm1(&x).powi(2) / d as f64) / nrm2_sq(&x);
        assert!((ratio - expected).abs() < 1e-9);
    }

    #[test]
    fn topk_orders_nan_inputs_deterministically() {
        // |NaN| is a positive NaN, which total_cmp orders above +inf: a NaN
        // coordinate is always selected, and repeated compressions of the
        // same input pick the identical support (no visit-order dependence).
        let c = TopK::new(8, 3);
        let x = [
            0.1,
            -3.0,
            f64::NAN,
            0.2,
            f64::INFINITY,
            -0.5,
            7.0,
            f64::NAN,
        ];
        let mut rng = Pcg64::new(10);
        let select = |rng: &mut Pcg64| -> Vec<u32> {
            let pkt = c.compress(rng, &x);
            let Packet::Sparse { indices, .. } = pkt else {
                panic!("top-k emits sparse packets");
            };
            indices
        };
        let first = select(&mut rng);
        assert_eq!(first.len(), 3);
        // the two NaNs outrank +inf; the third slot goes to +inf
        assert_eq!(first, vec![2, 4, 7]);
        for _ in 0..10 {
            assert_eq!(select(&mut rng), first, "selection must be deterministic");
        }
    }

    #[test]
    fn topk_with_ties_keeps_exactly_k() {
        let c = TopK::new(5, 3);
        let x = [1.0, 1.0, 1.0, 1.0, 1.0];
        let mut rng = Pcg64::new(9);
        let out = c.compress(&mut rng, &x).decode();
        assert_eq!(out.iter().filter(|&&v| v != 0.0).count(), 3);
    }
}
