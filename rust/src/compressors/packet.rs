//! The on-the-wire representation of a compressed vector.
//!
//! Compressors produce a [`Packet`]; the coordinator serializes packets with
//! [`crate::wire`] before "sending" them. Bit accounting is derived from the
//! packet structure itself (what an efficient encoder actually needs), so
//! the x-axis of the paper's figures — *communicated bits* — is measured,
//! not assumed.
//!
//! # Sparse-aware consumption and the zero-allocation round contract
//!
//! The hot path never materializes a dense decode of a sparse message:
//! consumers fold packets straight into their accumulators with
//! [`Packet::add_scaled_into`], which costs O(nnz) for [`Packet::Sparse`] /
//! [`Packet::TernaryPkt`] / [`Packet::Zero`] payloads and O(d) — but
//! allocation-free — for the dense-shaped ones. [`Packet::decode_into`] and
//! [`Packet::decode`] remain as the reference implementations; property
//! tests in `tests/properties.rs` pin `add_scaled_into` to be bit-identical
//! to `decode` + `axpy` for every variant.
//!
//! Buffer ownership in a steady-state round:
//!
//! * each *worker* (a [`crate::algorithms::DcgdShift`] slot or a
//!   [`crate::coordinator`] thread) owns one scratch `Packet` per
//!   compressor and refills it in place every round via
//!   [`crate::compressors::Compressor::compress_into`];
//! * the *master* owns one scratch `Packet` per frame kind and refills it
//!   via [`crate::wire::decode_into`]; wire frames themselves are recycled
//!   by shipping the consumed buffers back to the worker with the next
//!   round command.
//!
//! After warm-up no `Packet` buffer is ever reallocated: index/value/sign
//! vectors are `clear()`ed and refilled at constant capacity (the counting
//! allocator test in `tests/alloc_free.rs` enforces this end to end).

/// Floating-point precision used for values on the wire.
///
/// The paper's simulations run in NumPy float64; we default to [`F64`] so
/// deep-convergence curves (relative errors down to 1e-30) are faithful,
/// and support [`F32`] for the common 32-bit accounting convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValPrec {
    F32,
    F64,
}

impl ValPrec {
    #[inline]
    pub fn bits(self) -> u64 {
        match self {
            ValPrec::F32 => 32,
            ValPrec::F64 => 64,
        }
    }

    /// Round `v` to this wire precision (identity for [`F64`]). Idempotent,
    /// and encoding a quantized value is lossless — state updates applied
    /// from a quantized packet are therefore reproducible on both ends of
    /// the link (the downlink delta and shift-refresh paths rely on this).
    ///
    /// [`F64`]: ValPrec::F64
    #[inline]
    pub fn quantize(self, v: f64) -> f64 {
        match self {
            ValPrec::F32 => v as f32 as f64,
            ValPrec::F64 => v,
        }
    }
}

/// Compressed message payloads.
#[derive(Clone, Debug, PartialEq)]
pub enum Packet {
    /// Uncompressed dense vector (Identity compressor, shift uploads).
    Dense(Vec<f64>),
    /// Sparse subset: sorted indices + values (+ an overall scale applied at
    /// decode, used by Rand-K's d/K factor so values stay at their original
    /// magnitudes on the wire).
    Sparse {
        dim: u32,
        indices: Vec<u32>,
        values: Vec<f64>,
        scale: f64,
    },
    /// Dithering-style quantization: one norm + per-coordinate sign and
    /// level index in `0..=s` (level 0 ⇒ coordinate is zero). Decoded value
    /// is `sign * norm * 2^(level - s)` for level ≥ 1.
    Levels {
        dim: u32,
        norm: f64,
        /// number of exponent levels `s` (level indices fit in
        /// `ceil(log2(s+1))` bits)
        s: u8,
        signs: Vec<bool>,
        levels: Vec<u8>,
    },
    /// Linear-grid dithering (QSGD-style): one norm + per-coordinate sign
    /// and integer level in `0..=s`; decoded value is
    /// `sign * norm * level / s`.
    LevelsLinear {
        dim: u32,
        norm: f64,
        s: u32,
        signs: Vec<bool>,
        levels: Vec<u8>,
    },
    /// Natural compression: per-coordinate sign + 8-bit exponent (the
    /// "float without mantissa" format). `exps[i] = i8::MIN` encodes an
    /// exact zero.
    NatExp { dim: u32, signs: Vec<bool>, exps: Vec<i8> },
    /// Sign quantization with a single scale: `scale * sign(x_i)`.
    SignScale {
        dim: u32,
        scale: f64,
        signs: Vec<bool>,
    },
    /// Ternary: sign bitmap + presence bitmap + one scale.
    TernaryPkt {
        dim: u32,
        scale: f64,
        /// non-zero mask
        mask: Vec<bool>,
        /// signs of the non-zero entries (len = popcount(mask))
        signs: Vec<bool>,
    },
    /// The zero vector (Bernoulli miss / Zero compressor): one flag bit.
    Zero { dim: u32 },
}

impl Packet {
    pub fn dim(&self) -> usize {
        match self {
            Packet::Dense(v) => v.len(),
            Packet::Sparse { dim, .. }
            | Packet::Levels { dim, .. }
            | Packet::LevelsLinear { dim, .. }
            | Packet::NatExp { dim, .. }
            | Packet::SignScale { dim, .. }
            | Packet::TernaryPkt { dim, .. }
            | Packet::Zero { dim } => *dim as usize,
        }
    }

    /// Decode into a dense vector (must be zeroed-capacity `dim` long).
    pub fn decode_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.dim(), "decode dim mismatch");
        match self {
            Packet::Dense(v) => out.copy_from_slice(v),
            Packet::Sparse {
                indices,
                values,
                scale,
                ..
            } => {
                out.iter_mut().for_each(|o| *o = 0.0);
                for (i, v) in indices.iter().zip(values.iter()) {
                    out[*i as usize] = scale * v;
                }
            }
            Packet::Levels {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in 0..out.len() {
                    let lvl = levels[i];
                    out[i] = if lvl == 0 {
                        0.0
                    } else {
                        let mag = norm * 2f64.powi(lvl as i32 - *s as i32);
                        if signs[i] {
                            mag
                        } else {
                            -mag
                        }
                    };
                }
            }
            Packet::LevelsLinear {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in 0..out.len() {
                    let mag = norm * levels[i] as f64 / *s as f64;
                    out[i] = if levels[i] == 0 {
                        0.0
                    } else if signs[i] {
                        mag
                    } else {
                        -mag
                    };
                }
            }
            Packet::NatExp { signs, exps, .. } => {
                for i in 0..out.len() {
                    out[i] = if exps[i] == i8::MIN {
                        0.0
                    } else {
                        let mag = 2f64.powi(exps[i] as i32);
                        if signs[i] {
                            mag
                        } else {
                            -mag
                        }
                    };
                }
            }
            Packet::SignScale { scale, signs, .. } => {
                for i in 0..out.len() {
                    out[i] = if signs[i] { *scale } else { -*scale };
                }
            }
            Packet::TernaryPkt {
                scale,
                mask,
                signs,
                ..
            } => {
                let mut sign_cursor = 0;
                for i in 0..out.len() {
                    if mask[i] {
                        out[i] = if signs[sign_cursor] { *scale } else { -*scale };
                        sign_cursor += 1;
                    } else {
                        out[i] = 0.0;
                    }
                }
            }
            Packet::Zero { .. } => out.iter_mut().for_each(|o| *o = 0.0),
        }
    }

    pub fn decode(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.decode_into(&mut out);
        out
    }

    /// `out += alpha * decode(self)` without materializing the decode.
    ///
    /// This is the sparse-aware aggregation primitive: Sparse/Ternary/Zero
    /// payloads are applied at O(nnz) (coordinates the packet does not
    /// carry are untouched), everything else at O(d) with zero heap
    /// traffic. Per-coordinate arithmetic reproduces `decode` + `axpy`
    /// bit-for-bit: each touched coordinate receives exactly
    /// `alpha * v_i` where `v_i` is the value `decode` would produce.
    /// (The only representational difference is that explicit zeros are
    /// skipped instead of adding `alpha * 0.0`, which can normalize a
    /// `-0.0` accumulator entry to `+0.0` in the dense path — invisible to
    /// `==` and to every downstream computation.)
    pub fn add_scaled_into(&self, alpha: f64, out: &mut [f64]) {
        assert_eq!(out.len(), self.dim(), "add_scaled dim mismatch");
        match self {
            Packet::Dense(v) => crate::linalg::axpy(alpha, v, out),
            Packet::Sparse {
                indices,
                values,
                scale,
                ..
            } => {
                if *scale == 1.0 {
                    crate::linalg::scatter_axpy(alpha, indices, values, out);
                } else {
                    for (i, v) in indices.iter().zip(values.iter()) {
                        out[*i as usize] += alpha * (*scale * *v);
                    }
                }
            }
            Packet::Levels {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in 0..out.len() {
                    let lvl = levels[i];
                    if lvl != 0 {
                        let mag = norm * 2f64.powi(lvl as i32 - *s as i32);
                        out[i] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::LevelsLinear {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in 0..out.len() {
                    if levels[i] != 0 {
                        let mag = norm * levels[i] as f64 / *s as f64;
                        out[i] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::NatExp { signs, exps, .. } => {
                for i in 0..out.len() {
                    if exps[i] != i8::MIN {
                        let mag = 2f64.powi(exps[i] as i32);
                        out[i] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::SignScale { scale, signs, .. } => {
                for i in 0..out.len() {
                    out[i] += alpha * if signs[i] { *scale } else { -*scale };
                }
            }
            Packet::TernaryPkt {
                scale,
                mask,
                signs,
                ..
            } => {
                let mut sign_cursor = 0;
                for i in 0..out.len() {
                    if mask[i] {
                        out[i] += alpha * if signs[sign_cursor] { *scale } else { -*scale };
                        sign_cursor += 1;
                    }
                }
            }
            Packet::Zero { .. } => {}
        }
    }

    /// Payload cursors at each shard cut point, for
    /// [`Packet::add_scaled_range`]. `cuts` are the `T + 1` ascending
    /// coordinate boundaries of the shard partition (`cuts[0] = 0`,
    /// `cuts[T] = d`, see `coordinator::pool::shard_cuts_into`); `out[s]`
    /// receives this packet's payload position at coordinate `cuts[s]`:
    ///
    /// * [`Packet::Sparse`] — the index-array offset, located with one
    ///   binary search (`partition_point`) over the sorted indices per cut;
    /// * [`Packet::TernaryPkt`] — the sign-array cursor, i.e. the prefix
    ///   popcount of the presence mask, computed for all cuts in one O(d)
    ///   pass;
    /// * dense-shaped variants — the coordinate itself (payloads are
    ///   coordinate-indexed).
    ///
    /// Bounds are computed once per packet per round and cached by the
    /// coordinator (reused buffer — allocation-free after warm-up), so the
    /// T-shard fold does O(T log nnz) location work instead of every shard
    /// scanning the payload from the start.
    pub fn shard_bounds_into(&self, cuts: &[usize], out: &mut Vec<u32>) {
        out.clear();
        match self {
            Packet::Sparse { indices, .. } => {
                for &c in cuts {
                    out.push(indices.partition_point(|&i| (i as usize) < c) as u32);
                }
            }
            Packet::TernaryPkt { mask, .. } => {
                let mut cursor = 0u32;
                let mut pos = 0usize;
                for &c in cuts {
                    while pos < c {
                        cursor += u32::from(mask[pos]);
                        pos += 1;
                    }
                    out.push(cursor);
                }
            }
            _ => out.extend(cuts.iter().map(|&c| c as u32)),
        }
    }

    /// Shard-restricted [`Packet::add_scaled_into`]: applies exactly the
    /// coordinates in `[lo, hi)` to `out`, which is the **pre-sliced**
    /// shard sub-range (`out.len() == hi - lo`; `out[i - lo]` is global
    /// coordinate `i`). `bounds` are this packet's payload cursors at `lo`
    /// and `hi` from [`Packet::shard_bounds_into`] (ignored by the
    /// dense-shaped variants).
    ///
    /// Per-coordinate arithmetic is byte-for-byte the same expression as
    /// `add_scaled_into`, so running every shard of a partition of
    /// `[0, d)` reproduces the unsharded apply bit-identically — the
    /// parallel fold's bit-identity invariant rests on this (pinned by the
    /// `sharded_apply_matches_full_apply` test below for every variant).
    pub fn add_scaled_range(
        &self,
        alpha: f64,
        lo: usize,
        hi: usize,
        bounds: (u32, u32),
        out: &mut [f64],
    ) {
        debug_assert!(lo <= hi && hi <= self.dim());
        debug_assert_eq!(out.len(), hi - lo, "add_scaled_range shard-slice mismatch");
        match self {
            Packet::Dense(v) => crate::linalg::axpy(alpha, &v[lo..hi], out),
            Packet::Sparse {
                indices,
                values,
                scale,
                ..
            } => {
                let (b0, b1) = (bounds.0 as usize, bounds.1 as usize);
                if *scale == 1.0 {
                    for (i, v) in indices[b0..b1].iter().zip(values[b0..b1].iter()) {
                        out[*i as usize - lo] += alpha * *v;
                    }
                } else {
                    for (i, v) in indices[b0..b1].iter().zip(values[b0..b1].iter()) {
                        out[*i as usize - lo] += alpha * (*scale * *v);
                    }
                }
            }
            Packet::Levels {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in lo..hi {
                    let lvl = levels[i];
                    if lvl != 0 {
                        let mag = norm * 2f64.powi(lvl as i32 - *s as i32);
                        out[i - lo] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::LevelsLinear {
                norm,
                s,
                signs,
                levels,
                ..
            } => {
                for i in lo..hi {
                    if levels[i] != 0 {
                        let mag = norm * levels[i] as f64 / *s as f64;
                        out[i - lo] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::NatExp { signs, exps, .. } => {
                for i in lo..hi {
                    if exps[i] != i8::MIN {
                        let mag = 2f64.powi(exps[i] as i32);
                        out[i - lo] += alpha * if signs[i] { mag } else { -mag };
                    }
                }
            }
            Packet::SignScale { scale, signs, .. } => {
                for i in lo..hi {
                    out[i - lo] += alpha * if signs[i] { *scale } else { -*scale };
                }
            }
            Packet::TernaryPkt {
                scale,
                mask,
                signs,
                ..
            } => {
                let mut sign_cursor = bounds.0 as usize;
                for i in lo..hi {
                    if mask[i] {
                        out[i - lo] += alpha * if signs[sign_cursor] { *scale } else { -*scale };
                        sign_cursor += 1;
                    }
                }
            }
            Packet::Zero { .. } => {}
        }
    }

    /// Round every floating-point field (values, scales, norms) to the
    /// wire precision, in place. A quantized packet survives the
    /// encode → decode round-trip bit for bit, so *both* ends of a link
    /// can apply the identical packet and stay bit-equal — the downlink
    /// delta path has always relied on this ([`crate::wire::build_update_packet`]),
    /// and workers quantize their uplink packets before folding them into
    /// local shift state so `h` matches the master's wire-reconstructed
    /// replica under f32 precision too. Idempotent; a no-op for
    /// [`ValPrec::F64`]. Exponent/level/sign fields are integers and are
    /// exact on the wire already.
    pub fn quantize(&mut self, prec: ValPrec) {
        if prec == ValPrec::F64 {
            return;
        }
        match self {
            Packet::Dense(v) => {
                for x in v.iter_mut() {
                    *x = prec.quantize(*x);
                }
            }
            Packet::Sparse { values, scale, .. } => {
                *scale = prec.quantize(*scale);
                for x in values.iter_mut() {
                    *x = prec.quantize(*x);
                }
            }
            Packet::Levels { norm, .. } | Packet::LevelsLinear { norm, .. } => {
                *norm = prec.quantize(*norm);
            }
            Packet::SignScale { scale, .. } | Packet::TernaryPkt { scale, .. } => {
                *scale = prec.quantize(*scale);
            }
            Packet::NatExp { .. } | Packet::Zero { .. } => {}
        }
    }

    /// Copy `src` into `self`, reusing the existing buffers when the
    /// variants match (the recycled-scratch analog of `clone_from`; the
    /// derived `Clone` would reallocate every call). Only the Sparse and
    /// Dense arms are on hot paths ([`crate::wire::build_update_packet`]
    /// outputs, staged per-sub-step in batched EF-uplink rounds); other
    /// variants fall back to a plain clone.
    pub fn copy_from(&mut self, src: &Packet) {
        match src {
            Packet::Sparse {
                dim,
                indices,
                values,
                scale,
            } => {
                let (d, i, v, s) = self.ensure_sparse();
                *d = *dim;
                *s = *scale;
                i.clear();
                i.extend_from_slice(indices);
                v.clear();
                v.extend_from_slice(values);
            }
            Packet::Dense(vals) => {
                let v = self.ensure_dense();
                v.clear();
                v.extend_from_slice(vals);
            }
            other => *self = other.clone(),
        }
    }

    /// Number of coordinates this packet actually carries (what
    /// [`add_scaled_into`](Self::add_scaled_into) will touch) — `dim` for
    /// dense-shaped payloads, the support size for sparse ones.
    pub fn nnz(&self) -> usize {
        match self {
            Packet::Sparse { indices, .. } => indices.len(),
            Packet::TernaryPkt { signs, .. } => signs.len(),
            Packet::Zero { .. } => 0,
            _ => self.dim(),
        }
    }

    /// Exact number of payload bits an efficient encoder needs for this
    /// packet (matches [`crate::wire`]'s bit-level encoding, excluding the
    /// fixed per-message header). This is what the "communicated bits"
    /// axis of the figures integrates.
    pub fn payload_bits(&self, prec: ValPrec) -> u64 {
        let vb = prec.bits();
        match self {
            Packet::Dense(v) => v.len() as u64 * vb,
            Packet::Sparse {
                dim,
                indices,
                values,
                ..
            } => {
                let idx_bits = index_bits(*dim);
                indices.len() as u64 * idx_bits + values.len() as u64 * vb + vb /* scale */
            }
            Packet::Levels { dim, s, .. } => {
                let lvl_bits = bits_for_levels(*s);
                vb /* norm */ + (*dim as u64) * (1 + lvl_bits)
            }
            Packet::LevelsLinear { dim, s, .. } => {
                let n = s + 1; // levels 0..=s
                let lvl_bits = if n <= 1 {
                    1
                } else {
                    (32 - (n - 1).leading_zeros()) as u64
                };
                vb /* norm */ + (*dim as u64) * (1 + lvl_bits)
            }
            Packet::NatExp { dim, .. } => (*dim as u64) * 9, // sign + 8-bit exponent
            Packet::SignScale { dim, .. } => vb + *dim as u64,
            Packet::TernaryPkt { dim, signs, .. } => vb + *dim as u64 + signs.len() as u64,
            Packet::Zero { .. } => 1,
        }
    }
}

/// `ensure_*` accessors: make `self` hold the named variant — reusing its
/// buffers when the variant already matches, replacing it with an empty
/// instance otherwise — and return mutable references to the variant's
/// fields. These centralize the "reset scratch packet to variant X,
/// destructure, refill" pattern shared by every `compress_into` /
/// `decode_into` implementation. Buffers are **not** cleared: callers
/// refill them (and keep their capacity, which is what makes the
/// steady-state round pipeline allocation-free).
impl Packet {
    pub fn ensure_dense(&mut self) -> &mut Vec<f64> {
        if !matches!(self, Packet::Dense(_)) {
            *self = Packet::Dense(Vec::new());
        }
        let Packet::Dense(v) = self else { unreachable!() };
        v
    }

    /// Returns `(dim, indices, values, scale)`.
    pub fn ensure_sparse(&mut self) -> (&mut u32, &mut Vec<u32>, &mut Vec<f64>, &mut f64) {
        if !matches!(self, Packet::Sparse { .. }) {
            *self = Packet::Sparse {
                dim: 0,
                indices: Vec::new(),
                values: Vec::new(),
                scale: 0.0,
            };
        }
        let Packet::Sparse {
            dim,
            indices,
            values,
            scale,
        } = self
        else {
            unreachable!()
        };
        (dim, indices, values, scale)
    }

    /// Returns `(dim, norm, s, signs, levels)`.
    pub fn ensure_levels(
        &mut self,
    ) -> (&mut u32, &mut f64, &mut u8, &mut Vec<bool>, &mut Vec<u8>) {
        if !matches!(self, Packet::Levels { .. }) {
            *self = Packet::Levels {
                dim: 0,
                norm: 0.0,
                s: 0,
                signs: Vec::new(),
                levels: Vec::new(),
            };
        }
        let Packet::Levels {
            dim,
            norm,
            s,
            signs,
            levels,
        } = self
        else {
            unreachable!()
        };
        (dim, norm, s, signs, levels)
    }

    /// Returns `(dim, norm, s, signs, levels)`.
    pub fn ensure_levels_linear(
        &mut self,
    ) -> (&mut u32, &mut f64, &mut u32, &mut Vec<bool>, &mut Vec<u8>) {
        if !matches!(self, Packet::LevelsLinear { .. }) {
            *self = Packet::LevelsLinear {
                dim: 0,
                norm: 0.0,
                s: 0,
                signs: Vec::new(),
                levels: Vec::new(),
            };
        }
        let Packet::LevelsLinear {
            dim,
            norm,
            s,
            signs,
            levels,
        } = self
        else {
            unreachable!()
        };
        (dim, norm, s, signs, levels)
    }

    /// Returns `(dim, signs, exps)`.
    pub fn ensure_natexp(&mut self) -> (&mut u32, &mut Vec<bool>, &mut Vec<i8>) {
        if !matches!(self, Packet::NatExp { .. }) {
            *self = Packet::NatExp {
                dim: 0,
                signs: Vec::new(),
                exps: Vec::new(),
            };
        }
        let Packet::NatExp { dim, signs, exps } = self else {
            unreachable!()
        };
        (dim, signs, exps)
    }

    /// Returns `(dim, scale, signs)`.
    pub fn ensure_signscale(&mut self) -> (&mut u32, &mut f64, &mut Vec<bool>) {
        if !matches!(self, Packet::SignScale { .. }) {
            *self = Packet::SignScale {
                dim: 0,
                scale: 0.0,
                signs: Vec::new(),
            };
        }
        let Packet::SignScale { dim, scale, signs } = self else {
            unreachable!()
        };
        (dim, scale, signs)
    }

    /// Returns `(dim, scale, mask, signs)`.
    pub fn ensure_ternary(&mut self) -> (&mut u32, &mut f64, &mut Vec<bool>, &mut Vec<bool>) {
        if !matches!(self, Packet::TernaryPkt { .. }) {
            *self = Packet::TernaryPkt {
                dim: 0,
                scale: 0.0,
                mask: Vec::new(),
                signs: Vec::new(),
            };
        }
        let Packet::TernaryPkt {
            dim,
            scale,
            mask,
            signs,
        } = self
        else {
            unreachable!()
        };
        (dim, scale, mask, signs)
    }
}

/// Cached [`Packet::payload_bits`] evaluator.
///
/// A worker emits the same packet *shape* (variant, dimension, level count,
/// precision) every round; only the item count (sparse support, ternary
/// hits) varies. This memoizes the shape-derived constants — index/level
/// bit widths and fixed per-message terms — so the steady-state bit
/// accounting is one multiply-add instead of a recomputation of
/// `leading_zeros`-based formulas. Always returns exactly what
/// [`Packet::payload_bits`] returns (pinned by tests).
#[derive(Clone, Debug, Default)]
pub struct PayloadBitsCache {
    key: Option<(u8, u32, u32, u8)>,
    fixed: u64,
    per_item: u64,
}

impl PayloadBitsCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bits(&mut self, pkt: &Packet, prec: ValPrec) -> u64 {
        let vb = prec.bits();
        // (variant tag, dim, shape param) identifies the formula constants;
        // the item count is applied per call.
        let (tag, dim, sp, count) = match pkt {
            Packet::Dense(v) => (0u8, 0u32, 0u32, v.len() as u64),
            Packet::Sparse { dim, indices, .. } => (1, *dim, 0, indices.len() as u64),
            Packet::Levels { dim, s, .. } => (2, *dim, *s as u32, 0),
            Packet::LevelsLinear { dim, s, .. } => (3, *dim, *s, 0),
            Packet::NatExp { dim, .. } => (4, *dim, 0, 0),
            Packet::SignScale { dim, .. } => (5, *dim, 0, 0),
            Packet::TernaryPkt { dim, signs, .. } => (6, *dim, 0, signs.len() as u64),
            Packet::Zero { .. } => (7, 0, 0, 0),
        };
        let key = (tag, dim, sp, prec.bits() as u8);
        if self.key != Some(key) {
            let (fixed, per_item) = match pkt {
                Packet::Dense(_) => (0, vb),
                Packet::Sparse { dim, .. } => (vb, index_bits(*dim) + vb),
                Packet::Levels { dim, s, .. } => {
                    (vb + *dim as u64 * (1 + bits_for_levels(*s)), 0)
                }
                Packet::LevelsLinear { dim, s, .. } => {
                    let n = s + 1;
                    let lb = if n <= 1 {
                        1
                    } else {
                        (32 - (n - 1).leading_zeros()) as u64
                    };
                    (vb + *dim as u64 * (1 + lb), 0)
                }
                Packet::NatExp { dim, .. } => (*dim as u64 * 9, 0),
                Packet::SignScale { dim, .. } => (vb + *dim as u64, 0),
                Packet::TernaryPkt { dim, .. } => (vb + *dim as u64, 1),
                Packet::Zero { .. } => (1, 0),
            };
            self.key = Some(key);
            self.fixed = fixed;
            self.per_item = per_item;
        }
        self.fixed + self.per_item * count
    }
}

/// Bits needed per index for a vector of dimension `dim`.
#[inline]
pub fn index_bits(dim: u32) -> u64 {
    if dim <= 1 {
        1
    } else {
        (32 - (dim - 1).leading_zeros()) as u64
    }
}

/// Bits needed to store a level index in `0..=s`.
#[inline]
pub fn bits_for_levels(s: u8) -> u64 {
    let n = s as u32 + 1; // levels 0..=s
    if n <= 1 {
        1
    } else {
        (32 - (n - 1).leading_zeros()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let p = Packet::Dense(vec![1.0, -2.0, 3.5]);
        assert_eq!(p.decode(), vec![1.0, -2.0, 3.5]);
        assert_eq!(p.payload_bits(ValPrec::F64), 3 * 64);
        assert_eq!(p.payload_bits(ValPrec::F32), 3 * 32);
    }

    #[test]
    fn sparse_decode_applies_scale() {
        let p = Packet::Sparse {
            dim: 5,
            indices: vec![1, 4],
            values: vec![2.0, -1.0],
            scale: 2.5,
        };
        assert_eq!(p.decode(), vec![0.0, 5.0, 0.0, 0.0, -2.5]);
        // 3 index bits for dim=5, two values + scale in f64
        assert_eq!(p.payload_bits(ValPrec::F64), 2 * 3 + 3 * 64);
    }

    #[test]
    fn levels_decode() {
        // s = 3: level l decodes to norm * 2^(l-3); level 0 is zero.
        let p = Packet::Levels {
            dim: 4,
            norm: 8.0,
            s: 3,
            signs: vec![true, false, true, true],
            levels: vec![3, 2, 0, 1],
        };
        assert_eq!(p.decode(), vec![8.0, -4.0, 0.0, 2.0]);
        // norm (64) + 4 * (1 sign + 2 level bits)
        assert_eq!(p.payload_bits(ValPrec::F64), 64 + 4 * 3);
    }

    #[test]
    fn natexp_decode() {
        let p = Packet::NatExp {
            dim: 3,
            signs: vec![true, false, true],
            exps: vec![2, -1, i8::MIN],
        };
        assert_eq!(p.decode(), vec![4.0, -0.5, 0.0]);
        assert_eq!(p.payload_bits(ValPrec::F64), 27);
    }

    #[test]
    fn sign_and_ternary_decode() {
        let p = Packet::SignScale {
            dim: 3,
            scale: 0.5,
            signs: vec![true, false, true],
        };
        assert_eq!(p.decode(), vec![0.5, -0.5, 0.5]);

        let t = Packet::TernaryPkt {
            dim: 4,
            scale: 3.0,
            mask: vec![true, false, false, true],
            signs: vec![false, true],
        };
        assert_eq!(t.decode(), vec![-3.0, 0.0, 0.0, 3.0]);
        assert_eq!(t.payload_bits(ValPrec::F64), 64 + 4 + 2);
    }

    #[test]
    fn add_scaled_matches_decode_axpy_per_variant() {
        let pkts = vec![
            Packet::Dense(vec![1.5, -2.0, 0.25]),
            Packet::Sparse {
                dim: 3,
                indices: vec![0, 2],
                values: vec![2.0, -4.0],
                scale: 1.5,
            },
            Packet::Sparse {
                dim: 3,
                indices: vec![1],
                values: vec![3.0],
                scale: 1.0,
            },
            Packet::Levels {
                dim: 3,
                norm: 8.0,
                s: 3,
                signs: vec![true, false, true],
                levels: vec![3, 2, 0],
            },
            Packet::LevelsLinear {
                dim: 3,
                norm: 2.0,
                s: 4,
                signs: vec![false, true, true],
                levels: vec![4, 0, 1],
            },
            Packet::NatExp {
                dim: 3,
                signs: vec![true, false, true],
                exps: vec![2, -1, i8::MIN],
            },
            Packet::SignScale {
                dim: 3,
                scale: 0.5,
                signs: vec![true, false, true],
            },
            Packet::TernaryPkt {
                dim: 3,
                scale: 3.0,
                mask: vec![true, false, true],
                signs: vec![false, true],
            },
            Packet::Zero { dim: 3 },
        ];
        for pkt in &pkts {
            for &alpha in &[1.0, -0.75, 0.0, 2.5] {
                let acc0 = [0.5, -1.25, 2.0];
                // reference: dense decode + axpy
                let mut want = acc0;
                let dec = pkt.decode();
                for j in 0..3 {
                    want[j] += alpha * dec[j];
                }
                let mut got = acc0;
                pkt.add_scaled_into(alpha, &mut got);
                for j in 0..3 {
                    assert_eq!(
                        got[j].to_bits(),
                        want[j].to_bits(),
                        "{pkt:?} alpha={alpha} coord {j}: {} vs {}",
                        got[j],
                        want[j]
                    );
                }
            }
        }
    }

    #[test]
    fn nnz_reports_support_size() {
        assert_eq!(Packet::Zero { dim: 9 }.nnz(), 0);
        assert_eq!(Packet::Dense(vec![0.0; 4]).nnz(), 4);
        let p = Packet::Sparse {
            dim: 100,
            indices: vec![3, 7],
            values: vec![1.0, 2.0],
            scale: 1.0,
        };
        assert_eq!(p.nnz(), 2);
        let t = Packet::TernaryPkt {
            dim: 6,
            scale: 1.0,
            mask: vec![true, false, false, true, false, false],
            signs: vec![true, false],
        };
        assert_eq!(t.nnz(), 2);
    }

    #[test]
    fn zero_packet() {
        let p = Packet::Zero { dim: 7 };
        assert_eq!(p.decode(), vec![0.0; 7]);
        assert_eq!(p.payload_bits(ValPrec::F64), 1);
    }

    #[test]
    fn index_bit_widths() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(80), 7);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
    }

    #[test]
    fn ensure_accessors_reuse_matching_buffers() {
        let mut p = Packet::Sparse {
            dim: 9,
            indices: Vec::with_capacity(123),
            values: vec![1.0, 2.0],
            scale: 4.0,
        };
        {
            let (dim, indices, values, scale) = p.ensure_sparse();
            assert_eq!(indices.capacity(), 123, "matching variant keeps buffers");
            assert_eq!(values, &vec![1.0, 2.0], "buffers are not cleared");
            *dim = 5;
            *scale = 1.0;
        }
        // mismatched variant is replaced by an empty instance
        let v = p.ensure_dense();
        assert!(v.is_empty());
        v.extend_from_slice(&[7.0, 8.0]);
        assert_eq!(p, Packet::Dense(vec![7.0, 8.0]));
        let (dim, norm, s, signs, levels) = p.ensure_levels();
        *dim = 2;
        *norm = 1.0;
        *s = 1;
        signs.extend_from_slice(&[true, false]);
        levels.extend_from_slice(&[1, 0]);
        assert_eq!(p.decode(), vec![1.0, 0.0]);
        let _ = p.ensure_levels_linear();
        assert!(matches!(p, Packet::LevelsLinear { .. }));
        let _ = p.ensure_natexp();
        assert!(matches!(p, Packet::NatExp { .. }));
        let _ = p.ensure_signscale();
        assert!(matches!(p, Packet::SignScale { .. }));
        let _ = p.ensure_ternary();
        assert!(matches!(p, Packet::TernaryPkt { .. }));
    }

    #[test]
    fn payload_bits_cache_matches_direct_formula() {
        let pkts = vec![
            Packet::Dense(vec![1.0; 7]),
            Packet::Sparse {
                dim: 80,
                indices: vec![0, 9, 79],
                values: vec![1.0; 3],
                scale: 1.0,
            },
            Packet::Sparse {
                dim: 80,
                indices: vec![5],
                values: vec![2.0],
                scale: 1.0,
            },
            Packet::Levels {
                dim: 5,
                norm: 1.0,
                s: 3,
                signs: vec![true; 5],
                levels: vec![1; 5],
            },
            Packet::LevelsLinear {
                dim: 5,
                norm: 1.0,
                s: 9,
                signs: vec![true; 5],
                levels: vec![1; 5],
            },
            Packet::NatExp {
                dim: 4,
                signs: vec![true; 4],
                exps: vec![0; 4],
            },
            Packet::SignScale {
                dim: 6,
                scale: 1.0,
                signs: vec![true; 6],
            },
            Packet::TernaryPkt {
                dim: 6,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true],
                signs: vec![true, false, true],
            },
            Packet::Zero { dim: 11 },
        ];
        // one shared cache driven across mismatched shapes (worst case for
        // the keying), plus repeated hits on the same shape
        let mut cache = PayloadBitsCache::new();
        for prec in [ValPrec::F64, ValPrec::F32] {
            for pkt in &pkts {
                assert_eq!(cache.bits(pkt, prec), pkt.payload_bits(prec), "{pkt:?}");
                assert_eq!(cache.bits(pkt, prec), pkt.payload_bits(prec), "hit {pkt:?}");
            }
        }
    }

    #[test]
    fn quantize_roundtrips_through_f32() {
        assert_eq!(ValPrec::F64.quantize(0.1), 0.1);
        let q = ValPrec::F32.quantize(0.1);
        assert_ne!(q, 0.1);
        assert_eq!(ValPrec::F32.quantize(q), q, "quantize must be idempotent");
        assert_eq!(q as f32 as f64, q);
    }

    #[test]
    fn quantize_rounds_every_float_field() {
        let mut pkts = vec![
            Packet::Dense(vec![0.1, -0.2, 0.0]),
            Packet::Sparse {
                dim: 9,
                indices: vec![1, 7],
                values: vec![0.1, -7.3],
                scale: 0.3,
            },
            Packet::Levels {
                dim: 2,
                norm: 0.1,
                s: 3,
                signs: vec![true, false],
                levels: vec![1, 2],
            },
            Packet::LevelsLinear {
                dim: 2,
                norm: 0.7,
                s: 5,
                signs: vec![true, false],
                levels: vec![1, 2],
            },
            Packet::NatExp {
                dim: 2,
                signs: vec![true, false],
                exps: vec![3, i8::MIN],
            },
            Packet::SignScale {
                dim: 2,
                scale: 0.1,
                signs: vec![true, false],
            },
            Packet::TernaryPkt {
                dim: 2,
                scale: 0.1,
                mask: vec![true, false],
                signs: vec![true],
            },
            Packet::Zero { dim: 4 },
        ];
        for pkt in pkts.iter_mut() {
            // F64 is the identity
            let before = pkt.clone();
            pkt.quantize(ValPrec::F64);
            assert_eq!(*pkt, before, "f64 quantize must be a no-op");
            // F32 rounds every float *field* to an f32-representable double
            // (decoded products like norm·2^(l−s) may still leave f32 range;
            // what matters is that the fields survive the wire round-trip)
            pkt.quantize(ValPrec::F32);
            let fields: Vec<f64> = match &*pkt {
                Packet::Dense(v) => v.clone(),
                Packet::Sparse { values, scale, .. } => {
                    values.iter().copied().chain([*scale]).collect()
                }
                Packet::Levels { norm, .. } | Packet::LevelsLinear { norm, .. } => vec![*norm],
                Packet::SignScale { scale, .. } | Packet::TernaryPkt { scale, .. } => {
                    vec![*scale]
                }
                Packet::NatExp { .. } | Packet::Zero { .. } => vec![],
            };
            for v in fields {
                assert_eq!(v as f32 as f64, v, "{pkt:?} field {v} not f32-exact");
            }
            // idempotent
            let once = pkt.clone();
            pkt.quantize(ValPrec::F32);
            assert_eq!(*pkt, once, "f32 quantize must be idempotent");
        }
    }

    #[test]
    fn level_bit_widths() {
        assert_eq!(bits_for_levels(1), 1); // levels {0,1}
        assert_eq!(bits_for_levels(3), 2); // {0..3}
        assert_eq!(bits_for_levels(4), 3); // {0..4}
        assert_eq!(bits_for_levels(15), 4);
    }

    #[test]
    fn sharded_apply_matches_full_apply() {
        // Every variant, several shard partitions (including empty shards
        // and the trivial 1-shard split): applying add_scaled_range over a
        // partition of [0, d) must be bit-identical to add_scaled_into.
        let d = 13usize;
        let pkts = vec![
            Packet::Dense((0..d).map(|i| i as f64 * 0.37 - 2.0).collect()),
            Packet::Sparse {
                dim: d as u32,
                indices: vec![0, 3, 4, 7, 12],
                values: vec![2.0, -4.0, 0.5, 1.25, -9.0],
                scale: 1.5,
            },
            Packet::Sparse {
                dim: d as u32,
                indices: vec![2, 11],
                values: vec![3.0, -1.0],
                scale: 1.0,
            },
            Packet::Levels {
                dim: d as u32,
                norm: 8.0,
                s: 3,
                signs: (0..d).map(|i| i % 2 == 0).collect(),
                levels: (0..d).map(|i| (i % 4) as u8).collect(),
            },
            Packet::LevelsLinear {
                dim: d as u32,
                norm: 2.0,
                s: 4,
                signs: (0..d).map(|i| i % 3 == 0).collect(),
                levels: (0..d).map(|i| (i % 5) as u8).collect(),
            },
            Packet::NatExp {
                dim: d as u32,
                signs: (0..d).map(|i| i % 2 == 1).collect(),
                exps: (0..d)
                    .map(|i| if i % 4 == 0 { i8::MIN } else { (i as i8) - 6 })
                    .collect(),
            },
            Packet::SignScale {
                dim: d as u32,
                scale: 0.5,
                signs: (0..d).map(|i| i % 3 != 1).collect(),
            },
            Packet::TernaryPkt {
                dim: d as u32,
                scale: 3.0,
                mask: (0..d).map(|i| i % 3 != 0).collect(),
                signs: (0..d).filter(|i| i % 3 != 0).map(|i| i % 2 == 0).collect(),
            },
            Packet::Zero { dim: d as u32 },
        ];
        let partitions: Vec<Vec<usize>> = vec![
            vec![0, d],                   // T = 1
            vec![0, 7, d],                // T = 2
            vec![0, 4, 4, 9, d],          // T = 4 with an empty shard
            (0..=d).collect(),            // T = d, one coordinate each
        ];
        let acc0: Vec<f64> = (0..d).map(|i| (i as f64) * 0.11 - 0.6).collect();
        let mut bounds = Vec::new();
        for pkt in &pkts {
            for alpha in [1.0, -0.75, 2.5] {
                let mut want = acc0.clone();
                pkt.add_scaled_into(alpha, &mut want);
                for cuts in &partitions {
                    pkt.shard_bounds_into(cuts, &mut bounds);
                    assert_eq!(bounds.len(), cuts.len());
                    let mut got = acc0.clone();
                    for s in 0..cuts.len() - 1 {
                        let (lo, hi) = (cuts[s], cuts[s + 1]);
                        pkt.add_scaled_range(
                            alpha,
                            lo,
                            hi,
                            (bounds[s], bounds[s + 1]),
                            &mut got[lo..hi],
                        );
                    }
                    for j in 0..d {
                        assert_eq!(
                            got[j].to_bits(),
                            want[j].to_bits(),
                            "{pkt:?} alpha={alpha} cuts={cuts:?} coord {j}"
                        );
                    }
                }
            }
        }
    }
}
