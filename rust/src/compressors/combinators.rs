//! Compressor combinators — the constructions at the heart of the paper.
//!
//! * [`Shifted`] — Definition 3 / Lemma 1: `Q_h(x) = h + Q(x − h)`,
//!   `Q_h ∈ U(ω; h)`. Shifts add up: shifting a shifted compressor by `v`
//!   lands in `U(ω; h + v)`.
//! * [`Induced`] — Definition 4 (Horváth & Richtárik, 2021):
//!   `Q_ind(x) = C(x) + Q(x − C(x)) ∈ U(ω(1 − δ))` for `C ∈ B(δ)`,
//!   `Q ∈ U(ω)`. This is how biased compressors enter the DIANA-like shift
//!   update (10) and its improved rate in Theorem 3.
//! * [`Scaled`] — `α·Q`; for `α = 1/(ω+1)` turns `Q ∈ U(ω)` into a
//!   contractive `B(1/(ω+1))` operator (Beznosikov et al., 2020).

use crate::compressors::packet::Packet;
use crate::compressors::Compressor;
use crate::util::rng::Pcg64;

// ------------------------------------------------------------------- Shifted

/// A shifted compressor `Q_h(x) = h + Q(x − h)` with a *fixed* shift vector.
///
/// In the algorithms the shift changes every round and the shift arithmetic
/// is done by the algorithm itself on raw packets (so only `Q(x − h)` hits
/// the wire); this combinator exists as a faithful object-level realization
/// of Definition 3, used in tests of Lemma 1 and in single-node code.
pub struct Shifted {
    pub h: Vec<f64>,
    pub inner: Box<dyn Compressor>,
}

impl Shifted {
    pub fn new(h: Vec<f64>, inner: Box<dyn Compressor>) -> Self {
        assert_eq!(h.len(), inner.dim());
        Self { h, inner }
    }

    /// Apply, returning the dense result `h + Q(x − h)` (a packet cannot
    /// represent the uncompressed shift addition — by design: the shift is
    /// *state shared by both endpoints*, it never travels on the wire).
    pub fn apply(&self, rng: &mut Pcg64, x: &[f64]) -> Vec<f64> {
        let d = self.h.len();
        assert_eq!(x.len(), d);
        let diff: Vec<f64> = (0..d).map(|i| x[i] - self.h[i]).collect();
        let mut out = self.inner.compress(rng, &diff).decode();
        for i in 0..d {
            out[i] += self.h[i];
        }
        out
    }

    pub fn omega(&self) -> Option<f64> {
        self.inner.omega()
    }
}

// ------------------------------------------------------------------- Induced

/// The induced compressor `Q_ind(x) = C(x) + Q(x − C(x))`.
///
/// Unbiased with `ω_ind = ω(1 − δ)` (Lemma 3 of the paper). The `C(x)` part
/// and the `Q(x − C(x))` part are both packets; `compress` returns them
/// fused as a dense-equivalent [`Packet::Dense`] would lose the bit
/// accounting, so we return a two-part packet via [`InducedPacket`].
pub struct Induced {
    pub c: Box<dyn Compressor>,
    pub q: Box<dyn Compressor>,
}

/// The two wire messages produced by one induced-compression application.
pub struct InducedPacket {
    pub c_part: Packet,
    pub q_part: Packet,
}

impl InducedPacket {
    pub fn decode(&self) -> Vec<f64> {
        let mut out = self.c_part.decode();
        let q = self.q_part.decode();
        for i in 0..out.len() {
            out[i] += q[i];
        }
        out
    }

    pub fn payload_bits(&self, prec: crate::compressors::ValPrec) -> u64 {
        self.c_part.payload_bits(prec) + self.q_part.payload_bits(prec)
    }
}

impl Induced {
    pub fn new(c: Box<dyn Compressor>, q: Box<dyn Compressor>) -> Self {
        assert_eq!(c.dim(), q.dim(), "induced parts must share dimension");
        Self { c, q }
    }

    pub fn dim(&self) -> usize {
        self.c.dim()
    }

    /// ω(1 − δ) — Lemma 3.
    pub fn omega(&self) -> Option<f64> {
        match (self.q.omega(), self.c.delta()) {
            (Some(w), Some(d)) => Some(w * (1.0 - d)),
            _ => None,
        }
    }

    pub fn apply(&self, rng: &mut Pcg64, x: &[f64]) -> InducedPacket {
        let c_part = self.c.compress(rng, x);
        let cx = c_part.decode();
        let resid: Vec<f64> = x.iter().zip(cx.iter()).map(|(a, b)| a - b).collect();
        let q_part = self.q.compress(rng, &resid);
        InducedPacket { c_part, q_part }
    }
}

/// Adapter: expose [`Induced`] through the [`Compressor`] trait by fusing
/// both parts into a dense packet whose bit count is the true two-part sum.
/// (Dense packets have a fixed bit formula, so we carry the real cost via a
/// wrapper that recomputes it — see `compress` which returns a `Sparse`
/// packet holding all touched coordinates when that is cheaper.)
pub struct InducedCompressor {
    pub inner: std::sync::Arc<Induced>,
}

impl InducedCompressor {
    pub fn new(c: Box<dyn Compressor>, q: Box<dyn Compressor>) -> Self {
        Self {
            inner: std::sync::Arc::new(Induced::new(c, q)),
        }
    }
}

impl Compressor for InducedCompressor {
    fn name(&self) -> String {
        format!("induced({}, {})", self.inner.c.name(), self.inner.q.name())
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        // Fuse to dense; algorithms that need exact two-part bit accounting
        // use `Induced::apply` directly (the DIANA-like shift path does).
        let pkt = self.inner.apply(rng, x);
        Packet::Dense(pkt.decode())
    }
    fn omega(&self) -> Option<f64> {
        self.inner.omega()
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(InducedCompressor {
            inner: self.inner.clone(),
        })
    }
}

// -------------------------------------------------------------------- Scaled

/// `α · Q(·)`. For unbiased `Q ∈ U(ω)` and `α = 1/(ω+1)` this is the
/// canonical contractive scaling `B(1/(ω+1))`.
pub struct Scaled {
    pub alpha: f64,
    pub inner: Box<dyn Compressor>,
}

impl Scaled {
    pub fn new(alpha: f64, inner: Box<dyn Compressor>) -> Self {
        Self { alpha, inner }
    }

    /// The canonical unbiased→contractive scaling α = 1/(ω+1).
    pub fn canonical(inner: Box<dyn Compressor>) -> Self {
        let w = inner
            .omega()
            .expect("canonical scaling needs an unbiased inner compressor");
        Self::new(1.0 / (w + 1.0), inner)
    }
}

impl Compressor for Scaled {
    fn name(&self) -> String {
        format!("scaled({}, {})", self.alpha, self.inner.name())
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let pkt = self.inner.compress(rng, x);
        scale_packet(pkt, self.alpha)
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        self.inner.compress_into(rng, x, out);
        scale_packet_mut(out, self.alpha);
    }
    fn omega(&self) -> Option<f64> {
        // α·Q is biased for α ≠ 1 (E[αQ(x)] = αx).
        if self.alpha == 1.0 {
            self.inner.omega()
        } else {
            None
        }
    }
    fn delta(&self) -> Option<f64> {
        // E‖αQ(x) − x‖² = (1−α)²‖x‖² + α²·E‖Q(x)−x‖² ≤ ((1−α)² + α²ω)‖x‖²
        let w = self.inner.omega()?;
        let a = self.alpha;
        let contraction = (1.0 - a) * (1.0 - a) + a * a * w;
        if contraction < 1.0 {
            Some(1.0 - contraction)
        } else {
            None
        }
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(Scaled {
            alpha: self.alpha,
            inner: self.inner.clone_box(),
        })
    }
}

/// Multiply a packet's decoded value by `a` without densifying.
pub fn scale_packet(mut pkt: Packet, a: f64) -> Packet {
    scale_packet_mut(&mut pkt, a);
    pkt
}

/// In-place variant of [`scale_packet`] for the zero-allocation hot path:
/// every variant except [`Packet::NatExp`] is rescaled without touching the
/// heap (NatExp has no scale knob on its power-of-two grid, so general
/// scaling densifies it — documented allocation).
pub fn scale_packet_mut(pkt: &mut Packet, a: f64) {
    if matches!(pkt, Packet::NatExp { .. }) {
        // general scaling leaves the power-of-two grid; densify
        let mut v = pkt.decode();
        for x in v.iter_mut() {
            *x *= a;
        }
        *pkt = Packet::Dense(v);
        return;
    }
    let flip = a < 0.0;
    match pkt {
        Packet::Dense(v) => {
            for x in v.iter_mut() {
                *x *= a;
            }
        }
        Packet::Sparse { scale, .. } => *scale *= a,
        Packet::Levels { norm, signs, .. } | Packet::LevelsLinear { norm, signs, .. } => {
            *norm *= a.abs();
            if flip {
                for b in signs.iter_mut() {
                    *b = !*b;
                }
            }
        }
        Packet::SignScale { scale, signs, .. } | Packet::TernaryPkt { scale, signs, .. } => {
            *scale *= a.abs();
            if flip {
                for b in signs.iter_mut() {
                    *b = !*b;
                }
            }
        }
        Packet::NatExp { .. } => unreachable!("handled above"),
        Packet::Zero { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{
        empirical_bias_ratio, empirical_variance_ratio, RandK, TopK, ZeroCompressor,
    };
    use crate::linalg::{dist_sq, nrm2_sq};

    fn test_vec(d: usize, seed: u64) -> Vec<f64> {
        let mut g = Pcg64::new(seed);
        (0..d).map(|_| g.normal() * 2.0 + 1.0).collect()
    }

    #[test]
    fn shifted_variance_concentrates_at_shift() {
        // Q_h has zero variance at x = h (the defining property that makes
        // shifts useful: the "special point" moves from 0 to h).
        let d = 20;
        let h = test_vec(d, 1);
        let q = Shifted::new(h.clone(), Box::new(RandK::new(d, 4)));
        let mut rng = Pcg64::new(2);
        let out = q.apply(&mut rng, &h);
        assert!(dist_sq(&out, &h) < 1e-20);
    }

    #[test]
    fn shifted_is_unbiased_everywhere() {
        let d = 15;
        let h = test_vec(d, 3);
        let x = test_vec(d, 4);
        let q = Shifted::new(h, Box::new(RandK::new(d, 3)));
        let mut rng = Pcg64::new(5);
        let trials = 40_000;
        let mut mean = vec![0.0; d];
        for _ in 0..trials {
            let o = q.apply(&mut rng, &x);
            crate::linalg::axpy(1.0 / trials as f64, &o, &mut mean);
        }
        let rel = dist_sq(&mean, &x).sqrt() / crate::linalg::nrm2(&x);
        assert!(rel < 0.02, "bias {rel}");
    }

    #[test]
    fn shifted_variance_bound_uses_distance_to_shift() {
        // E‖Q_h(x) − x‖² ≤ ω‖x − h‖² (Definition 3(b)).
        let d = 25;
        let h = test_vec(d, 6);
        let x = test_vec(d, 7);
        let inner = RandK::new(d, 5); // ω = 4
        let omega = inner.omega().unwrap();
        let q = Shifted::new(h.clone(), Box::new(inner));
        let mut rng = Pcg64::new(8);
        let trials = 5_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let o = q.apply(&mut rng, &x);
            acc += dist_sq(&o, &x);
        }
        let bound = omega * dist_sq(&x, &h);
        assert!(acc / trials as f64 <= bound * 1.1, "{} vs {bound}", acc / trials as f64);
    }

    #[test]
    fn lemma1_shift_addition() {
        // Q(x) := v + Q_h(x − v) ∈ U(ω; h+v): zero variance at x = h + v.
        let d = 10;
        let h = test_vec(d, 9);
        let v = test_vec(d, 10);
        let inner = Shifted::new(h.clone(), Box::new(RandK::new(d, 2)));
        let mut rng = Pcg64::new(11);
        let hv: Vec<f64> = h.iter().zip(v.iter()).map(|(a, b)| a + b).collect();
        // apply composed operator at x = h + v
        let shifted_arg: Vec<f64> = hv.iter().zip(v.iter()).map(|(a, b)| a - b).collect();
        let mut out = inner.apply(&mut rng, &shifted_arg);
        for i in 0..d {
            out[i] += v[i];
        }
        assert!(dist_sq(&out, &hv) < 1e-20);
    }

    #[test]
    fn induced_unbiased_with_reduced_omega() {
        let d = 30;
        let c = TopK::new(d, 15); // δ = 0.5
        let q = RandK::new(d, 6); // ω = 4
        let ind = Induced::new(Box::new(c), Box::new(q));
        assert!((ind.omega().unwrap() - 2.0).abs() < 1e-12); // 4 · (1 − 0.5)

        let x = test_vec(d, 12);
        let mut rng = Pcg64::new(13);
        // unbiased
        let trials = 30_000;
        let mut mean = vec![0.0; d];
        for _ in 0..trials {
            let o = ind.apply(&mut rng, &x).decode();
            crate::linalg::axpy(1.0 / trials as f64, &o, &mut mean);
        }
        let rel = dist_sq(&mean, &x).sqrt() / crate::linalg::nrm2(&x);
        assert!(rel < 0.02, "bias {rel}");
        // variance within ω(1−δ)
        let mut acc = 0.0;
        let trials2 = 5_000;
        for _ in 0..trials2 {
            let o = ind.apply(&mut rng, &x).decode();
            acc += dist_sq(&o, &x);
        }
        let ratio = acc / trials2 as f64 / nrm2_sq(&x);
        assert!(ratio <= ind.omega().unwrap() * 1.1, "ratio {ratio}");
    }

    #[test]
    fn induced_beats_plain_q_variance() {
        // The whole point of Lemma 3: Q_ind variance ≤ Q variance.
        let d = 40;
        let x = test_vec(d, 14);
        let q_plain = RandK::new(d, 4); // ω = 9
        let ind = InducedCompressor::new(
            Box::new(TopK::new(d, 20)),
            Box::new(RandK::new(d, 4)),
        );
        let mut r1 = Pcg64::new(15);
        let mut r2 = Pcg64::new(16);
        let v_plain = empirical_variance_ratio(&q_plain, &mut r1, &x, 4_000);
        let v_ind = empirical_variance_ratio(&ind, &mut r2, &x, 4_000);
        assert!(v_ind < v_plain, "induced {v_ind} vs plain {v_plain}");
    }

    #[test]
    fn scaled_canonical_is_contractive() {
        let d = 20;
        let c = Scaled::canonical(Box::new(RandK::new(d, 4))); // ω=4 → α=0.2
        assert!((c.alpha - 0.2).abs() < 1e-12);
        let delta = c.delta().unwrap();
        assert!((delta - 0.2).abs() < 1e-12); // 1 − ((1−α)² + α²ω) = α for canonical
        let x = test_vec(d, 17);
        let mut rng = Pcg64::new(18);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 8_000);
        assert!(ratio <= (1.0 - delta) * 1.05, "ratio {ratio}");
    }

    #[test]
    fn scale_packet_matches_dense_scaling() {
        let d = 16;
        let x = test_vec(d, 19);
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(RandK::new(d, 4)),
            Box::new(crate::compressors::NaturalDithering::l2(d, 4)),
            Box::new(crate::compressors::NaturalCompression::new(d)),
            Box::new(TopK::new(d, 4)),
            Box::new(crate::compressors::Ternary::new(d)),
            Box::new(ZeroCompressor::new(d)),
        ];
        for c in &comps {
            for &a in &[2.0, -0.5, 0.0] {
                let mut r1 = Pcg64::new(20);
                let mut r2 = Pcg64::new(20);
                let direct = c.compress(&mut r1, &x).decode();
                let scaled = scale_packet(c.compress(&mut r2, &x), a).decode();
                for i in 0..d {
                    assert!(
                        (direct[i] * a - scaled[i]).abs() < 1e-12,
                        "{}: coord {i}: {} vs {}",
                        c.name(),
                        direct[i] * a,
                        scaled[i]
                    );
                }
            }
        }
    }

    #[test]
    fn scaled_compress_into_matches_compress() {
        let d = 12;
        let x = test_vec(d, 23);
        for &a in &[0.2, -1.5] {
            let c = Scaled::new(a, Box::new(RandK::new(d, 3)));
            let mut r1 = Pcg64::new(9);
            let mut r2 = r1.clone();
            let fresh = c.compress(&mut r1, &x);
            // dirty scratch of a mismatched variant
            let mut scratch = Packet::Zero { dim: d as u32 };
            c.compress_into(&mut r2, &x, &mut scratch);
            assert_eq!(fresh, scratch);
            // nat-comp inner: scaling densifies on both paths identically
            let c = Scaled::new(a, Box::new(crate::compressors::NaturalCompression::new(d)));
            let mut r1 = Pcg64::new(10);
            let mut r2 = r1.clone();
            let fresh = c.compress(&mut r1, &x);
            c.compress_into(&mut r2, &x, &mut scratch);
            assert_eq!(fresh, scratch);
        }
    }

    #[test]
    fn induced_compressor_trait_bias() {
        let d = 12;
        let ind = InducedCompressor::new(Box::new(TopK::new(d, 6)), Box::new(RandK::new(d, 3)));
        let x = test_vec(d, 21);
        let mut rng = Pcg64::new(22);
        assert!(empirical_bias_ratio(&ind, &mut rng, &x, 30_000) < 0.02);
    }
}
