//! Compression operators.
//!
//! Two classes from the paper (Definitions 1–2):
//!
//! * **Unbiased** `Q ∈ U(ω)`: `E Q(x) = x` and `E‖Q(x) − x‖² ≤ ω‖x‖²`.
//! * **Contractive (possibly biased)** `C ∈ B(δ)`:
//!   `E‖C(x) − x‖² ≤ (1 − δ)‖x‖²`, δ ∈ (0, 1].
//!
//! plus the paper's central concept, the **shifted compressor**
//! `Q_h(x) = h + Q(x − h) ∈ U(ω; h)` (Definition 3, realized by
//! [`combinators::Shifted`]) and the **induced compressor**
//! `Q_ind(x) = C(x) + Q(x − C(x)) ∈ U(ω(1 − δ))` (Definition 4,
//! [`combinators::Induced`]).
//!
//! Every compressor returns a [`Packet`] whose wire encoding defines the
//! *measured* communicated bits. ω/δ accessors expose the theoretical
//! constants consumed by the step-size rules in [`crate::theory`].

pub mod biased;
pub mod combinators;
pub mod packet;
pub mod unbiased;

pub use biased::{SignScaled, TopK, ZeroCompressor};
pub use combinators::{Induced, Scaled, Shifted};
pub use packet::{index_bits, Packet, PayloadBitsCache, ValPrec};
pub use unbiased::{
    BernoulliP, Identity, NaturalCompression, NaturalDithering, RandK, StandardDithering, Ternary,
};

use crate::util::rng::Pcg64;

/// A (possibly randomized) compression operator `R^d → R^d`.
pub trait Compressor: Send + Sync {
    /// Short human-readable identifier, e.g. `rand-k(8/80)`.
    fn name(&self) -> String;

    /// Dimension this operator was constructed for.
    fn dim(&self) -> usize;

    /// Apply the operator to `x` using the caller's RNG stream.
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet;

    /// Apply the operator, writing the result into `out` and reusing its
    /// buffers (indices/values/signs/levels vectors) when `out` already
    /// holds the matching [`Packet`] variant. This is the zero-allocation
    /// hot path: steady-state rounds recycle one scratch packet per
    /// compressor and never reallocate.
    ///
    /// Contract: the resulting packet — and the sequence of draws taken
    /// from `rng` — must be **identical** to what [`compress`](Self::compress)
    /// produces from the same generator state, regardless of `out`'s prior
    /// contents (pinned by property tests in `tests/properties.rs`). The
    /// default implementation falls back to `compress` (allocating);
    /// in-tree compressors override it.
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        *out = self.compress(rng, x);
    }

    /// Unbiased variance parameter ω with `E‖Q(x) − x‖² ≤ ω‖x‖²`,
    /// or `None` if the operator is biased.
    fn omega(&self) -> Option<f64>;

    /// Contraction parameter δ with `E‖C(x) − x‖² ≤ (1 − δ)‖x‖²`.
    ///
    /// Defined for every operator in the library: for unbiased `Q ∈ U(ω)`,
    /// the *scaled* operator `Q/(ω+1) ∈ B(1/(ω+1))`, and we report that
    /// canonical value (Beznosikov et al., 2020). For the Zero operator the
    /// paper's convention "δ interpreted as 0" applies.
    fn delta(&self) -> Option<f64> {
        self.omega().map(|w| 1.0 / (w + 1.0))
    }

    fn clone_box(&self) -> Box<dyn Compressor>;
}

impl Clone for Box<dyn Compressor> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Convenience: compress and immediately decode to a dense vector,
/// returning the payload bit count too. Single-process algorithm drivers
/// use this; the distributed coordinator keeps the packet and encodes it.
pub fn compress_dense(
    c: &dyn Compressor,
    rng: &mut Pcg64,
    x: &[f64],
    prec: ValPrec,
) -> (Vec<f64>, u64) {
    let pkt = c.compress(rng, x);
    let bits = pkt.payload_bits(prec);
    (pkt.decode(), bits)
}

/// Monte-Carlo estimate of `E‖Q(x) − x‖² / ‖x‖²` at a given point — used by
/// tests to verify ω (and `1 − δ`) bounds empirically.
pub fn empirical_variance_ratio(
    c: &dyn Compressor,
    rng: &mut Pcg64,
    x: &[f64],
    trials: usize,
) -> f64 {
    let xn = crate::linalg::nrm2_sq(x);
    if xn == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    let mut buf = vec![0.0; x.len()];
    for _ in 0..trials {
        let pkt = c.compress(rng, x);
        pkt.decode_into(&mut buf);
        acc += crate::linalg::dist_sq(&buf, x);
    }
    acc / trials as f64 / xn
}

/// Monte-Carlo estimate of the bias `‖E Q(x) − x‖ / ‖x‖`.
pub fn empirical_bias_ratio(
    c: &dyn Compressor,
    rng: &mut Pcg64,
    x: &[f64],
    trials: usize,
) -> f64 {
    let mut mean = vec![0.0; x.len()];
    let mut buf = vec![0.0; x.len()];
    for _ in 0..trials {
        let pkt = c.compress(rng, x);
        pkt.decode_into(&mut buf);
        crate::linalg::axpy(1.0, &buf, &mut mean);
    }
    crate::linalg::scale(1.0 / trials as f64, &mut mean);
    let xn = crate::linalg::nrm2(x);
    if xn == 0.0 {
        return crate::linalg::nrm2(&mean);
    }
    let diff: f64 = mean
        .iter()
        .zip(x.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    diff / xn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compress_dense_matches_packet_decode() {
        let mut rng = Pcg64::new(5);
        let c = RandK::new(10, 4);
        let x: Vec<f64> = (0..10).map(|i| i as f64 - 4.5).collect();
        let mut rng2 = rng.clone();
        let (dense, bits) = compress_dense(&c, &mut rng, &x, ValPrec::F64);
        let pkt = c.compress(&mut rng2, &x);
        assert_eq!(dense, pkt.decode());
        assert_eq!(bits, pkt.payload_bits(ValPrec::F64));
    }

    #[test]
    fn box_clone_preserves_behaviour() {
        let c: Box<dyn Compressor> = Box::new(RandK::new(8, 2));
        let c2 = c.clone();
        assert_eq!(c.name(), c2.name());
        assert_eq!(c.omega(), c2.omega());
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(1);
        let x = vec![1.0; 8];
        assert_eq!(c.compress(&mut r1, &x), c2.compress(&mut r2, &x));
    }

    #[test]
    fn default_delta_is_scaled_inverse() {
        let c = RandK::new(10, 5); // omega = 1
        assert!((c.delta().unwrap() - 0.5).abs() < 1e-12);
    }
}
