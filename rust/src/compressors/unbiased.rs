//! Unbiased compression operators `Q ∈ U(ω)` (Definition 2).
//!
//! | Operator             | ω                                             | wire payload |
//! |----------------------|-----------------------------------------------|--------------|
//! | [`Identity`]         | 0                                             | d values |
//! | [`RandK`]            | d/K − 1                                       | K indices + K values |
//! | [`NaturalDithering`] | 1/8 + d^{1/p}·2^{1−s}·min(1, d^{1/p}·2^{1−s}) | 1 norm + d·(1+⌈log₂(s+1)⌉) bits |
//! | [`StandardDithering`]| min(d/s², √d/s) (QSGD bound)                  | same shape |
//! | [`NaturalCompression`]| 1/8                                          | 9 bits/coordinate |
//! | [`BernoulliP`]       | 1/p − 1                                       | dense w.p. p, else 1 bit |
//! | [`Ternary`]          | √d − 1 (worst case)                           | 1 scale + ≤2 bits/coordinate |

use crate::compressors::packet::Packet;
use crate::compressors::Compressor;
use crate::linalg::{nrm2, nrm_inf, nrmp};
use crate::util::rng::Pcg64;

/// `floor(log2(x))` for finite positive normal `x`, via the IEEE-754
/// exponent field — ~10× cheaper than `x.log2().floor()` on the dithering
/// hot path. Falls back to the slow path for subnormals.
#[inline]
fn log2_floor(x: f64) -> i32 {
    debug_assert!(x > 0.0);
    let bits = x.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32;
    if exp == 0 {
        // subnormal — rare (|x_i|/norm below 2^-1022)
        return x.log2().floor() as i32;
    }
    exp - 1023
}

/// `2^e` for |e| ≤ 1022 via bit construction (no `powi` call).
#[inline]
fn exp2_i(e: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

// ------------------------------------------------------------------ Identity

/// The identity operator: ω = 0, full communication. `DGD` in Table 2.
#[derive(Clone, Debug)]
pub struct Identity {
    pub d: usize,
}

impl Identity {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, _rng: &mut Pcg64, x: &[f64]) -> Packet {
        Packet::Dense(x.to_vec())
    }
    fn compress_into(&self, _rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        let v = out.ensure_dense();
        v.clear();
        v.extend_from_slice(x);
    }
    fn omega(&self) -> Option<f64> {
        Some(0.0)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// -------------------------------------------------------------------- Rand-K

/// Random sparsification (Rand-K), Eq. (2) of the paper:
/// `Q(x) = (d/K) Σ_{i∈S} x_i e_i` over a uniformly random K-subset S.
/// `Q ∈ U(d/K − 1)`.
#[derive(Clone, Debug)]
pub struct RandK {
    pub d: usize,
    pub k: usize,
}

impl RandK {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d, "Rand-K needs 1 ≤ K ≤ d (got K={k}, d={d})");
        Self { d, k }
    }

    /// Construct from the paper's `q = K/d` share of kept coordinates.
    pub fn with_q(d: usize, q: f64) -> Self {
        let k = ((q * d as f64).round() as usize).clamp(1, d);
        Self::new(d, k)
    }

    pub fn q(&self) -> f64 {
        self.k as f64 / self.d as f64
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand-k({}/{})", self.k, self.d)
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, indices, values, scale) = out.ensure_sparse();
        *dim = self.d as u32;
        *scale = self.d as f64 / self.k as f64;
        rng.subset_into(self.d, self.k, indices);
        values.clear();
        values.extend(indices.iter().map(|&i| x[i as usize]));
    }
    fn omega(&self) -> Option<f64> {
        Some(self.d as f64 / self.k as f64 - 1.0)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// -------------------------------------------------------- Natural Dithering

/// Natural Dithering `D^{nat}_{p,s}` (Horváth et al., 2019a): coordinates
/// are randomly rounded to the binary level grid
/// `{0, 2^{1−s}, 2^{2−s}, …, 2^{−1}, 1} · ‖x‖_p`, preserving expectations.
///
/// ω = 1/8 + d^{1/p}·2^{1−s} · min(1, d^{1/p}·2^{1−s}).
#[derive(Clone, Debug)]
pub struct NaturalDithering {
    pub d: usize,
    /// number of binary levels s ≥ 1
    pub s: u8,
    /// which ℓp norm scales the grid (paper's experiments use p = 2)
    pub p: f64,
}

impl NaturalDithering {
    pub fn new(d: usize, s: u8, p: f64) -> Self {
        assert!(s >= 1, "need at least one level");
        assert!(p >= 1.0);
        Self { d, s, p }
    }

    pub fn l2(d: usize, s: u8) -> Self {
        Self::new(d, s, 2.0)
    }

    pub fn omega_formula(d: usize, s: u8, p: f64) -> f64 {
        let r = (d as f64).powf(1.0 / p) * 2f64.powi(1 - s as i32);
        0.125 + r * r.min(1.0)
    }
}

impl Compressor for NaturalDithering {
    fn name(&self) -> String {
        format!("nat-dith(s={}, p={})", self.s, self.p)
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, norm, out_s, signs, levels) = out.ensure_levels();
        let s = self.s;
        *dim = self.d as u32;
        *out_s = s;
        signs.clear();
        signs.resize(self.d, false);
        levels.clear();
        levels.resize(self.d, 0u8);
        let nrm = nrmp(x, self.p);
        *norm = nrm;
        if nrm == 0.0 {
            return;
        }
        let inv_norm = 1.0 / nrm; // one divide, d multiplies (§Perf)
        let tiny = exp2_i(1 - s as i32); // smallest positive grid level
        for i in 0..self.d {
            let v = x[i];
            signs[i] = v >= 0.0;
            let u = v.abs() * inv_norm; // ∈ [0, 1]
            if u == 0.0 {
                continue;
            }
            // Find the bracketing binary levels. Level index l ∈ {1..s}
            // decodes to 2^{l−s}; level 0 decodes to 0.
            // Upper level: smallest grid point ≥ u  (clamped to 1).
            // floor(log2): u ∈ [2^{e}, 2^{e+1}). Bit-level fast paths —
            // see log2_floor/exp2_i (§Perf).
            let e = log2_floor(u); // u ≥ 2^e
            let lo_exp = e.max(1 - s as i32).min(0); // grid exponent of lower bracket
            let lo = if u >= tiny {
                exp2_i(lo_exp)
            } else {
                0.0 // below the smallest positive level
            };
            let hi = if lo == 0.0 {
                tiny
            } else {
                exp2_i((lo_exp + 1).min(0))
            };
            let (lo, hi) = if u >= 1.0 {
                (1.0, 1.0)
            } else if (u - lo).abs() < f64::EPSILON * lo {
                (lo, lo)
            } else {
                (lo, hi)
            };
            let chosen = if hi == lo {
                hi
            } else {
                // unbiased randomized rounding between lo and hi
                let p_hi = (u - lo) / (hi - lo);
                if rng.bernoulli(p_hi) {
                    hi
                } else {
                    lo
                }
            };
            levels[i] = if chosen == 0.0 {
                0
            } else {
                // chosen = 2^{l−s} ⇒ l = log2(chosen) + s (exact powers of
                // two: the exponent field IS the answer)
                (log2_floor(chosen) + s as i32) as u8
            };
        }
    }
    fn omega(&self) -> Option<f64> {
        Some(Self::omega_formula(self.d, self.s, self.p))
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// -------------------------------------------------------- Standard Dithering

/// Standard (linear-grid) random dithering with s uniform levels — the QSGD
/// quantizer (Alistarh et al., 2017). ω = min(d/s², √d/s).
#[derive(Clone, Debug)]
pub struct StandardDithering {
    pub d: usize,
    pub s: u32,
}

impl StandardDithering {
    pub fn new(d: usize, s: u32) -> Self {
        assert!(s >= 1);
        Self { d, s }
    }
}

impl Compressor for StandardDithering {
    fn name(&self) -> String {
        format!("std-dith(s={})", self.s)
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        assert!(self.s <= 255, "StandardDithering supports s ≤ 255");
        let (dim, norm, out_s, signs, levels) = out.ensure_levels_linear();
        *dim = self.d as u32;
        *out_s = self.s;
        signs.clear();
        signs.resize(self.d, false);
        levels.clear();
        levels.resize(self.d, 0u8);
        let nrm = nrm2(x);
        *norm = nrm;
        let s = self.s as f64;
        if nrm > 0.0 {
            for i in 0..self.d {
                let v = x[i];
                signs[i] = v >= 0.0;
                // Randomized rounding on the uniform grid {0, 1/s, ..., 1}:
                // level q satisfies E[q/s] = |v|/norm.
                let u = v.abs() / nrm * s; // ∈ [0, s]
                let lo = u.floor();
                let p_hi = u - lo;
                let q = lo + if rng.bernoulli(p_hi) { 1.0 } else { 0.0 };
                levels[i] = q as u8;
            }
        }
    }
    fn omega(&self) -> Option<f64> {
        let d = self.d as f64;
        let s = self.s as f64;
        Some((d / (s * s)).min(d.sqrt() / s))
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// ------------------------------------------------------- Natural Compression

/// Natural compression `C_{nat}` (Horváth et al., 2019a): randomized
/// rounding of each coordinate to the nearest power of two, preserving the
/// sign and expectation. ω = 1/8; 9 bits per coordinate on the wire.
#[derive(Clone, Debug)]
pub struct NaturalCompression {
    pub d: usize,
}

impl NaturalCompression {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl Compressor for NaturalCompression {
    fn name(&self) -> String {
        "nat-comp".into()
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, signs, exps) = out.ensure_natexp();
        *dim = self.d as u32;
        signs.clear();
        signs.resize(self.d, false);
        exps.clear();
        exps.resize(self.d, i8::MIN);
        for i in 0..self.d {
            let v = x[i];
            signs[i] = v >= 0.0;
            let a = v.abs();
            if a == 0.0 {
                continue;
            }
            let e = log2_floor(a);
            let lo = if (-1022..=1023).contains(&e) {
                exp2_i(e)
            } else {
                2f64.powi(e)
            };
            let p_hi = (a - lo) / lo; // ∈ [0, 1): round up to 2^{e+1} w.p. (a−2^e)/2^e
            let chosen_e = if rng.bernoulli(p_hi) { e + 1 } else { e };
            // clamp to i8 exponent range (|x| ∈ [2^-126, 2^127] covers f32)
            exps[i] = chosen_e.clamp(-126, 127) as i8;
        }
    }
    fn omega(&self) -> Option<f64> {
        Some(0.125)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// ------------------------------------------------------------- Bernoulli_p

/// The Bernoulli compressor `B_p` from Table 2: the *whole vector* is sent
/// (scaled by 1/p) with probability p, otherwise nothing is sent.
/// Unbiased with ω = 1/p − 1. This is the natural `C_i` realization of the
/// Rand-DIANA shift update viewed through the shift form (4).
#[derive(Clone, Debug)]
pub struct BernoulliP {
    pub d: usize,
    pub p: f64,
}

impl BernoulliP {
    pub fn new(d: usize, p: f64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "Bernoulli needs p ∈ (0, 1]");
        Self { d, p }
    }
}

impl Compressor for BernoulliP {
    fn name(&self) -> String {
        format!("bernoulli(p={})", self.p)
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        if rng.bernoulli(self.p) {
            let v = out.ensure_dense();
            v.clear();
            v.extend(x.iter().map(|v| v / self.p));
        } else {
            // miss: one flag bit on the wire. (The hit↔miss flip drops the
            // dense buffer — Bernoulli is not on the zero-alloc bench path.)
            *out = Packet::Zero { dim: self.d as u32 };
        }
    }
    fn omega(&self) -> Option<f64> {
        Some(1.0 / self.p - 1.0)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

// ------------------------------------------------------------------ Ternary

/// TernGrad-style ternary quantization (Wen et al., 2017):
/// `Q(x)_i = ‖x‖_∞ · sign(x_i) · Bernoulli(|x_i|/‖x‖_∞)`.
/// Unbiased; `E‖Q(x)‖² ≤ ‖x‖_∞‖x‖₁ ≤ √d‖x‖²` ⇒ ω ≤ √d − 1.
#[derive(Clone, Debug)]
pub struct Ternary {
    pub d: usize,
}

impl Ternary {
    pub fn new(d: usize) -> Self {
        Self { d }
    }
}

impl Compressor for Ternary {
    fn name(&self) -> String {
        "ternary".into()
    }
    fn dim(&self) -> usize {
        self.d
    }
    fn compress(&self, rng: &mut Pcg64, x: &[f64]) -> Packet {
        let mut out = Packet::Zero { dim: self.d as u32 };
        self.compress_into(rng, x, &mut out);
        out
    }
    fn compress_into(&self, rng: &mut Pcg64, x: &[f64], out: &mut Packet) {
        assert_eq!(x.len(), self.d);
        let (dim, scale, mask, signs) = out.ensure_ternary();
        *dim = self.d as u32;
        mask.clear();
        mask.resize(self.d, false);
        signs.clear();
        let sc = nrm_inf(x);
        *scale = sc;
        if sc > 0.0 {
            for i in 0..self.d {
                let p = x[i].abs() / sc;
                if rng.bernoulli(p) {
                    mask[i] = true;
                    signs.push(x[i] >= 0.0);
                }
            }
        }
    }
    fn omega(&self) -> Option<f64> {
        Some((self.d as f64).sqrt() - 1.0)
    }
    fn clone_box(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{empirical_bias_ratio, empirical_variance_ratio};

    fn test_vec(d: usize, seed: u64) -> Vec<f64> {
        let mut g = Pcg64::new(seed);
        (0..d).map(|_| g.normal() * 3.0 + 0.5).collect()
    }

    #[test]
    fn identity_is_exact() {
        let c = Identity::new(6);
        let x = test_vec(6, 1);
        let mut rng = Pcg64::new(2);
        assert_eq!(c.compress(&mut rng, &x).decode(), x);
        assert_eq!(c.omega(), Some(0.0));
    }

    #[test]
    fn randk_keeps_k_scaled_coordinates() {
        let c = RandK::new(10, 3);
        let x = test_vec(10, 3);
        let mut rng = Pcg64::new(4);
        let out = c.compress(&mut rng, &x).decode();
        let nonzero: Vec<usize> = (0..10).filter(|&i| out[i] != 0.0).collect();
        assert!(nonzero.len() <= 3);
        for &i in &nonzero {
            assert!((out[i] - x[i] * 10.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn randk_unbiased_and_variance_bounded() {
        let d = 40;
        let c = RandK::new(d, 8); // omega = 4
        let x = test_vec(d, 5);
        let mut rng = Pcg64::new(6);
        assert!(empirical_bias_ratio(&c, &mut rng, &x, 20_000) < 0.02);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 5_000);
        assert!(ratio <= c.omega().unwrap() * 1.05, "ratio {ratio}");
    }

    #[test]
    fn randk_with_q_matches_paper_parameterization() {
        let c = RandK::with_q(80, 0.1);
        assert_eq!(c.k, 8);
        assert!((c.omega().unwrap() - 9.0).abs() < 1e-12);
        let c = RandK::with_q(80, 0.9);
        assert_eq!(c.k, 72);
    }

    #[test]
    fn natural_dithering_unbiased() {
        let d = 30;
        for s in [2u8, 5, 9] {
            let c = NaturalDithering::l2(d, s);
            let x = test_vec(d, 7 + s as u64);
            let mut rng = Pcg64::new(8);
            let bias = empirical_bias_ratio(&c, &mut rng, &x, 30_000);
            assert!(bias < 0.02, "s={s}: bias {bias}");
        }
    }

    #[test]
    fn natural_dithering_variance_within_formula() {
        let d = 30;
        for s in [2u8, 4, 8] {
            let c = NaturalDithering::l2(d, s);
            let x = test_vec(d, 11 + s as u64);
            let mut rng = Pcg64::new(12);
            let ratio = empirical_variance_ratio(&c, &mut rng, &x, 4_000);
            let omega = c.omega().unwrap();
            assert!(ratio <= omega * 1.1 + 0.02, "s={s}: {ratio} vs ω={omega}");
        }
    }

    #[test]
    fn natural_dithering_levels_are_grid_points() {
        let d = 12;
        let c = NaturalDithering::l2(d, 4);
        let x = test_vec(d, 13);
        let mut rng = Pcg64::new(14);
        let pkt = c.compress(&mut rng, &x);
        if let Packet::Levels { norm, s, levels, .. } = &pkt {
            for &l in levels {
                assert!(l <= *s);
            }
            let out = pkt.decode();
            for (i, &v) in out.iter().enumerate() {
                if v != 0.0 {
                    let u = v.abs() / norm;
                    let log = u.log2();
                    assert!((log - log.round()).abs() < 1e-9, "coord {i}: {u}");
                }
            }
        } else {
            panic!("expected Levels packet");
        }
    }

    #[test]
    fn natural_dithering_zero_vector() {
        let c = NaturalDithering::l2(5, 3);
        let mut rng = Pcg64::new(15);
        assert_eq!(c.compress(&mut rng, &[0.0; 5]).decode(), vec![0.0; 5]);
    }

    #[test]
    fn natural_compression_unbiased_small_variance() {
        let d = 25;
        let c = NaturalCompression::new(d);
        let x = test_vec(d, 16);
        let mut rng = Pcg64::new(17);
        assert!(empirical_bias_ratio(&c, &mut rng, &x, 30_000) < 0.01);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 10_000);
        assert!(ratio <= 0.125 * 1.1, "ratio {ratio}");
    }

    #[test]
    fn natural_compression_outputs_powers_of_two() {
        let c = NaturalCompression::new(8);
        let x = test_vec(8, 18);
        let mut rng = Pcg64::new(19);
        let out = c.compress(&mut rng, &x).decode();
        for &v in &out {
            if v != 0.0 {
                let l = v.abs().log2();
                assert!((l - l.round()).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bernoulli_unbiased_with_omega() {
        let d = 15;
        let p = 0.25;
        let c = BernoulliP::new(d, p);
        assert!((c.omega().unwrap() - 3.0).abs() < 1e-12);
        let x = test_vec(d, 20);
        let mut rng = Pcg64::new(21);
        assert!(empirical_bias_ratio(&c, &mut rng, &x, 40_000) < 0.03);
        // Exact variance of Bernoulli: (1/p − 1)·‖x‖² exactly at every x.
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 40_000);
        assert!((ratio - 3.0).abs() < 0.15, "ratio {ratio}");
    }

    #[test]
    fn ternary_unbiased_and_bounded() {
        let d = 36;
        let c = Ternary::new(d);
        let x = test_vec(d, 22);
        let mut rng = Pcg64::new(23);
        assert!(empirical_bias_ratio(&c, &mut rng, &x, 30_000) < 0.02);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 5_000);
        assert!(ratio <= c.omega().unwrap() * 1.1 + 0.05, "ratio {ratio}");
        // outputs are in {−s, 0, s}
        let out = c.compress(&mut rng, &x).decode();
        let s = crate::linalg::nrm_inf(&x);
        for &v in &out {
            assert!(v == 0.0 || (v.abs() - s).abs() < 1e-12);
        }
    }

    #[test]
    fn std_dithering_unbiased() {
        let d = 20;
        let c = StandardDithering::new(d, 4);
        let x = test_vec(d, 24);
        let mut rng = Pcg64::new(25);
        assert!(empirical_bias_ratio(&c, &mut rng, &x, 30_000) < 0.02);
        let ratio = empirical_variance_ratio(&c, &mut rng, &x, 5_000);
        assert!(ratio <= c.omega().unwrap() * 1.15 + 0.02, "ratio {ratio}");
    }
}
