//! Byte-level wire format for compressed messages.
//!
//! The coordinator serializes every [`Packet`] before handing it to the
//! simulated network, so the "communicated bits" axis of the figures is the
//! size of a *real decodable encoding*, not a formula. The format is
//! self-describing and bit-packed:
//!
//! ```text
//! header: 1 byte tag | 1 byte prec | 4 bytes dim (LE)
//! body:   tag-specific, bit-packed (signs: 1 bit, indices: ⌈log₂ d⌉ bits,
//!         levels: ⌈log₂(s+1)⌉ bits, values: f32/f64)
//! ```
//!
//! `Packet::payload_bits` counts only the body (the interesting,
//! per-coordinate cost); `encode` adds the 6-byte header, reported
//! separately by [`HEADER_BITS`].

use crate::compressors::packet::{bits_for_levels, index_bits, Packet, ValPrec};

pub const HEADER_BITS: u64 = 48;

#[derive(Debug)]
pub enum WireError {
    Truncated { needed: usize, have: usize },
    BadTag(u8),
    BadPrec(u8),
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated message: needed {needed} bytes, had {have}")
            }
            WireError::BadTag(t) => write!(f, "unknown packet tag {t}"),
            WireError::BadPrec(p) => write!(f, "unknown precision tag {p}"),
            WireError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_LEVELS: u8 = 3;
const TAG_LEVELS_LINEAR: u8 = 4;
const TAG_NATEXP: u8 = 5;
const TAG_SIGNSCALE: u8 = 6;
const TAG_TERNARY: u8 = 7;
const TAG_ZERO: u8 = 8;

// --------------------------------------------------------------- bit writer

/// Bit-packer over a borrowed, caller-recycled byte buffer (the
/// zero-allocation round pipeline reuses frame buffers across rounds; after
/// warm-up the buffer capacity is stable and writes never allocate).
struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    /// number of valid bits in the last byte (0 ⇒ byte-aligned)
    bit_pos: u8,
}

impl<'a> BitWriter<'a> {
    fn new(buf: &'a mut Vec<u8>) -> Self {
        buf.clear();
        Self { buf, bit_pos: 0 }
    }

    fn write_bits(&mut self, value: u64, nbits: u64) {
        debug_assert!(nbits <= 64);
        for i in 0..nbits {
            let bit = (value >> i) & 1;
            if self.bit_pos == 0 {
                self.buf.push(0);
            }
            let last = self.buf.len() - 1;
            self.buf[last] |= (bit as u8) << self.bit_pos;
            self.bit_pos = (self.bit_pos + 1) % 8;
        }
    }

    fn align(&mut self) {
        self.bit_pos = 0;
    }

    fn write_u8(&mut self, v: u8) {
        self.align();
        self.buf.push(v);
    }

    fn write_u32(&mut self, v: u32) {
        self.align();
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn write_val(&mut self, v: f64, prec: ValPrec) {
        self.align();
        match prec {
            ValPrec::F32 => self.buf.extend_from_slice(&(v as f32).to_le_bytes()),
            ValPrec::F64 => self.buf.extend_from_slice(&v.to_le_bytes()),
        }
    }
}

// --------------------------------------------------------------- bit reader

struct BitReader<'a> {
    buf: &'a [u8],
    byte_pos: usize,
    bit_pos: u8,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self {
            buf,
            byte_pos: 0,
            bit_pos: 0,
        }
    }

    fn read_bits(&mut self, nbits: u64) -> Result<u64, WireError> {
        let mut out = 0u64;
        for i in 0..nbits {
            if self.byte_pos >= self.buf.len() {
                return Err(WireError::Truncated {
                    needed: self.byte_pos + 1,
                    have: self.buf.len(),
                });
            }
            let bit = (self.buf[self.byte_pos] >> self.bit_pos) & 1;
            out |= (bit as u64) << i;
            self.bit_pos += 1;
            if self.bit_pos == 8 {
                self.bit_pos = 0;
                self.byte_pos += 1;
            }
        }
        Ok(out)
    }

    fn align(&mut self) {
        if self.bit_pos != 0 {
            self.bit_pos = 0;
            self.byte_pos += 1;
        }
    }

    fn read_u8(&mut self) -> Result<u8, WireError> {
        self.align();
        let b = *self
            .buf
            .get(self.byte_pos)
            .ok_or(WireError::Truncated {
                needed: self.byte_pos + 1,
                have: self.buf.len(),
            })?;
        self.byte_pos += 1;
        Ok(b)
    }

    fn read_u32(&mut self) -> Result<u32, WireError> {
        self.align();
        if self.byte_pos + 4 > self.buf.len() {
            return Err(WireError::Truncated {
                needed: self.byte_pos + 4,
                have: self.buf.len(),
            });
        }
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 4]);
        self.byte_pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    fn read_val(&mut self, prec: ValPrec) -> Result<f64, WireError> {
        self.align();
        match prec {
            ValPrec::F32 => {
                if self.byte_pos + 4 > self.buf.len() {
                    return Err(WireError::Truncated {
                        needed: self.byte_pos + 4,
                        have: self.buf.len(),
                    });
                }
                let mut b = [0u8; 4];
                b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 4]);
                self.byte_pos += 4;
                Ok(f32::from_le_bytes(b) as f64)
            }
            ValPrec::F64 => {
                if self.byte_pos + 8 > self.buf.len() {
                    return Err(WireError::Truncated {
                        needed: self.byte_pos + 8,
                        have: self.buf.len(),
                    });
                }
                let mut b = [0u8; 8];
                b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 8]);
                self.byte_pos += 8;
                Ok(f64::from_le_bytes(b))
            }
        }
    }
}

fn write_signs(w: &mut BitWriter, signs: &[bool]) {
    for &s in signs {
        w.write_bits(s as u64, 1);
    }
}

fn read_signs_into(r: &mut BitReader, n: usize, out: &mut Vec<bool>) -> Result<(), WireError> {
    out.clear();
    out.reserve(n);
    for _ in 0..n {
        out.push(r.read_bits(1)? == 1);
    }
    Ok(())
}

// ------------------------------------------------------------------- encode

/// Serialize a packet. Values are rounded to `prec` (f32 loses precision —
/// the default experiment precision is F64, matching the paper's float64
/// simulations).
pub fn encode(pkt: &Packet, prec: ValPrec) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_into(pkt, prec, &mut buf);
    buf
}

/// Like [`encode`] but writes into a caller-recycled buffer (cleared
/// first). Byte-for-byte identical output; after warm-up, no allocation.
pub fn encode_into(pkt: &Packet, prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::new(out);
    let prec_tag = match prec {
        ValPrec::F32 => 0u8,
        ValPrec::F64 => 1u8,
    };
    match pkt {
        Packet::Dense(v) => {
            w.write_u8(TAG_DENSE);
            w.write_u8(prec_tag);
            w.write_u32(v.len() as u32);
            for &x in v {
                w.write_val(x, prec);
            }
        }
        Packet::Sparse {
            dim,
            indices,
            values,
            scale,
        } => {
            w.write_u8(TAG_SPARSE);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            w.write_u32(indices.len() as u32);
            w.write_val(*scale, prec);
            let ib = index_bits(*dim);
            for &i in indices {
                w.write_bits(i as u64, ib);
            }
            w.align();
            for &v in values {
                w.write_val(v, prec);
            }
        }
        Packet::Levels {
            dim,
            norm,
            s,
            signs,
            levels,
        } => {
            w.write_u8(TAG_LEVELS);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            w.write_u8(*s);
            w.write_val(*norm, prec);
            write_signs(&mut w, signs);
            w.align();
            let lb = bits_for_levels(*s);
            for &l in levels {
                w.write_bits(l as u64, lb);
            }
        }
        Packet::LevelsLinear {
            dim,
            norm,
            s,
            signs,
            levels,
        } => {
            w.write_u8(TAG_LEVELS_LINEAR);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            w.write_u32(*s);
            w.write_val(*norm, prec);
            write_signs(&mut w, signs);
            w.align();
            let n = s + 1;
            let lb = if n <= 1 {
                1
            } else {
                (32 - (n - 1).leading_zeros()) as u64
            };
            for &l in levels {
                w.write_bits(l as u64, lb);
            }
        }
        Packet::NatExp { dim, signs, exps } => {
            w.write_u8(TAG_NATEXP);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            write_signs(&mut w, signs);
            w.align();
            for &e in exps {
                w.write_bits(e as u8 as u64, 8);
            }
        }
        Packet::SignScale { dim, scale, signs } => {
            w.write_u8(TAG_SIGNSCALE);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            w.write_val(*scale, prec);
            write_signs(&mut w, signs);
        }
        Packet::TernaryPkt {
            dim,
            scale,
            mask,
            signs,
        } => {
            w.write_u8(TAG_TERNARY);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
            w.write_val(*scale, prec);
            write_signs(&mut w, mask);
            w.align();
            w.write_u32(signs.len() as u32);
            write_signs(&mut w, signs);
        }
        Packet::Zero { dim } => {
            w.write_u8(TAG_ZERO);
            w.write_u8(prec_tag);
            w.write_u32(*dim);
        }
    }
}

/// Write a [`Packet::Dense`] frame directly from a slice — byte-identical
/// to `encode_into(&Packet::Dense(values.to_vec()), ..)` without building
/// the packet. Used by the Rand-DIANA shift-refresh path so the (dense,
/// rare) refresh upload does not clone the shift vector.
pub fn encode_dense_into(values: &[f64], prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::new(out);
    let prec_tag = match prec {
        ValPrec::F32 => 0u8,
        ValPrec::F64 => 1u8,
    };
    w.write_u8(TAG_DENSE);
    w.write_u8(prec_tag);
    w.write_u32(values.len() as u32);
    for &x in values {
        w.write_val(x, prec);
    }
}

// ------------------------------------------------------------------- decode

/// Deserialize a packet previously produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Packet, WireError> {
    let mut pkt = Packet::Zero { dim: 0 };
    decode_into(bytes, &mut pkt)?;
    Ok(pkt)
}

/// Deserialize into a caller-recycled [`Packet`], reusing its vectors when
/// `out` already holds the frame's variant (the steady-state case: a master
/// decoding the same worker/compressor shape every round never allocates
/// after warm-up). Produces exactly what [`decode`] produces. On `Err`,
/// `out` is left in a valid but unspecified state.
pub fn decode_into(bytes: &[u8], out: &mut Packet) -> Result<(), WireError> {
    let mut r = BitReader::new(bytes);
    let tag = r.read_u8()?;
    let prec = match r.read_u8()? {
        0 => ValPrec::F32,
        1 => ValPrec::F64,
        p => return Err(WireError::BadPrec(p)),
    };
    let dim = r.read_u32()?;
    match tag {
        TAG_DENSE => {
            if !matches!(out, Packet::Dense(_)) {
                *out = Packet::Dense(Vec::new());
            }
            let Packet::Dense(v) = out else { unreachable!() };
            v.clear();
            v.reserve(dim as usize);
            for _ in 0..dim {
                v.push(r.read_val(prec)?);
            }
            Ok(())
        }
        TAG_SPARSE => {
            let k = r.read_u32()?;
            if k > dim {
                return Err(WireError::Malformed(format!("k={k} > dim={dim}")));
            }
            let scale_v = r.read_val(prec)?;
            if !matches!(out, Packet::Sparse { .. }) {
                *out = Packet::Sparse {
                    dim: 0,
                    indices: Vec::new(),
                    values: Vec::new(),
                    scale: 0.0,
                };
            }
            let Packet::Sparse {
                dim: out_dim,
                indices,
                values,
                scale,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            *scale = scale_v;
            let ib = index_bits(dim);
            indices.clear();
            for _ in 0..k {
                let idx = r.read_bits(ib)? as u32;
                if idx >= dim {
                    return Err(WireError::Malformed(format!("index {idx} ≥ dim {dim}")));
                }
                indices.push(idx);
            }
            r.align();
            values.clear();
            for _ in 0..k {
                values.push(r.read_val(prec)?);
            }
            Ok(())
        }
        TAG_LEVELS => {
            let s_v = r.read_u8()?;
            let norm_v = r.read_val(prec)?;
            if !matches!(out, Packet::Levels { .. }) {
                *out = Packet::Levels {
                    dim: 0,
                    norm: 0.0,
                    s: 0,
                    signs: Vec::new(),
                    levels: Vec::new(),
                };
            }
            let Packet::Levels {
                dim: out_dim,
                norm,
                s,
                signs,
                levels,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            *norm = norm_v;
            *s = s_v;
            read_signs_into(&mut r, dim as usize, signs)?;
            r.align();
            let lb = bits_for_levels(s_v);
            levels.clear();
            for _ in 0..dim {
                let l = r.read_bits(lb)? as u8;
                if l > s_v {
                    return Err(WireError::Malformed(format!("level {l} > s {s_v}")));
                }
                levels.push(l);
            }
            Ok(())
        }
        TAG_LEVELS_LINEAR => {
            let s_v = r.read_u32()?;
            let norm_v = r.read_val(prec)?;
            if !matches!(out, Packet::LevelsLinear { .. }) {
                *out = Packet::LevelsLinear {
                    dim: 0,
                    norm: 0.0,
                    s: 0,
                    signs: Vec::new(),
                    levels: Vec::new(),
                };
            }
            let Packet::LevelsLinear {
                dim: out_dim,
                norm,
                s,
                signs,
                levels,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            *norm = norm_v;
            *s = s_v;
            read_signs_into(&mut r, dim as usize, signs)?;
            r.align();
            let n = s_v + 1;
            let lb = if n <= 1 {
                1
            } else {
                (32 - (n - 1).leading_zeros()) as u64
            };
            levels.clear();
            for _ in 0..dim {
                levels.push(r.read_bits(lb)? as u8);
            }
            Ok(())
        }
        TAG_NATEXP => {
            if !matches!(out, Packet::NatExp { .. }) {
                *out = Packet::NatExp {
                    dim: 0,
                    signs: Vec::new(),
                    exps: Vec::new(),
                };
            }
            let Packet::NatExp {
                dim: out_dim,
                signs,
                exps,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            read_signs_into(&mut r, dim as usize, signs)?;
            r.align();
            exps.clear();
            for _ in 0..dim {
                exps.push(r.read_bits(8)? as u8 as i8);
            }
            Ok(())
        }
        TAG_SIGNSCALE => {
            let scale_v = r.read_val(prec)?;
            if !matches!(out, Packet::SignScale { .. }) {
                *out = Packet::SignScale {
                    dim: 0,
                    scale: 0.0,
                    signs: Vec::new(),
                };
            }
            let Packet::SignScale {
                dim: out_dim,
                scale,
                signs,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            *scale = scale_v;
            read_signs_into(&mut r, dim as usize, signs)?;
            Ok(())
        }
        TAG_TERNARY => {
            let scale_v = r.read_val(prec)?;
            if !matches!(out, Packet::TernaryPkt { .. }) {
                *out = Packet::TernaryPkt {
                    dim: 0,
                    scale: 0.0,
                    mask: Vec::new(),
                    signs: Vec::new(),
                };
            }
            let Packet::TernaryPkt {
                dim: out_dim,
                scale,
                mask,
                signs,
            } = out
            else {
                unreachable!()
            };
            *out_dim = dim;
            *scale = scale_v;
            read_signs_into(&mut r, dim as usize, mask)?;
            r.align();
            let nnz = r.read_u32()? as usize;
            if nnz != mask.iter().filter(|&&b| b).count() {
                return Err(WireError::Malformed("ternary nnz mismatch".into()));
            }
            read_signs_into(&mut r, nnz, signs)?;
            Ok(())
        }
        TAG_ZERO => {
            *out = Packet::Zero { dim };
            Ok(())
        }
        t => Err(WireError::BadTag(t)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(pkt: Packet) {
        for prec in [ValPrec::F64, ValPrec::F32] {
            let bytes = encode(&pkt, prec);
            let back = decode(&bytes).unwrap();
            match prec {
                ValPrec::F64 => assert_eq!(back, pkt, "f64 roundtrip"),
                ValPrec::F32 => {
                    // values rounded to f32; structure must match
                    assert_eq!(back.dim(), pkt.dim());
                    let a = back.decode();
                    let b = pkt.decode();
                    for (x, y) in a.iter().zip(b.iter()) {
                        let tol = 1e-6 * y.abs().max(1.0);
                        assert!((x - y).abs() <= tol, "{x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn roundtrips_all_variants() {
        roundtrip(Packet::Dense(vec![1.5, -2.25, 0.0, 1e-3]));
        roundtrip(Packet::Sparse {
            dim: 80,
            indices: vec![0, 7, 79],
            values: vec![1.0, -0.5, 3.25],
            scale: 10.0,
        });
        roundtrip(Packet::Levels {
            dim: 5,
            norm: 4.5,
            s: 3,
            signs: vec![true, false, true, true, false],
            levels: vec![0, 1, 2, 3, 1],
        });
        roundtrip(Packet::LevelsLinear {
            dim: 4,
            norm: 2.0,
            s: 7,
            signs: vec![true, true, false, false],
            levels: vec![7, 0, 3, 5],
        });
        roundtrip(Packet::NatExp {
            dim: 3,
            signs: vec![true, false, true],
            exps: vec![5, -3, i8::MIN],
        });
        roundtrip(Packet::SignScale {
            dim: 9,
            scale: 0.125,
            signs: vec![true; 9],
        });
        roundtrip(Packet::TernaryPkt {
            dim: 6,
            scale: 1.0,
            mask: vec![true, false, true, false, false, true],
            signs: vec![true, false, true],
        });
        roundtrip(Packet::Zero { dim: 100 });
    }

    #[test]
    fn encoded_size_close_to_payload_bits() {
        // The byte size must be within header + alignment slack of the
        // theoretical payload bits.
        let pkts = vec![
            Packet::Sparse {
                dim: 80,
                indices: (0..8).collect(),
                values: vec![1.0; 8],
                scale: 10.0,
            },
            Packet::Levels {
                dim: 80,
                norm: 1.0,
                s: 7,
                signs: vec![true; 80],
                levels: vec![3; 80],
            },
            Packet::NatExp {
                dim: 80,
                signs: vec![false; 80],
                exps: vec![0; 80],
            },
        ];
        for pkt in pkts {
            let bits = pkt.payload_bits(ValPrec::F64);
            let bytes = encode(&pkt, ValPrec::F64).len() as u64 * 8;
            assert!(bytes >= bits, "encoding can't beat its own accounting");
            // slack: header + ≤4 alignment paddings of ≤7 bits + length field
            assert!(
                bytes <= bits + HEADER_BITS + 64,
                "too much overhead: {bytes} vs {bits}"
            );
        }
    }

    #[test]
    fn encode_into_and_decode_into_reuse_buffers() {
        let pkts = vec![
            Packet::Dense(vec![1.5, -2.25, 0.0]),
            Packet::Sparse {
                dim: 80,
                indices: vec![0, 7, 79],
                values: vec![1.0, -0.5, 3.25],
                scale: 10.0,
            },
            Packet::Levels {
                dim: 5,
                norm: 4.5,
                s: 3,
                signs: vec![true, false, true, true, false],
                levels: vec![0, 1, 2, 3, 1],
            },
            Packet::TernaryPkt {
                dim: 6,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true],
                signs: vec![true, false, true],
            },
            Packet::Zero { dim: 100 },
        ];
        // deliberately dirty scratch: reused across mismatched variants
        let mut buf = vec![0xAAu8; 64];
        let mut scratch = Packet::SignScale {
            dim: 3,
            scale: 9.0,
            signs: vec![true; 3],
        };
        for pkt in &pkts {
            let fresh = encode(pkt, ValPrec::F64);
            encode_into(pkt, ValPrec::F64, &mut buf);
            assert_eq!(fresh, buf, "encode_into must be byte-identical");
            decode_into(&buf, &mut scratch).unwrap();
            assert_eq!(&scratch, pkt, "decode_into must reproduce decode");
            // second pass now hits the matched-variant reuse path
            decode_into(&buf, &mut scratch).unwrap();
            assert_eq!(&scratch, pkt);
        }
    }

    #[test]
    fn encode_dense_into_matches_dense_packet() {
        let v = vec![0.5, -1.25, 3.0, 1e-9];
        for prec in [ValPrec::F64, ValPrec::F32] {
            let via_packet = encode(&Packet::Dense(v.clone()), prec);
            let mut direct = vec![7u8; 3];
            encode_dense_into(&v, prec, &mut direct);
            assert_eq!(via_packet, direct);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99, 1, 0, 0, 0, 0]).is_err());
        // truncated dense
        let bytes = encode(&Packet::Dense(vec![1.0, 2.0]), ValPrec::F64);
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        // sparse with k > dim
        let bad = encode(
            &Packet::Sparse {
                dim: 2,
                indices: vec![0, 1, 1],
                values: vec![1.0; 3],
                scale: 1.0,
            },
            ValPrec::F64,
        );
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn bitpacking_is_compact() {
        // 80 indices at 7 bits each = 70 bytes vs 320 for u32s.
        let pkt = Packet::Sparse {
            dim: 80,
            indices: (0..80).collect(),
            values: vec![0.0; 80],
            scale: 1.0,
        };
        let bytes = encode(&pkt, ValPrec::F32);
        // header 6 + k(4) + scale(4) + ceil(80*7/8)=70 + values 320
        assert!(bytes.len() <= 6 + 4 + 4 + 70 + 320 + 2, "len {}", bytes.len());
    }
}
