//! Byte-level wire format for compressed messages — uplink packet frames
//! and the downlink broadcast frames.
//!
//! The coordinator serializes every [`Packet`] before handing it to the
//! simulated network, so the "communicated bits" axis of the figures is the
//! size of a *real decodable encoding*, not a formula.
//!
//! # Uplink packet frames
//!
//! ```text
//! header: 1 byte tag | 1 byte prec | 4 bytes dim (LE)
//! body:   tag-specific, bit-packed (signs: 1 bit, indices: ⌈log₂ d⌉ bits,
//!         levels: ⌈log₂(s+1)⌉ bits, values: f32/f64)
//! ```
//!
//! `Packet::payload_bits` counts only the body (the interesting,
//! per-coordinate cost); `encode` adds the 6-byte header, reported
//! separately by [`HEADER_BITS`]. [`encoded_len`] gives the exact byte size
//! of a frame without materializing it.
//!
//! The uplink frame format is payload-agnostic: with the error-fed-back
//! uplink armed (`cluster.uplink`, [`crate::ef::EfUplink`]) the Q-frame
//! carries `C_i(e_i + m_i)` — the worker's accumulator-fed compression —
//! instead of `Q_i(m_i)`, re-packed through [`build_update_packet`] into
//! the ordinary Sparse/Dense packet frames below. No new tag is needed;
//! the master folds whatever packet arrives.
//!
//! # Batched uplink frames (local steps)
//!
//! With `local_steps = τ > 1` a worker performs τ local shifted
//! sub-steps per communication round and ships all τ compressed
//! gradient-difference packets in **one** `Batch` frame — one round trip
//! of latency instead of τ:
//!
//! ```text
//! batch frame: 1 byte tag (9) | 2 bytes count τ (LE) | τ packet frames
//! ```
//!
//! Each body is an ordinary packet frame (header + bit-packed body as
//! above, byte-aligned), appended in sub-step order with
//! [`append_batch_packet`] and decoded incrementally with
//! [`decode_batch_packet`] so the master can replay the τ sub-step folds
//! with one recycled scratch packet per worker. A `count` of 0 is
//! malformed; τ = 1 runs ship plain packet frames (tags 1–8), keeping the
//! wire bytes of the per-round protocol unchanged.
//!
//! # Frame kinds at a glance
//!
//! One row per frame byte; `shiftcomp-lint` (rule `wire-tags`) checks that
//! every `TAG_*`/`DOWN_*` constant below is unique in its namespace and
//! appears in this table as `tag N` / `kind N`. Every uplink packet frame
//! is one compressed message (a Q/C/refresh frame; the EF uplink ships
//! C(e + m) in the same encodings).
//!
//! | dir      | kind                     | first byte | body                          |
//! |----------|--------------------------|------------|-------------------------------|
//! | uplink   | `Dense` packet           | tag 1      | dense f32/f64 values          |
//! | uplink   | `Sparse` packet          | tag 2      | bit-packed indices + values   |
//! | uplink   | `Levels` packet          | tag 3      | norm + sign/level bit runs    |
//! | uplink   | `LevelsLinear` packet    | tag 4      | norm + sign/level bit runs    |
//! | uplink   | `NatExp` packet          | tag 5      | sign + exponent bit runs      |
//! | uplink   | `SignScale` packet       | tag 6      | scale + sign bit run          |
//! | uplink   | `Ternary` packet         | tag 7      | scale + 2-bit trit run        |
//! | uplink   | `Zero` packet            | tag 8      | empty (all-zero message)      |
//! | uplink   | `Batch`                  | tag 9      | count (u16) + τ packet frames |
//! | downlink | [`DownKind::Delta`]      | kind 1     | exact delta packet frame      |
//! | downlink | [`DownKind::Resync`]     | kind 2     | dense f64 full iterate        |
//! | downlink | [`DownKind::EfDelta`]    | kind 3     | lossy EF update C(e + Δ)      |
//!
//! # Downlink (broadcast) frames
//!
//! The master never ships the dense iterate: it broadcasts one frame per
//! round, shared by every worker, that is a **delta**, an error-fed-back
//! **EF delta**, or a **resync**:
//!
//! ```text
//! downlink frame: 1 byte kind | packet frame (header + body as above)
//!   kind = 1 (Delta):   packet decodes to x^{k+1} − x^k = −γ·g^k; workers
//!                       apply it to their local replica with
//!                       `add_scaled_into(1.0, &mut x)`. Sparse when the
//!                       aggregate is sparse (exact bit accounting picks the
//!                       cheaper of Sparse/Dense — see [`build_update_packet`]).
//!   kind = 3 (EfDelta): a *lossy* replica update C(e^k + (x^{k+1} − x^k))
//!                       produced by the master's error-fed-back downlink
//!                       compressor (see [`crate::downlink::EfDownlink`]).
//!                       Workers apply it exactly like a Delta; the part the
//!                       compressor dropped stays in the master's error
//!                       accumulator e and is retried next round, so the
//!                       EF invariant  x_replica + e = x_master  holds (to
//!                       fp rounding; bit-exactly right after a resync).
//!                       Keeps the broadcast O(nnz) even when DIANA-family
//!                       shifts densify the exact delta.
//!   kind = 2 (Resync):  a Dense packet of the full iterate; workers
//!                       overwrite their replica. Sent on round 0 (replica
//!                       bootstrap for joiners), every `resync_every` rounds
//!                       (round 0 itself is skipped — the bootstrap resync
//!                       already covers it), and after out-of-band iterate
//!                       changes (`set_x0`). Resync frames are always f64 —
//!                       they re-establish bit-exact replica state
//!                       regardless of the delta precision — and flush the
//!                       EF error accumulator to zero.
//! ```
//!
//! Delta application is exact f64 arithmetic: the packet carries the
//! estimator values with scale −γ, so every touched coordinate computes
//! `x[j] += (−γ)·g[j]` with the same two roundings as the dense
//! `axpy(−γ, g, x)` reference — trajectories are bit-identical to a dense
//! broadcast (pinned by `tests/coordinator.rs` and `tests/properties.rs`).
//! Under f32 wire precision the values are pre-quantized so the encode →
//! decode round-trip is lossless and master and replicas still agree bit
//! for bit.
//!
//! # Alignment rules
//!
//! Bit-packed runs (signs, indices, levels) are written LSB-first within
//! each byte by a word-at-a-time packer ([`BitWriter::write_bits`] /
//! [`BitReader::read_bits`] move up to 64 bits per shift/mask operation —
//! no per-bit loop). Multi-byte scalars (u32 lengths, f32/f64 values)
//! always start on a byte boundary: writers pad the current byte with zero
//! bits (`align`), readers skip to the next boundary.

use crate::compressors::packet::{bits_for_levels, index_bits, Packet, ValPrec};

pub const HEADER_BITS: u64 = 48;

#[derive(Debug)]
pub enum WireError {
    Truncated { needed: usize, have: usize },
    BadTag(u8),
    BadPrec(u8),
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated message: needed {needed} bytes, had {have}")
            }
            WireError::BadTag(t) => write!(f, "unknown packet tag {t}"),
            WireError::BadPrec(p) => write!(f, "unknown precision tag {p}"),
            WireError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_LEVELS: u8 = 3;
const TAG_LEVELS_LINEAR: u8 = 4;
const TAG_NATEXP: u8 = 5;
const TAG_SIGNSCALE: u8 = 6;
const TAG_TERNARY: u8 = 7;
const TAG_ZERO: u8 = 8;
const TAG_BATCH: u8 = 9;

const DOWN_DELTA: u8 = 1;
const DOWN_RESYNC: u8 = 2;
const DOWN_EF_DELTA: u8 = 3;

/// What a downlink broadcast frame carries (see the module doc).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DownKind {
    /// Iterate delta x^{k+1} − x^k, applied to the replica in place.
    Delta,
    /// Full dense iterate, overwriting the replica.
    Resync,
    /// Error-fed-back compressed replica update C(e + Δ), applied to the
    /// replica exactly like a [`Delta`](DownKind::Delta); the residual
    /// stays in the master's error accumulator.
    EfDelta,
}

/// Low `n` bits set (`n ≤ 64`).
#[inline]
fn mask(n: u64) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

// --------------------------------------------------------------- bit writer

/// Bit-packer over a borrowed, caller-recycled byte buffer (the
/// zero-allocation round pipeline reuses frame buffers across rounds; after
/// warm-up the buffer capacity is stable and writes never allocate).
struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    /// number of valid bits in the last byte (0 ⇒ byte-aligned)
    bit_pos: u8,
}

impl<'a> BitWriter<'a> {
    fn new(buf: &'a mut Vec<u8>) -> Self {
        buf.clear();
        Self { buf, bit_pos: 0 }
    }

    /// Like [`new`](Self::new) but appends to the buffer's current content
    /// instead of clearing it — batched frames concatenate packet frames,
    /// and every packet frame begins and ends on a byte boundary, so
    /// appending is byte-identical to one continuous aligned writer.
    fn append(buf: &'a mut Vec<u8>) -> Self {
        Self { buf, bit_pos: 0 }
    }

    /// Append the low `nbits` of `value`, LSB-first. Word-at-a-time: the
    /// partial tail byte is topped up with one shift/mask, then all whole
    /// bytes land in a single `extend_from_slice` of the value's
    /// little-endian bytes (a memcpy the optimizer can keep in registers) —
    /// no per-bit or per-byte loop. The stream is byte-identical to the
    /// byte-at-a-time formulation.
    fn write_bits(&mut self, value: u64, nbits: u64) {
        debug_assert!(nbits <= 64);
        let mut v = value & mask(nbits);
        let mut left = nbits;
        if self.bit_pos != 0 {
            let free = (8 - self.bit_pos) as u64;
            let take = left.min(free);
            let last = self.buf.len() - 1;
            self.buf[last] |= ((v & mask(take)) as u8) << self.bit_pos;
            self.bit_pos = ((self.bit_pos as u64 + take) % 8) as u8;
            v >>= take;
            left -= take;
        }
        let nbytes = (left / 8) as usize;
        if nbytes > 0 {
            self.buf.extend_from_slice(&v.to_le_bytes()[..nbytes]);
            left -= nbytes as u64 * 8;
        }
        if left > 0 {
            // left > 0 here forces nbytes ≤ 7, so the shift is < 64
            v >>= nbytes * 8;
            self.buf.push(v as u8);
            self.bit_pos = left as u8;
        }
    }

    fn align(&mut self) {
        self.bit_pos = 0;
    }

    fn write_u8(&mut self, v: u8) {
        self.align();
        self.buf.push(v);
    }

    fn write_u32(&mut self, v: u32) {
        self.align();
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn write_val(&mut self, v: f64, prec: ValPrec) {
        self.align();
        match prec {
            ValPrec::F32 => self.buf.extend_from_slice(&(v as f32).to_le_bytes()),
            ValPrec::F64 => self.buf.extend_from_slice(&v.to_le_bytes()),
        }
    }
}

// --------------------------------------------------------------- bit reader

struct BitReader<'a> {
    buf: &'a [u8],
    byte_pos: usize,
    bit_pos: u8,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self {
            buf,
            byte_pos: 0,
            bit_pos: 0,
        }
    }

    /// Bits left to read from the current position.
    fn avail_bits(&self) -> u64 {
        (self.buf.len() - self.byte_pos) as u64 * 8 - self.bit_pos as u64
    }

    /// Read `nbits` LSB-first. Mirrors [`BitWriter::write_bits`]: one
    /// shift/mask for the partial head byte, then all whole bytes in a
    /// single little-endian word load (a bounded memcpy into a stack word)
    /// instead of a per-byte loop.
    fn read_bits(&mut self, nbits: u64) -> Result<u64, WireError> {
        debug_assert!(nbits <= 64);
        let avail = self.avail_bits();
        if nbits > avail {
            return Err(WireError::Truncated {
                needed: self.byte_pos + ((self.bit_pos as u64 + nbits + 7) / 8) as usize,
                have: self.buf.len(),
            });
        }
        let mut out = 0u64;
        let mut got = 0u64;
        if self.bit_pos != 0 {
            let free = (8 - self.bit_pos) as u64;
            let take = nbits.min(free);
            out = ((self.buf[self.byte_pos] >> self.bit_pos) as u64) & mask(take);
            got = take;
            self.bit_pos = ((self.bit_pos as u64 + take) % 8) as u8;
            if self.bit_pos == 0 {
                self.byte_pos += 1;
            }
        }
        let nbytes = ((nbits - got) / 8) as usize;
        if nbytes > 0 {
            let mut word = [0u8; 8];
            word[..nbytes].copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + nbytes]);
            // got > 0 forces nbytes ≤ 7, so the shifted value fits in u64
            out |= u64::from_le_bytes(word) << got;
            self.byte_pos += nbytes;
            got += nbytes as u64 * 8;
        }
        let rem = nbits - got;
        if rem > 0 {
            out |= ((self.buf[self.byte_pos] as u64) & mask(rem)) << got;
            self.bit_pos = rem as u8;
        }
        Ok(out)
    }

    fn align(&mut self) {
        if self.bit_pos != 0 {
            self.bit_pos = 0;
            self.byte_pos += 1;
        }
    }

    fn read_u8(&mut self) -> Result<u8, WireError> {
        self.align();
        let b = *self
            .buf
            .get(self.byte_pos)
            .ok_or(WireError::Truncated {
                needed: self.byte_pos + 1,
                have: self.buf.len(),
            })?;
        self.byte_pos += 1;
        Ok(b)
    }

    fn read_u32(&mut self) -> Result<u32, WireError> {
        self.align();
        if self.byte_pos + 4 > self.buf.len() {
            return Err(WireError::Truncated {
                needed: self.byte_pos + 4,
                have: self.buf.len(),
            });
        }
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 4]);
        self.byte_pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    fn read_val(&mut self, prec: ValPrec) -> Result<f64, WireError> {
        self.align();
        match prec {
            ValPrec::F32 => {
                if self.byte_pos + 4 > self.buf.len() {
                    return Err(WireError::Truncated {
                        needed: self.byte_pos + 4,
                        have: self.buf.len(),
                    });
                }
                let mut b = [0u8; 4];
                b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 4]);
                self.byte_pos += 4;
                Ok(f32::from_le_bytes(b) as f64)
            }
            ValPrec::F64 => {
                if self.byte_pos + 8 > self.buf.len() {
                    return Err(WireError::Truncated {
                        needed: self.byte_pos + 8,
                        have: self.buf.len(),
                    });
                }
                let mut b = [0u8; 8];
                b.copy_from_slice(&self.buf[self.byte_pos..self.byte_pos + 8]);
                self.byte_pos += 8;
                Ok(f64::from_le_bytes(b))
            }
        }
    }
}

/// Sign/mask runs go through the packer 64 bools per word (bit i of the
/// word is element i of the chunk — LSB-first, so the stream is
/// byte-identical to one `write_bits(…, 1)` call per element).
fn write_signs(w: &mut BitWriter, signs: &[bool]) {
    for chunk in signs.chunks(64) {
        let mut word = 0u64;
        for (i, &s) in chunk.iter().enumerate() {
            word |= (s as u64) << i;
        }
        w.write_bits(word, chunk.len() as u64);
    }
}

fn read_signs_into(r: &mut BitReader, n: usize, out: &mut Vec<bool>) -> Result<(), WireError> {
    // Bound the reservation by the actual input before trusting a
    // header-supplied count: a corrupted `dim` must produce `Truncated`,
    // not a multi-gigabyte allocation attempt.
    if n as u64 > r.avail_bits() {
        return Err(WireError::Truncated {
            needed: r.byte_pos + (n + 7) / 8,
            have: r.buf.len(),
        });
    }
    out.clear();
    out.reserve(n);
    let mut left = n;
    while left > 0 {
        let take = left.min(64);
        let word = r.read_bits(take as u64)?;
        for i in 0..take {
            out.push((word >> i) & 1 == 1);
        }
        left -= take;
    }
    Ok(())
}

// ------------------------------------------------------------------- encode

/// Serialize a packet. Values are rounded to `prec` (f32 loses precision —
/// the default experiment precision is F64, matching the paper's float64
/// simulations).
pub fn encode(pkt: &Packet, prec: ValPrec) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_into(pkt, prec, &mut buf);
    buf
}

/// Like [`encode`] but writes into a caller-recycled buffer (cleared
/// first). Byte-for-byte identical output; after warm-up, no allocation.
pub fn encode_into(pkt: &Packet, prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::new(out);
    encode_packet(pkt, prec, &mut w);
}

fn prec_tag(prec: ValPrec) -> u8 {
    match prec {
        ValPrec::F32 => 0u8,
        ValPrec::F64 => 1u8,
    }
}

/// Write one packet frame (header + body) through an open writer — shared
/// by the uplink ([`encode_into`]) and downlink ([`encode_down_into`])
/// paths.
fn encode_packet(pkt: &Packet, prec: ValPrec, w: &mut BitWriter) {
    match pkt {
        Packet::Dense(v) => encode_dense_body(v, prec, w),
        Packet::Sparse {
            dim,
            indices,
            values,
            scale,
        } => {
            w.write_u8(TAG_SPARSE);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            w.write_u32(indices.len() as u32);
            w.write_val(*scale, prec);
            let ib = index_bits(*dim);
            for &i in indices {
                w.write_bits(i as u64, ib);
            }
            w.align();
            for &v in values {
                w.write_val(v, prec);
            }
        }
        Packet::Levels {
            dim,
            norm,
            s,
            signs,
            levels,
        } => {
            w.write_u8(TAG_LEVELS);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            w.write_u8(*s);
            w.write_val(*norm, prec);
            write_signs(w, signs);
            w.align();
            let lb = bits_for_levels(*s);
            for &l in levels {
                w.write_bits(l as u64, lb);
            }
        }
        Packet::LevelsLinear {
            dim,
            norm,
            s,
            signs,
            levels,
        } => {
            w.write_u8(TAG_LEVELS_LINEAR);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            w.write_u32(*s);
            w.write_val(*norm, prec);
            write_signs(w, signs);
            w.align();
            let n = s + 1;
            let lb = if n <= 1 {
                1
            } else {
                (32 - (n - 1).leading_zeros()) as u64
            };
            for &l in levels {
                w.write_bits(l as u64, lb);
            }
        }
        Packet::NatExp { dim, signs, exps } => {
            w.write_u8(TAG_NATEXP);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            write_signs(w, signs);
            w.align();
            for &e in exps {
                w.write_bits(e as u8 as u64, 8);
            }
        }
        Packet::SignScale { dim, scale, signs } => {
            w.write_u8(TAG_SIGNSCALE);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            w.write_val(*scale, prec);
            write_signs(w, signs);
        }
        Packet::TernaryPkt {
            dim,
            scale,
            mask,
            signs,
        } => {
            w.write_u8(TAG_TERNARY);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
            w.write_val(*scale, prec);
            write_signs(w, mask);
            w.align();
            w.write_u32(signs.len() as u32);
            write_signs(w, signs);
        }
        Packet::Zero { dim } => {
            w.write_u8(TAG_ZERO);
            w.write_u8(prec_tag(prec));
            w.write_u32(*dim);
        }
    }
}

fn encode_dense_body(values: &[f64], prec: ValPrec, w: &mut BitWriter) {
    w.write_u8(TAG_DENSE);
    w.write_u8(prec_tag(prec));
    w.write_u32(values.len() as u32);
    for &x in values {
        w.write_val(x, prec);
    }
}

/// Exact encoded byte length of [`encode`]'s output for `pkt` (header
/// included; the downlink kind byte is *not* — see [`down_frame_bits`]).
/// Used for bit accounting without materializing a frame; pinned to
/// `encode(pkt, prec).len()` by unit tests.
pub fn encoded_len(pkt: &Packet, prec: ValPrec) -> usize {
    let vb = match prec {
        ValPrec::F32 => 4usize,
        ValPrec::F64 => 8,
    };
    let hdr = 6usize;
    match pkt {
        Packet::Dense(v) => hdr + v.len() * vb,
        Packet::Sparse { dim, indices, values, .. } => {
            let ib = index_bits(*dim) as usize;
            hdr + 4 + vb + (indices.len() * ib + 7) / 8 + values.len() * vb
        }
        Packet::Levels { dim, s, .. } => {
            let lb = bits_for_levels(*s) as usize;
            let d = *dim as usize;
            hdr + 1 + vb + (d + 7) / 8 + (d * lb + 7) / 8
        }
        Packet::LevelsLinear { dim, s, .. } => {
            let n = s + 1;
            let lb = if n <= 1 {
                1usize
            } else {
                (32 - (n - 1).leading_zeros()) as usize
            };
            let d = *dim as usize;
            hdr + 4 + vb + (d + 7) / 8 + (d * lb + 7) / 8
        }
        Packet::NatExp { dim, .. } => hdr + (*dim as usize + 7) / 8 + *dim as usize,
        Packet::SignScale { dim, .. } => hdr + vb + (*dim as usize + 7) / 8,
        Packet::TernaryPkt { dim, signs, .. } => {
            hdr + vb + (*dim as usize + 7) / 8 + 4 + (signs.len() + 7) / 8
        }
        Packet::Zero { .. } => hdr,
    }
}

// -------------------------------------------------------- downlink framing

/// Serialize a downlink frame: 1 kind byte, then the packet frame. The
/// broadcast is one buffer shared (via `Arc`) by every worker.
pub fn encode_down_into(kind: DownKind, pkt: &Packet, prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::new(out);
    w.write_u8(down_tag(kind));
    encode_packet(pkt, prec, &mut w);
}

/// Downlink resync frame straight from the iterate slice (no Dense packet
/// is built): 1 kind byte + a Dense frame. Byte-identical to
/// `encode_down_into(DownKind::Resync, &Packet::Dense(x.to_vec()), ..)`.
pub fn encode_down_dense(kind: DownKind, values: &[f64], prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::new(out);
    w.write_u8(down_tag(kind));
    encode_dense_body(values, prec, &mut w);
}

fn down_tag(kind: DownKind) -> u8 {
    match kind {
        DownKind::Delta => DOWN_DELTA,
        DownKind::Resync => DOWN_RESYNC,
        DownKind::EfDelta => DOWN_EF_DELTA,
    }
}

/// Deserialize a downlink frame into a caller-recycled packet, returning
/// what kind of frame it was. Same reuse semantics as [`decode_into`].
pub fn decode_down_into(bytes: &[u8], out: &mut Packet) -> Result<DownKind, WireError> {
    let mut r = BitReader::new(bytes);
    let kind = match r.read_u8()? {
        DOWN_DELTA => DownKind::Delta,
        DOWN_RESYNC => DownKind::Resync,
        DOWN_EF_DELTA => DownKind::EfDelta,
        t => return Err(WireError::BadTag(t)),
    };
    decode_packet(&mut r, out)?;
    Ok(kind)
}

/// Size in bits of the downlink frame that would carry `pkt` (kind byte +
/// header + body) — the measured per-worker broadcast cost.
pub fn down_frame_bits(pkt: &Packet, prec: ValPrec) -> u64 {
    8 + encoded_len(pkt, prec) as u64 * 8
}

/// Size in bits of a dense resync frame for a `d`-dimensional iterate
/// (kind byte + header + d f64 values — resync frames are always f64).
/// Equals what [`encode_down_dense`] emits; the single-process driver uses
/// it to mirror the coordinator's round-0 bootstrap accounting.
pub fn resync_frame_bits(d: usize) -> u64 {
    (7 + 8 * d as u64) * 8
}

// ------------------------------------------------- batched uplink framing

/// Byte size of a batched uplink frame's header ([`begin_batch_frame`]):
/// 1 tag byte + 2 count bytes.
pub const BATCH_HEADER_BYTES: usize = 3;

/// Start a batched uplink frame (the `Batch` kind of the module doc's
/// table): clears `out` and writes the 3-byte header
/// `tag | count (u16 LE)`. The body is `count` ordinary packet frames
/// appended with [`append_batch_packet`], one per local sub-step, in
/// sub-step order.
pub fn begin_batch_frame(count: usize, out: &mut Vec<u8>) {
    assert!(
        (1..=u16::MAX as usize).contains(&count),
        "batch count {count} out of range"
    );
    out.clear();
    out.push(TAG_BATCH);
    out.extend_from_slice(&(count as u16).to_le_bytes());
}

/// Append one packet frame to a batched uplink frame begun with
/// [`begin_batch_frame`]. The appended bytes are identical to what
/// [`encode_into`] would produce for the same packet.
pub fn append_batch_packet(pkt: &Packet, prec: ValPrec, out: &mut Vec<u8>) {
    let mut w = BitWriter::append(out);
    encode_packet(pkt, prec, &mut w);
}

/// Validate a batched uplink frame's header, returning the sub-step count
/// and the byte offset of the first packet frame.
pub fn split_batch_frame(bytes: &[u8]) -> Result<(usize, usize), WireError> {
    if bytes.len() < BATCH_HEADER_BYTES {
        return Err(WireError::Truncated {
            needed: BATCH_HEADER_BYTES,
            have: bytes.len(),
        });
    }
    if bytes[0] != TAG_BATCH {
        return Err(WireError::BadTag(bytes[0]));
    }
    let count = u16::from_le_bytes([bytes[1], bytes[2]]) as usize;
    if count == 0 {
        return Err(WireError::Malformed("empty batch frame".into()));
    }
    Ok((count, BATCH_HEADER_BYTES))
}

/// Decode the packet frame starting at byte `offset` of a batched uplink
/// frame into a caller-recycled packet (same reuse semantics as
/// [`decode_into`]); returns the offset of the next packet frame. The
/// caller walks the frame by feeding each returned offset back in,
/// [`split_batch_frame`]'s count times.
pub fn decode_batch_packet(
    bytes: &[u8],
    offset: usize,
    out: &mut Packet,
) -> Result<usize, WireError> {
    let tail = bytes.get(offset..).ok_or(WireError::Truncated {
        needed: offset,
        have: bytes.len(),
    })?;
    let mut r = BitReader::new(tail);
    decode_packet(&mut r, out)?;
    r.align();
    Ok(offset + r.byte_pos)
}

// ------------------------------------------------- update (delta) building

/// Scratch for [`build_update_packet`]: both candidate representations
/// stay allocated so the sparse↔dense choice can flip between rounds
/// without touching the allocator.
pub struct DeltaScratch {
    sparse: Packet,
    dense: Packet,
    use_sparse: bool,
}

impl DeltaScratch {
    /// `cap` pre-sizes the buffers (pass the dimension on hot master paths
    /// so steady-state rounds never reallocate even while the aggregate's
    /// support is still growing; pass 0 where warm-up growth is fine).
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            sparse: Packet::Sparse {
                dim: 0,
                indices: Vec::with_capacity(cap),
                values: Vec::with_capacity(cap),
                scale: 1.0,
            },
            dense: Packet::Dense(Vec::with_capacity(cap)),
            use_sparse: true,
        }
    }

    /// The representation chosen by the last [`build_update_packet`] call.
    pub fn packet(&self) -> &Packet {
        if self.use_sparse {
            &self.sparse
        } else {
            &self.dense
        }
    }
}

/// Build a wire packet that decodes to `scale · v` on the nonzero support
/// of `v`, choosing the cheaper of the Sparse and Dense representations by
/// exact payload-bit accounting. This is the downlink delta builder
/// (`v = g^k`, `scale = −γ`) and the Rand-DIANA refresh-delta builder
/// (`v = ∇f_i − h_i`, `scale = 1`).
///
/// Values are pre-quantized to `prec`, so the encode → decode round-trip
/// is lossless and *both* ends of the link can apply the identical packet
/// (via `add_scaled_into(1.0, ..)`) — replicas stay bit-equal. At f64 every
/// touched coordinate receives exactly `scale · v[j]` with the same two
/// roundings as the dense `axpy(scale, v, out)` reference; coordinates
/// where `v[j] == 0.0` exactly are skipped by the Sparse representation
/// (invisible to `==`: the dense path would only normalize a `-0.0`).
pub fn build_update_packet<'a>(
    v: &[f64],
    scale: f64,
    prec: ValPrec,
    scratch: &'a mut DeltaScratch,
) -> &'a Packet {
    let d = v.len();
    let nnz = v.iter().filter(|&&x| x != 0.0).count();
    let vb = prec.bits();
    let ib = index_bits(d as u32);
    let sparse_bits = nnz as u64 * (ib + vb) + vb;
    let dense_bits = d as u64 * vb;
    scratch.use_sparse = sparse_bits < dense_bits;
    if scratch.use_sparse {
        let (dim, indices, values, pscale) = scratch.sparse.ensure_sparse();
        *dim = d as u32;
        *pscale = prec.quantize(scale);
        indices.clear();
        values.clear();
        for (j, &x) in v.iter().enumerate() {
            if x != 0.0 {
                indices.push(j as u32);
                values.push(prec.quantize(x));
            }
        }
        &scratch.sparse
    } else {
        let values = scratch.dense.ensure_dense();
        values.clear();
        values.extend(v.iter().map(|&x| prec.quantize(scale * x)));
        &scratch.dense
    }
}

// ------------------------------------------------------------------- decode

/// Deserialize a packet previously produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Packet, WireError> {
    let mut pkt = Packet::Zero { dim: 0 };
    decode_into(bytes, &mut pkt)?;
    Ok(pkt)
}

/// Deserialize into a caller-recycled [`Packet`], reusing its vectors when
/// `out` already holds the frame's variant (the steady-state case: a master
/// decoding the same worker/compressor shape every round never allocates
/// after warm-up). Produces exactly what [`decode`] produces. On `Err`,
/// `out` is left in a valid but unspecified state.
pub fn decode_into(bytes: &[u8], out: &mut Packet) -> Result<(), WireError> {
    let mut r = BitReader::new(bytes);
    decode_packet(&mut r, out)
}

/// Read one packet frame (header + body) through an open reader — shared
/// by the uplink ([`decode_into`]) and downlink ([`decode_down_into`])
/// paths.
fn decode_packet(r: &mut BitReader, out: &mut Packet) -> Result<(), WireError> {
    let tag = r.read_u8()?;
    let prec = match r.read_u8()? {
        0 => ValPrec::F32,
        1 => ValPrec::F64,
        p => return Err(WireError::BadPrec(p)),
    };
    let dim = r.read_u32()?;
    match tag {
        TAG_DENSE => {
            // bound the reservation by the input before trusting `dim`
            // (values are byte-aligned, so avail_bits is the right budget
            // up to one alignment byte — a marginal pass still errors
            // cleanly in read_val)
            let vb = prec.bits();
            if dim as u64 * vb > r.avail_bits() {
                return Err(WireError::Truncated {
                    needed: r.byte_pos + (dim as u64 * vb / 8) as usize,
                    have: r.buf.len(),
                });
            }
            let v = out.ensure_dense();
            v.clear();
            v.reserve(dim as usize);
            for _ in 0..dim {
                v.push(r.read_val(prec)?);
            }
            Ok(())
        }
        TAG_SPARSE => {
            let k = r.read_u32()?;
            if k > dim {
                return Err(WireError::Malformed(format!("k={k} > dim={dim}")));
            }
            let scale_v = r.read_val(prec)?;
            let (out_dim, indices, values, scale) = out.ensure_sparse();
            *out_dim = dim;
            *scale = scale_v;
            let ib = index_bits(dim);
            indices.clear();
            for _ in 0..k {
                let idx = r.read_bits(ib)? as u32;
                if idx >= dim {
                    return Err(WireError::Malformed(format!("index {idx} ≥ dim {dim}")));
                }
                indices.push(idx);
            }
            r.align();
            values.clear();
            for _ in 0..k {
                values.push(r.read_val(prec)?);
            }
            Ok(())
        }
        TAG_LEVELS => {
            let s_v = r.read_u8()?;
            let norm_v = r.read_val(prec)?;
            let (out_dim, norm, s, signs, levels) = out.ensure_levels();
            *out_dim = dim;
            *norm = norm_v;
            *s = s_v;
            read_signs_into(r, dim as usize, signs)?;
            r.align();
            let lb = bits_for_levels(s_v);
            levels.clear();
            for _ in 0..dim {
                let l = r.read_bits(lb)? as u8;
                if l > s_v {
                    return Err(WireError::Malformed(format!("level {l} > s {s_v}")));
                }
                levels.push(l);
            }
            Ok(())
        }
        TAG_LEVELS_LINEAR => {
            let s_v = r.read_u32()?;
            // wire-supplied: bound before the `s + 1` arithmetic below (and
            // in Packet::payload_bits) can overflow
            if s_v == u32::MAX {
                return Err(WireError::Malformed(format!(
                    "levels-linear s={s_v} out of range"
                )));
            }
            let norm_v = r.read_val(prec)?;
            let (out_dim, norm, s, signs, levels) = out.ensure_levels_linear();
            *out_dim = dim;
            *norm = norm_v;
            *s = s_v;
            read_signs_into(r, dim as usize, signs)?;
            r.align();
            let n = s_v + 1;
            let lb = if n <= 1 {
                1
            } else {
                (32 - (n - 1).leading_zeros()) as u64
            };
            levels.clear();
            for _ in 0..dim {
                let l = r.read_bits(lb)?;
                // levels are u8 grid indices in 0..=s — reject instead of
                // silently truncating hostile values
                if l > s_v as u64 || l > u8::MAX as u64 {
                    return Err(WireError::Malformed(format!("level {l} > s {s_v}")));
                }
                levels.push(l as u8);
            }
            Ok(())
        }
        TAG_NATEXP => {
            let (out_dim, signs, exps) = out.ensure_natexp();
            *out_dim = dim;
            read_signs_into(r, dim as usize, signs)?;
            r.align();
            exps.clear();
            for _ in 0..dim {
                exps.push(r.read_bits(8)? as u8 as i8);
            }
            Ok(())
        }
        TAG_SIGNSCALE => {
            let scale_v = r.read_val(prec)?;
            let (out_dim, scale, signs) = out.ensure_signscale();
            *out_dim = dim;
            *scale = scale_v;
            read_signs_into(r, dim as usize, signs)?;
            Ok(())
        }
        TAG_TERNARY => {
            let scale_v = r.read_val(prec)?;
            let (out_dim, scale, mask, signs) = out.ensure_ternary();
            *out_dim = dim;
            *scale = scale_v;
            read_signs_into(r, dim as usize, mask)?;
            r.align();
            let nnz = r.read_u32()? as usize;
            if nnz != mask.iter().filter(|&&b| b).count() {
                return Err(WireError::Malformed("ternary nnz mismatch".into()));
            }
            read_signs_into(r, nnz, signs)?;
            Ok(())
        }
        TAG_ZERO => {
            *out = Packet::Zero { dim };
            Ok(())
        }
        t => Err(WireError::BadTag(t)),
    }
}

// ------------------------------------------------ walk-only frame validation

/// Summary of a validated downlink frame (see [`validate_down`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DownFrameInfo {
    /// Which downlink frame kind the kind byte announced.
    pub kind: DownKind,
    /// The inner packet-frame tag (`TAG_DENSE`, `TAG_SPARSE`, ...).
    pub tag: u8,
    /// The dimension carried by the inner packet header.
    pub dim: u32,
}

impl DownFrameInfo {
    /// True when the inner packet is a dense frame (the only shape a
    /// resync broadcast may carry).
    pub fn is_dense(&self) -> bool {
        self.tag == TAG_DENSE
    }
}

/// Walk a downlink frame end to end, enforcing exactly the structural
/// checks of [`decode_down_into`] without materializing the packet.
///
/// Workers use this on the shared broadcast buffer: under the
/// snapshot/overlay replica model ([`crate::coordinator::replica`]) they no
/// longer replay downlink deltas into a private dense replica, but a
/// corrupted or wrong-dimension frame must still surface as the same
/// structured failure it always did (the fault-injection and chaos suites
/// pin those strings). Keeping the walk allocation-free also means a dense
/// resync frame no longer costs every worker an O(d) decode buffer.
pub fn validate_down(bytes: &[u8]) -> Result<DownFrameInfo, WireError> {
    let mut r = BitReader::new(bytes);
    let kind = match r.read_u8()? {
        DOWN_DELTA => DownKind::Delta,
        DOWN_RESYNC => DownKind::Resync,
        DOWN_EF_DELTA => DownKind::EfDelta,
        t => return Err(WireError::BadTag(t)),
    };
    let (tag, dim) = validate_packet(&mut r)?;
    Ok(DownFrameInfo { kind, tag, dim })
}

/// Read-and-discard walk of one packet frame, mirroring
/// [`decode_packet`]'s per-tag strictness (the same rejects for bad
/// tags/precisions, truncation, out-of-range indices/levels, and ternary
/// nnz mismatches) while touching no allocator. Returns the frame's
/// `(tag, dim)` header.
fn validate_packet(r: &mut BitReader) -> Result<(u8, u32), WireError> {
    let tag = r.read_u8()?;
    let prec = match r.read_u8()? {
        0 => ValPrec::F32,
        1 => ValPrec::F64,
        p => return Err(WireError::BadPrec(p)),
    };
    let dim = r.read_u32()?;
    match tag {
        TAG_DENSE => {
            let vb = prec.bits();
            if dim as u64 * vb > r.avail_bits() {
                return Err(WireError::Truncated {
                    needed: r.byte_pos + (dim as u64 * vb / 8) as usize,
                    have: r.buf.len(),
                });
            }
            for _ in 0..dim {
                r.read_val(prec)?;
            }
        }
        TAG_SPARSE => {
            let k = r.read_u32()?;
            if k > dim {
                return Err(WireError::Malformed(format!("k={k} > dim={dim}")));
            }
            r.read_val(prec)?;
            let ib = index_bits(dim);
            for _ in 0..k {
                let idx = r.read_bits(ib)? as u32;
                if idx >= dim {
                    return Err(WireError::Malformed(format!("index {idx} ≥ dim {dim}")));
                }
            }
            r.align();
            for _ in 0..k {
                r.read_val(prec)?;
            }
        }
        TAG_LEVELS => {
            let s_v = r.read_u8()?;
            r.read_val(prec)?;
            skip_signs(r, dim as usize)?;
            r.align();
            let lb = bits_for_levels(s_v);
            for _ in 0..dim {
                let l = r.read_bits(lb)? as u8;
                if l > s_v {
                    return Err(WireError::Malformed(format!("level {l} > s {s_v}")));
                }
            }
        }
        TAG_LEVELS_LINEAR => {
            let s_v = r.read_u32()?;
            if s_v == u32::MAX {
                return Err(WireError::Malformed(format!(
                    "levels-linear s={s_v} out of range"
                )));
            }
            r.read_val(prec)?;
            skip_signs(r, dim as usize)?;
            r.align();
            let n = s_v + 1;
            let lb = if n <= 1 {
                1
            } else {
                (32 - (n - 1).leading_zeros()) as u64
            };
            for _ in 0..dim {
                let l = r.read_bits(lb)?;
                if l > s_v as u64 || l > u8::MAX as u64 {
                    return Err(WireError::Malformed(format!("level {l} > s {s_v}")));
                }
            }
        }
        TAG_NATEXP => {
            skip_signs(r, dim as usize)?;
            r.align();
            for _ in 0..dim {
                r.read_bits(8)?;
            }
        }
        TAG_SIGNSCALE => {
            r.read_val(prec)?;
            skip_signs(r, dim as usize)?;
        }
        TAG_TERNARY => {
            r.read_val(prec)?;
            let mask_nnz = skip_signs(r, dim as usize)?;
            r.align();
            let nnz = r.read_u32()? as usize;
            if nnz != mask_nnz {
                return Err(WireError::Malformed("ternary nnz mismatch".into()));
            }
            skip_signs(r, nnz)?;
        }
        TAG_ZERO => {}
        t => return Err(WireError::BadTag(t)),
    }
    Ok((tag, dim))
}

/// Discard `n` sign bits with [`read_signs_into`]'s exact bounds behavior,
/// returning the number of set bits (the ternary mask popcount).
fn skip_signs(r: &mut BitReader, n: usize) -> Result<usize, WireError> {
    if n as u64 > r.avail_bits() {
        return Err(WireError::Truncated {
            needed: r.byte_pos + (n + 7) / 8,
            have: r.buf.len(),
        });
    }
    let mut set = 0usize;
    let mut left = n;
    while left > 0 {
        let take = left.min(64);
        set += r.read_bits(take as u64)?.count_ones() as usize;
        left -= take;
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::run;

    fn roundtrip(pkt: Packet) {
        for prec in [ValPrec::F64, ValPrec::F32] {
            let bytes = encode(&pkt, prec);
            let back = decode(&bytes).unwrap();
            match prec {
                ValPrec::F64 => assert_eq!(back, pkt, "f64 roundtrip"),
                ValPrec::F32 => {
                    // values rounded to f32; structure must match
                    assert_eq!(back.dim(), pkt.dim());
                    let a = back.decode();
                    let b = pkt.decode();
                    for (x, y) in a.iter().zip(b.iter()) {
                        let tol = 1e-6 * y.abs().max(1.0);
                        assert!((x - y).abs() <= tol, "{x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn roundtrips_all_variants() {
        roundtrip(Packet::Dense(vec![1.5, -2.25, 0.0, 1e-3]));
        roundtrip(Packet::Sparse {
            dim: 80,
            indices: vec![0, 7, 79],
            values: vec![1.0, -0.5, 3.25],
            scale: 10.0,
        });
        roundtrip(Packet::Levels {
            dim: 5,
            norm: 4.5,
            s: 3,
            signs: vec![true, false, true, true, false],
            levels: vec![0, 1, 2, 3, 1],
        });
        roundtrip(Packet::LevelsLinear {
            dim: 4,
            norm: 2.0,
            s: 7,
            signs: vec![true, true, false, false],
            levels: vec![7, 0, 3, 5],
        });
        roundtrip(Packet::NatExp {
            dim: 3,
            signs: vec![true, false, true],
            exps: vec![5, -3, i8::MIN],
        });
        roundtrip(Packet::SignScale {
            dim: 9,
            scale: 0.125,
            signs: vec![true; 9],
        });
        roundtrip(Packet::TernaryPkt {
            dim: 6,
            scale: 1.0,
            mask: vec![true, false, true, false, false, true],
            signs: vec![true, false, true],
        });
        roundtrip(Packet::Zero { dim: 100 });
    }

    /// The walk-only downlink validator must agree with the materializing
    /// decoder on every frame: same accept set, same reject set — it is
    /// the worker-side guard now that workers no longer decode-apply.
    #[test]
    fn validate_down_agrees_with_decode_down() {
        let pkts = vec![
            Packet::Dense(vec![1.5, -2.25, 0.0, 1e-3]),
            Packet::Sparse {
                dim: 80,
                indices: vec![0, 7, 79],
                values: vec![1.0, -0.5, 3.25],
                scale: 10.0,
            },
            Packet::TernaryPkt {
                dim: 6,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true],
                signs: vec![true, false, true],
            },
            Packet::Zero { dim: 100 },
        ];
        for pkt in &pkts {
            for kind in [DownKind::Delta, DownKind::Resync, DownKind::EfDelta] {
                let mut bytes = Vec::new();
                encode_down_into(kind, pkt, ValPrec::F64, &mut bytes);
                let info = validate_down(&bytes).expect("valid frame must validate");
                assert_eq!(info.kind, kind);
                assert_eq!(info.dim, pkt.dim());
                let mut out = Packet::Zero { dim: 0 };
                assert_eq!(decode_down_into(&bytes, &mut out).unwrap(), kind);
                // truncation rejects in both
                for cut in [1usize, bytes.len() / 2, bytes.len().saturating_sub(1)] {
                    if cut < bytes.len() {
                        assert!(validate_down(&bytes[..cut]).is_err(), "cut at {cut}");
                        assert!(decode_down_into(&bytes[..cut], &mut out).is_err());
                    }
                }
            }
        }
        // a bad kind byte and a bad inner tag reject identically
        let mut bytes = Vec::new();
        encode_down_into(DownKind::Delta, &pkts[0], ValPrec::F64, &mut bytes);
        bytes[0] = 0x7f;
        assert!(validate_down(&bytes).is_err());
        bytes[0] = DOWN_DELTA;
        bytes[1] = 0x6e;
        assert!(validate_down(&bytes).is_err());
    }

    /// The word-at-a-time packer must agree, bit for bit, with a naive
    /// one-bit-per-iteration reference on random unaligned write/read
    /// sequences spanning every width 0..=64 and byte-boundary phase.
    #[test]
    fn word_at_a_time_matches_per_bit_reference() {
        struct RefWriter {
            buf: Vec<u8>,
            bit_pos: u8,
        }
        impl RefWriter {
            fn write_bits(&mut self, value: u64, nbits: u64) {
                for i in 0..nbits {
                    let bit = (value >> i) & 1;
                    if self.bit_pos == 0 {
                        self.buf.push(0);
                    }
                    let last = self.buf.len() - 1;
                    self.buf[last] |= (bit as u8) << self.bit_pos;
                    self.bit_pos = (self.bit_pos + 1) % 8;
                }
            }
        }
        run(300, 0xb17_f00d, |g| {
            let n_ops = g.usize_in(1, 40);
            let ops: Vec<(u64, u64)> = (0..n_ops)
                .map(|_| {
                    let nbits = g.usize_in(0, 64) as u64;
                    let v = g.rng.next_u64();
                    (v, nbits)
                })
                .collect();
            let mut fast_buf = vec![0xEEu8; 8]; // dirty, recycled
            let mut fast = BitWriter::new(&mut fast_buf);
            let mut reference = RefWriter {
                buf: Vec::new(),
                bit_pos: 0,
            };
            for &(v, n) in &ops {
                fast.write_bits(v, n);
                reference.write_bits(v, n);
                // occasionally re-align both, as frame encoders do
                if n % 7 == 3 {
                    fast.align();
                    reference.bit_pos = 0;
                }
            }
            if fast_buf != reference.buf {
                return Err(format!("writer bytes diverged on {ops:?}"));
            }
            // read everything back
            let mut r = BitReader::new(&fast_buf);
            for &(v, n) in &ops {
                let got = r.read_bits(n).map_err(|e| e.to_string())?;
                if got != v & mask(n) {
                    return Err(format!("read {got:#x} want {:#x} (n={n})", v & mask(n)));
                }
                if n % 7 == 3 {
                    r.align();
                }
            }
            Ok(())
        });
    }

    #[test]
    fn read_bits_rejects_truncation_at_any_phase() {
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        w.write_bits(0x5a5a, 16);
        w.write_bits(0x3, 3);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(16).unwrap(), 0x5a5a);
        assert_eq!(r.read_bits(3).unwrap(), 0x3);
        assert!(r.read_bits(6).is_err(), "only 5 padding bits remain");
        // a fresh reader asking for more than the buffer holds
        let mut r = BitReader::new(&buf);
        assert!(r.read_bits(64).is_err());
    }

    #[test]
    fn encoded_len_matches_encode_exactly() {
        let pkts = vec![
            Packet::Dense(vec![1.5, -2.25, 0.0]),
            Packet::Sparse {
                dim: 200_000,
                indices: vec![0, 77, 131_071, 199_999],
                values: vec![1.0, -0.5, 3.25, 9.0],
                scale: 2.0,
            },
            Packet::Sparse {
                dim: 80,
                indices: (0..80).collect(),
                values: vec![0.5; 80],
                scale: 1.0,
            },
            Packet::Levels {
                dim: 13,
                norm: 4.5,
                s: 5,
                signs: vec![true; 13],
                levels: vec![1; 13],
            },
            Packet::LevelsLinear {
                dim: 9,
                norm: 2.0,
                s: 200,
                signs: vec![false; 9],
                levels: vec![3; 9],
            },
            Packet::NatExp {
                dim: 17,
                signs: vec![true; 17],
                exps: vec![0; 17],
            },
            Packet::SignScale {
                dim: 9,
                scale: 0.125,
                signs: vec![true; 9],
            },
            Packet::TernaryPkt {
                dim: 11,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true, true, true, false, false, true],
                signs: vec![true; 6],
            },
            Packet::Zero { dim: 100 },
        ];
        for pkt in &pkts {
            for prec in [ValPrec::F64, ValPrec::F32] {
                assert_eq!(
                    encoded_len(pkt, prec),
                    encode(pkt, prec).len(),
                    "{pkt:?} {prec:?}"
                );
                let mut down = Vec::new();
                encode_down_into(DownKind::Delta, pkt, prec, &mut down);
                assert_eq!(
                    down_frame_bits(pkt, prec),
                    down.len() as u64 * 8,
                    "{pkt:?} {prec:?} downlink"
                );
            }
        }
    }

    #[test]
    fn down_frames_roundtrip_and_reject_garbage() {
        let pkt = Packet::Sparse {
            dim: 1000,
            indices: vec![3, 999],
            values: vec![0.5, -2.0],
            scale: -0.125,
        };
        let mut buf = Vec::new();
        for kind in [DownKind::Delta, DownKind::Resync, DownKind::EfDelta] {
            encode_down_into(kind, &pkt, ValPrec::F64, &mut buf);
            let mut out = Packet::Zero { dim: 0 };
            assert_eq!(decode_down_into(&buf, &mut out).unwrap(), kind);
            assert_eq!(out, pkt);
            // truncation at every cut must error
            for cut in 1..buf.len() {
                assert!(decode_down_into(&buf[..cut], &mut out).is_err(), "cut {cut}");
            }
        }
        // unknown kind byte
        buf[0] = 99;
        let mut out = Packet::Zero { dim: 0 };
        assert!(decode_down_into(&buf, &mut out).is_err());
        assert!(decode_down_into(&[], &mut out).is_err());
        // resync fast path is byte-identical to the packet path
        let x = vec![0.25, -1.5, 3.0];
        let mut direct = Vec::new();
        encode_down_dense(DownKind::Resync, &x, ValPrec::F64, &mut direct);
        let mut via_pkt = Vec::new();
        encode_down_into(DownKind::Resync, &Packet::Dense(x.clone()), ValPrec::F64, &mut via_pkt);
        assert_eq!(direct, via_pkt);
    }

    #[test]
    fn build_update_packet_matches_dense_axpy() {
        // sparse regime: few nonzeros
        let mut v = vec![0.0; 64];
        v[3] = 1.5;
        v[40] = -2.25;
        v[63] = 1e-3;
        let gamma = 0.37;
        let mut scratch = DeltaScratch::with_capacity(0);
        let pkt = build_update_packet(&v, -gamma, ValPrec::F64, &mut scratch);
        assert!(matches!(pkt, Packet::Sparse { .. }), "sparse regime must pick Sparse");
        let mut got = vec![1.0; 64];
        let mut want = vec![1.0; 64];
        pkt.add_scaled_into(1.0, &mut got);
        crate::linalg::axpy(-gamma, &v, &mut want);
        for j in 0..64 {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "coord {j}");
        }
        // dense regime: all nonzero ⇒ Dense is cheaper
        let v: Vec<f64> = (0..64).map(|i| (i as f64) - 31.5).collect();
        let pkt = build_update_packet(&v, -gamma, ValPrec::F64, &mut scratch);
        assert!(matches!(pkt, Packet::Dense(_)), "dense regime must pick Dense");
        let mut got = vec![1.0; 64];
        let mut want = vec![1.0; 64];
        pkt.add_scaled_into(1.0, &mut got);
        crate::linalg::axpy(-gamma, &v, &mut want);
        for j in 0..64 {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "coord {j}");
        }
        // `packet()` re-exposes the representation chosen by the last build
        assert!(matches!(scratch.packet(), Packet::Dense(_)));
    }

    #[test]
    fn build_update_packet_f32_is_wire_stable() {
        // f32-quantized packets must survive the encode → decode round-trip
        // unchanged, so master and replicas apply identical updates.
        let mut v = vec![0.0; 32];
        v[1] = 0.1; // not representable in f32 — must be pre-quantized
        v[30] = -7.3;
        let mut scratch = DeltaScratch::with_capacity(0);
        let pkt = build_update_packet(&v, -0.123, ValPrec::F32, &mut scratch);
        let mut buf = Vec::new();
        encode_down_into(DownKind::Delta, pkt, ValPrec::F32, &mut buf);
        let mut back = Packet::Zero { dim: 0 };
        assert_eq!(decode_down_into(&buf, &mut back).unwrap(), DownKind::Delta);
        assert_eq!(&back, pkt, "f32 round-trip must be lossless on quantized values");
    }

    #[test]
    fn batch_frames_roundtrip_all_variants() {
        // a batch mixing every shape a Q compressor can emit
        let pkts = vec![
            Packet::Sparse {
                dim: 120,
                indices: vec![0, 17, 119],
                values: vec![1.0, -0.5, 3.25],
                scale: 2.0,
            },
            Packet::Dense(vec![1.5, -2.25, 0.0, 1e-3]),
            Packet::Levels {
                dim: 5,
                norm: 4.5,
                s: 3,
                signs: vec![true, false, true, true, false],
                levels: vec![0, 1, 2, 3, 1],
            },
            Packet::Zero { dim: 100 },
            Packet::TernaryPkt {
                dim: 6,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true],
                signs: vec![true, false, true],
            },
        ];
        for prec in [ValPrec::F64, ValPrec::F32] {
            let mut buf = vec![0xEEu8; 16]; // dirty, recycled
            begin_batch_frame(pkts.len(), &mut buf);
            for pkt in &pkts {
                append_batch_packet(pkt, prec, &mut buf);
            }
            // body bytes are exactly the concatenated standalone encodings
            let mut want = Vec::new();
            for pkt in &pkts {
                want.extend_from_slice(&encode(pkt, prec));
            }
            assert_eq!(&buf[BATCH_HEADER_BYTES..], &want[..], "{prec:?} body");
            // walk the frame back with one recycled scratch packet
            let (count, mut off) = split_batch_frame(&buf).unwrap();
            assert_eq!(count, pkts.len());
            let mut scratch = Packet::Zero { dim: 0 };
            for (i, pkt) in pkts.iter().enumerate() {
                off = decode_batch_packet(&buf, off, &mut scratch).unwrap();
                match prec {
                    ValPrec::F64 => assert_eq!(&scratch, pkt, "packet {i}"),
                    ValPrec::F32 => assert_eq!(scratch.dim(), pkt.dim(), "packet {i}"),
                }
            }
            assert_eq!(off, buf.len(), "batch walk must consume the whole frame");
        }
    }

    #[test]
    fn batch_frames_reject_garbage() {
        let mut buf = Vec::new();
        begin_batch_frame(2, &mut buf);
        append_batch_packet(&Packet::Zero { dim: 4 }, ValPrec::F64, &mut buf);
        append_batch_packet(&Packet::Dense(vec![1.0, 2.0]), ValPrec::F64, &mut buf);
        assert!(split_batch_frame(&buf).is_ok());
        // too-short header / wrong tag / zero count
        assert!(split_batch_frame(&[]).is_err());
        assert!(split_batch_frame(&buf[..2]).is_err());
        let mut bad = buf.clone();
        bad[0] = TAG_DENSE;
        assert!(split_batch_frame(&bad).is_err());
        let mut bad = buf.clone();
        bad[1] = 0;
        bad[2] = 0;
        assert!(split_batch_frame(&bad).is_err());
        // truncated body errors at every cut
        let (_, first_off) = split_batch_frame(&buf).unwrap();
        let mut scratch = Packet::Zero { dim: 0 };
        for cut in first_off..buf.len() {
            let walked = decode_batch_packet(&buf[..cut], first_off, &mut scratch)
                .and_then(|off| decode_batch_packet(&buf[..cut], off, &mut scratch));
            assert!(walked.is_err(), "cut {cut} must not decode both packets");
        }
        // offsets beyond the buffer error instead of panicking
        assert!(decode_batch_packet(&buf, buf.len() + 7, &mut scratch).is_err());
        // a batch frame is not a plain packet frame
        assert!(matches!(decode(&buf), Err(WireError::BadTag(TAG_BATCH))));
    }

    #[test]
    fn encoded_size_close_to_payload_bits() {
        // The byte size must be within header + alignment slack of the
        // theoretical payload bits.
        let pkts = vec![
            Packet::Sparse {
                dim: 80,
                indices: (0..8).collect(),
                values: vec![1.0; 8],
                scale: 10.0,
            },
            Packet::Levels {
                dim: 80,
                norm: 1.0,
                s: 7,
                signs: vec![true; 80],
                levels: vec![3; 80],
            },
            Packet::NatExp {
                dim: 80,
                signs: vec![false; 80],
                exps: vec![0; 80],
            },
        ];
        for pkt in pkts {
            let bits = pkt.payload_bits(ValPrec::F64);
            let bytes = encode(&pkt, ValPrec::F64).len() as u64 * 8;
            assert!(bytes >= bits, "encoding can't beat its own accounting");
            // slack: header + ≤4 alignment paddings of ≤7 bits + length field
            assert!(
                bytes <= bits + HEADER_BITS + 64,
                "too much overhead: {bytes} vs {bits}"
            );
        }
    }

    #[test]
    fn encode_into_and_decode_into_reuse_buffers() {
        let pkts = vec![
            Packet::Dense(vec![1.5, -2.25, 0.0]),
            Packet::Sparse {
                dim: 80,
                indices: vec![0, 7, 79],
                values: vec![1.0, -0.5, 3.25],
                scale: 10.0,
            },
            Packet::Levels {
                dim: 5,
                norm: 4.5,
                s: 3,
                signs: vec![true, false, true, true, false],
                levels: vec![0, 1, 2, 3, 1],
            },
            Packet::TernaryPkt {
                dim: 6,
                scale: 1.0,
                mask: vec![true, false, true, false, false, true],
                signs: vec![true, false, true],
            },
            Packet::Zero { dim: 100 },
        ];
        // deliberately dirty scratch: reused across mismatched variants
        let mut buf = vec![0xAAu8; 64];
        let mut scratch = Packet::SignScale {
            dim: 3,
            scale: 9.0,
            signs: vec![true; 3],
        };
        for pkt in &pkts {
            let fresh = encode(pkt, ValPrec::F64);
            encode_into(pkt, ValPrec::F64, &mut buf);
            assert_eq!(fresh, buf, "encode_into must be byte-identical");
            decode_into(&buf, &mut scratch).unwrap();
            assert_eq!(&scratch, pkt, "decode_into must reproduce decode");
            // second pass now hits the matched-variant reuse path
            decode_into(&buf, &mut scratch).unwrap();
            assert_eq!(&scratch, pkt);
        }
    }

    #[test]
    fn encode_down_dense_matches_dense_packet() {
        let v = vec![0.5, -1.25, 3.0, 1e-9];
        for prec in [ValPrec::F64, ValPrec::F32] {
            let mut via_packet = Vec::new();
            encode_down_into(DownKind::Resync, &Packet::Dense(v.clone()), prec, &mut via_packet);
            let mut direct = vec![7u8; 3];
            encode_down_dense(DownKind::Resync, &v, prec, &mut direct);
            assert_eq!(via_packet, direct);
        }
    }

    #[test]
    fn corrupted_dim_errors_without_huge_allocation() {
        // a 6-byte header claiming dim = u32::MAX must produce Truncated,
        // not attempt a ~34 GB reservation
        let mut bytes = vec![TAG_DENSE, 1];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(WireError::Truncated { .. })));
        // signs-bearing variant goes through read_signs_into's guard
        let mut bytes = vec![TAG_SIGNSCALE, 1];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(WireError::Truncated { .. })));
        // and through the downlink path the workers .expect() on
        let mut down = vec![DOWN_DELTA, TAG_DENSE, 1];
        down.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut out = Packet::Zero { dim: 0 };
        assert!(decode_down_into(&down, &mut out).is_err());
        // levels-linear with s = u32::MAX must error, not overflow s + 1
        let mut bytes = vec![TAG_LEVELS_LINEAR, 1];
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn resync_frame_bits_matches_encoder() {
        for d in [0usize, 1, 7, 80, 1000] {
            let x = vec![0.5; d];
            let mut buf = Vec::new();
            encode_down_dense(DownKind::Resync, &x, ValPrec::F64, &mut buf);
            assert_eq!(resync_frame_bits(d), buf.len() as u64 * 8, "d={d}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[99, 1, 0, 0, 0, 0]).is_err());
        // truncated dense
        let bytes = encode(&Packet::Dense(vec![1.0, 2.0]), ValPrec::F64);
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        // sparse with k > dim
        let bad = encode(
            &Packet::Sparse {
                dim: 2,
                indices: vec![0, 1, 1],
                values: vec![1.0; 3],
                scale: 1.0,
            },
            ValPrec::F64,
        );
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn bitpacking_is_compact() {
        // 80 indices at 7 bits each = 70 bytes vs 320 for u32s.
        let pkt = Packet::Sparse {
            dim: 80,
            indices: (0..80).collect(),
            values: vec![0.0; 80],
            scale: 1.0,
        };
        let bytes = encode(&pkt, ValPrec::F32);
        // header 6 + k(4) + scale(4) + ceil(80*7/8)=70 + values 320
        assert!(bytes.len() <= 6 + 4 + 4 + 70 + 320 + 2, "len {}", bytes.len());
    }
}
