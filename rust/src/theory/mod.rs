//! Step-size rules and iteration-complexity formulas from the paper's
//! Theorems 1–6 and Table 1.
//!
//! The analyses all instantiate the unified framework of Gorbunov, Hanzely &
//! Richtárik (2020a, Theorem 4.1): an unbiased estimator `g^k` with
//!
//! ```text
//! E‖g^k − ∇f(x*)‖² ≤ 2 A · D_f(x^k, x*) + B · σ^k                (ES)
//! E σ^{k+1}        ≤ (1 − ρ) σ^k + 2 C · D_f(x^k, x*)           (REC)
//! ```
//!
//! yields, with Lyapunov `V^k = ‖x^k − x*‖² + M γ² σ^k`, step size
//! `γ ≤ 1/(A + M C)` and any `M > B/ρ`,
//!
//! ```text
//! E V^k ≤ max{ (1 − γμ)^k , (1 − ρ + B/M)^k } · V⁰.
//! ```
//!
//! Each method below supplies its (A, B, C, ρ) and a default `M`.

use crate::problems::Problem;

pub mod staleness;

/// Everything an algorithm instance needs from the theory.
#[derive(Clone, Copy, Debug)]
pub struct StepSizes {
    /// main step size γ
    pub gamma: f64,
    /// shift-learning step size α (DIANA-like; 0 when unused)
    pub alpha: f64,
    /// model-mixing step size η (GDCI family; 0 when unused)
    pub eta: f64,
    /// Lyapunov constant M (0 when unused)
    pub m: f64,
    /// linear rate bound per round: error contracts by ≤ this factor
    pub rate: f64,
}

impl StepSizes {
    /// `O~` iteration complexity to reach ε: log(1/ε) / −log(rate).
    pub fn iters_for(&self, eps: f64) -> f64 {
        assert!(eps > 0.0 && eps < 1.0);
        (1.0 / eps).ln() / -(self.rate.min(1.0 - 1e-15)).ln()
    }
}

// ---------------------------------------------------------------- Theorem 1

/// DCGD with fixed shifts: `γ ≤ 1/(L + 2 max_i(L_i ω_i)/n)`.
/// Converges linearly to a neighborhood of radius
/// `(2γ/μ)·(1/n)Σ (ω_i/n)‖∇f_i(x*) − h_i‖²`.
pub fn dcgd_fixed(p: &dyn Problem, omega: &[f64]) -> StepSizes {
    let n = p.n_workers() as f64;
    let max_lw = (0..p.n_workers())
        .map(|i| p.l_i(i) * omega[i])
        .fold(0.0, f64::max);
    let gamma = 1.0 / (p.l() + 2.0 * max_lw / n);
    StepSizes {
        gamma,
        alpha: 0.0,
        eta: 0.0,
        m: 0.0,
        rate: 1.0 - gamma * p.mu(),
    }
}

/// The oscillation-neighborhood radius of Theorem 1 (relative to
/// ‖x⁰ − x*‖² when `rel_to` is provided):
/// `(2γ/μ)·(1/n²)·Σ ω_i ‖∇f_i(x*) − h_i‖²`.
pub fn dcgd_fixed_neighborhood(
    p: &dyn Problem,
    omega: &[f64],
    shifts: &[Vec<f64>],
    gamma: f64,
) -> f64 {
    let n = p.n_workers();
    let mut acc = 0.0;
    for i in 0..n {
        acc += omega[i] * crate::linalg::dist_sq(p.grad_star(i), &shifts[i]);
    }
    2.0 * gamma / p.mu() * acc / (n * n) as f64
}

// ---------------------------------------------------------------- Theorem 2

/// DCGD-STAR: `γ ≤ 1/(L + max_i(L_i ω_i (1 − δ_i))/n)`; exact linear
/// convergence.
pub fn dcgd_star(p: &dyn Problem, omega: &[f64], delta: &[f64]) -> StepSizes {
    let n = p.n_workers() as f64;
    let max_term = (0..p.n_workers())
        .map(|i| p.l_i(i) * omega[i] * (1.0 - delta[i]))
        .fold(0.0, f64::max);
    let gamma = 1.0 / (p.l() + max_term / n);
    StepSizes {
        gamma,
        alpha: 0.0,
        eta: 0.0,
        m: 0.0,
        rate: 1.0 - gamma * p.mu(),
    }
}

// ---------------------------------------------------------------- Theorem 3

/// Generalized DIANA (Theorem 3 via the unified framework):
///
/// effective variance ω̃_i = ω_i(1 − δ_i) (induced compressor),
/// `α ≤ 1/(1 + max_i ω̃_i)`,
/// (A, B, C, ρ) = (2 max(ω̃_i L_i)/n + L_max, 2/n, α max(ω̃_i L_i), α),
/// `M = margin·B/ρ`, `γ ≤ 1/(A + MC)`.
pub fn diana(p: &dyn Problem, omega: &[f64], delta: &[f64], m_margin: f64) -> StepSizes {
    let n = p.n_workers() as f64;
    let wt: Vec<f64> = omega
        .iter()
        .zip(delta.iter())
        .map(|(&w, &d)| w * (1.0 - d))
        .collect();
    let max_wt = wt.iter().fold(0.0f64, |a, &b| a.max(b));
    let alpha = 1.0 / (1.0 + max_wt);
    let max_wl = (0..p.n_workers())
        .map(|i| wt[i] * p.l_i(i))
        .fold(0.0, f64::max);
    let a = 2.0 * max_wl / n + p.l_max();
    let b = 2.0 / n;
    let c = alpha * max_wl;
    let rho = alpha;
    let m = m_margin * b / rho; // M > B/ρ
    let gamma = 1.0 / (a + m * c);
    let rate_x = 1.0 - gamma * p.mu();
    let rate_sigma = 1.0 - rho + b / m;
    StepSizes {
        gamma,
        alpha,
        eta: 0.0,
        m,
        rate: rate_x.max(rate_sigma),
    }
}

// ---------------------------------------------------------------- Theorem 4

/// Rand-DIANA (Theorem 4):
/// `γ ≤ 1/((1 + 2ω/n) L_max + M max_i(p_i L_i))`, `M > 2ω/(n p_m)`,
/// rate `max{1 − γμ, 1 − p_m + 2ω/(nM)}`.
///
/// `m_override`: pass a specific M (the Figure-2 stability study sets
/// `M = b·M'`), else the paper's `M = 4ω/(n p_m)` is used.
pub fn rand_diana(
    p: &dyn Problem,
    omega_max: f64,
    probs: &[f64],
    m_override: Option<f64>,
) -> StepSizes {
    let n = p.n_workers() as f64;
    let p_m = probs.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    let max_pl = (0..p.n_workers())
        .map(|i| probs[i] * p.l_i(i))
        .fold(0.0, f64::max);
    let m_prime = 2.0 * omega_max / (n * p_m);
    let m = m_override.unwrap_or(2.0 * m_prime); // paper: M = 4ω/(np_m)
    let gamma = 1.0 / ((1.0 + 2.0 * omega_max / n) * p.l_max() + m * max_pl);
    let rate_x = 1.0 - gamma * p.mu();
    let rate_sigma = 1.0 - p_m + 2.0 * omega_max / (n * m);
    StepSizes {
        gamma,
        alpha: 0.0,
        eta: 0.0,
        m,
        rate: rate_x.max(rate_sigma),
    }
}

/// The paper's recommended refresh probability `p = 1/(ω+1)`.
pub fn rand_diana_default_p(omega: f64) -> f64 {
    1.0 / (omega + 1.0)
}

// ------------------------------------------- EF-BV uplink (arXiv:2205.04180)

/// EF21/EF-BV-style step size for the error-fed-back uplink: each worker
/// ships `c_i = C_i(e_i + m_i)` with a contractive `C_i ∈ B(δ_i)` and
/// retries the residual next round (see [`crate::ef::EfUplink`]).
///
/// With `δ = min_i δ_i`, the standard EF21 constants are
///
/// ```text
/// θ = 1 − √(1 − δ),   β = (1 − δ)/θ,
/// γ ≤ 1 / (L + L̃ √(β/θ)),   L̃ = √((1/n) Σ L_i²),
/// ```
///
/// and the residual recursion contracts at θ, giving the rate bound
/// `max{1 − γμ, 1 − θ/2}` under strong convexity. `C = Identity` (δ = 1)
/// recovers exact gradient descent: θ = 1, β = 0, γ = 1/L.
///
/// EF-BV (Condat et al., 2022) tightens these constants with a second
/// (η, β̃) characterization of the compressor class; the δ-only form here
/// is its conservative specialization, which every in-tree compressor can
/// supply through [`crate::compressors::Compressor::delta`].
pub fn ef_uplink(p: &dyn Problem, delta: &[f64]) -> StepSizes {
    let n = p.n_workers() as f64;
    assert_eq!(delta.len(), p.n_workers());
    let dmin = delta.iter().fold(1.0f64, |a, &b| a.min(b)).clamp(0.0, 1.0);
    assert!(
        dmin > 0.0,
        "the EF uplink needs contractive compressors (δ > 0); δ_min = {dmin}"
    );
    let theta = 1.0 - (1.0 - dmin).sqrt();
    let beta = (1.0 - dmin) / theta;
    let l_tilde = ((0..p.n_workers()).map(|i| p.l_i(i) * p.l_i(i)).sum::<f64>() / n).sqrt();
    let gamma = 1.0 / (p.l() + l_tilde * (beta / theta).sqrt());
    StepSizes {
        gamma,
        alpha: 0.0,
        eta: 0.0,
        m: 0.0,
        rate: (1.0 - gamma * p.mu()).max(1.0 - theta / 2.0),
    }
}

// ---------------------------------------------------------------- Theorem 5

/// GDCI (Theorem 5):
/// `η ≤ [L/μ + (2ω/n)(L_max/μ − 1)]⁻¹`,
/// `γ ≤ (1 + 2ηω/n) / (η (L + 2 L_max ω/n))`.
/// Converges linearly (rate 1−η) to a neighborhood
/// `η (2ω/n) (1/n) Σ ‖x* − γ∇f_i(x*)‖²`.
pub fn gdci(p: &dyn Problem, omega: f64) -> StepSizes {
    let n = p.n_workers() as f64;
    let (l, mu, lmax) = (p.l(), p.mu(), p.l_max());
    let eta = 1.0 / (l / mu + (2.0 * omega / n) * (lmax / mu - 1.0));
    let gamma = (1.0 + 2.0 * eta * omega / n) / (eta * (l + 2.0 * lmax * omega / n));
    StepSizes {
        gamma,
        alpha: 0.0,
        eta,
        m: 0.0,
        rate: 1.0 - eta,
    }
}

/// The GDCI neighborhood radius: `η·(2ω/n)·(1/n)Σ‖x* − γ∇f_i(x*)‖²`.
pub fn gdci_neighborhood(p: &dyn Problem, omega: f64, gamma: f64, eta: f64) -> f64 {
    let n = p.n_workers();
    let d = p.dim();
    let mut acc = 0.0;
    let x_star = p.x_star();
    for i in 0..n {
        let gs = p.grad_star(i);
        let mut t = 0.0;
        for j in 0..d {
            let v = x_star[j] - gamma * gs[j];
            t += v * v;
        }
        acc += t;
    }
    eta * (2.0 * omega / n as f64) * acc / n as f64
}

// ---------------------------------------------------------------- Theorem 6

/// VR-GDCI (Theorem 6): `α ≤ 1/(ω+1)`,
/// `η = [L/μ + (6ω/n)(L_max/μ − 1)]⁻¹`,
/// `γ ≤ (1 + 6ωη/n)/(η(L + 6 L_max ω/n))`,
/// rate `1 − min{α/2, η}` — exact convergence.
pub fn vr_gdci(p: &dyn Problem, omega: f64) -> StepSizes {
    let n = p.n_workers() as f64;
    let (l, mu, lmax) = (p.l(), p.mu(), p.l_max());
    let alpha = 1.0 / (omega + 1.0);
    let eta = 1.0 / (l / mu + (6.0 * omega / n) * (lmax / mu - 1.0));
    let gamma = (1.0 + 6.0 * omega * eta / n) / (eta * (l + 6.0 * lmax * omega / n));
    StepSizes {
        gamma,
        alpha,
        eta,
        m: 4.0 * eta * eta * omega / (alpha * n),
        rate: 1.0 - (alpha / 2.0).min(eta),
    }
}

// ------------------------------------------------------------------ Table 1

/// Iteration complexities (Õ, dropping log 1/ε) from Table 1, in the
/// simplified regime ω_i ≡ ω, δ_i ≡ δ, L_i ≡ L, p_i ≡ p.
#[derive(Clone, Copy, Debug)]
pub struct Complexity {
    pub ours: f64,
    /// best previously known (NaN when the method is new in this paper)
    pub previous: f64,
}

pub fn table1_complexities(
    kappa: f64,
    omega: f64,
    delta: f64,
    p_refresh: f64,
    n: usize,
) -> Vec<(&'static str, Complexity)> {
    let n = n as f64;
    vec![
        (
            "DCGD-FIXED",
            Complexity {
                ours: kappa * (1.0 + omega / n),
                previous: f64::NAN,
            },
        ),
        (
            "DCGD-STAR",
            Complexity {
                ours: kappa * (1.0 + omega / n * (1.0 - delta)),
                previous: f64::NAN,
            },
        ),
        (
            "DIANA",
            Complexity {
                ours: (kappa * (1.0 + omega / n * (1.0 - delta))).max(omega * (1.0 - delta)),
                previous: (kappa * (1.0 + omega / n)).max(omega),
            },
        ),
        (
            "RAND-DIANA",
            Complexity {
                ours: (kappa * (1.0 + omega / n * (1.0 - delta))).max(1.0 / p_refresh),
                previous: f64::NAN,
            },
        ),
        (
            "GDCI",
            Complexity {
                ours: kappa * (1.0 + omega / n),
                previous: kappa * kappa * (1.0 + omega / n),
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{Quadratic, Ridge};

    fn prob() -> Quadratic {
        Quadratic::random(10, 4, 1.0, 20.0, 1)
    }

    #[test]
    fn theorem1_gamma_formula() {
        let p = prob();
        let omega = vec![4.0; 4];
        let ss = dcgd_fixed(&p, &omega);
        let max_lw = (0..4).map(|i| p.l_i(i) * 4.0).fold(0.0, f64::max);
        let expect = 1.0 / (p.l() + 2.0 * max_lw / 4.0);
        assert!((ss.gamma - expect).abs() < 1e-15);
        assert!(ss.rate < 1.0 && ss.rate > 0.0);
    }

    #[test]
    fn star_beats_fixed_gamma() {
        // (1−δ) < 1 plus the missing factor 2 ⇒ STAR's γ is larger.
        let p = prob();
        let omega = vec![9.0; 4];
        let delta = vec![0.5; 4];
        let fixed = dcgd_fixed(&p, &omega);
        let star = dcgd_star(&p, &omega, &delta);
        assert!(star.gamma > fixed.gamma);
        assert!(star.rate < fixed.rate);
    }

    #[test]
    fn diana_alpha_and_m_satisfy_constraints() {
        let p = prob();
        let omega = vec![9.0; 4];
        let delta = vec![0.0; 4];
        let ss = diana(&p, &omega, &delta, 2.0);
        assert!((ss.alpha - 0.1).abs() < 1e-12); // 1/(1+9)
        // M > B/ρ = (2/n)/α
        assert!(ss.m > (2.0 / 4.0) / ss.alpha);
        assert!(ss.rate < 1.0);
        // biased C with δ=0.5 improves α and rate
        let ss2 = diana(&p, &omega, &vec![0.5; 4], 2.0);
        assert!(ss2.alpha > ss.alpha);
        assert!(ss2.gamma >= ss.gamma);
    }

    #[test]
    fn rand_diana_matches_paper_formulas() {
        let p = prob();
        let omega = 9.0;
        let pr = rand_diana_default_p(omega);
        assert!((pr - 0.1).abs() < 1e-12);
        let probs = vec![pr; 4];
        let ss = rand_diana(&p, omega, &probs, None);
        let n = 4.0;
        let m = 4.0 * omega / (n * pr);
        assert!((ss.m - m).abs() < 1e-12);
        let max_pl = (0..4).map(|i| pr * p.l_i(i)).fold(0.0, f64::max);
        let expect_gamma = 1.0 / ((1.0 + 2.0 * omega / n) * p.l_max() + m * max_pl);
        assert!((ss.gamma - expect_gamma).abs() < 1e-15);
        // second rate: 1 − p + 2ω/(nM) = 1 − p + p/2 < 1
        assert!((ss.rate - (1.0 - ss.gamma * p.mu()).max(1.0 - pr / 2.0)).abs() < 1e-12);
    }

    #[test]
    fn rand_diana_m_below_mprime_flagged_by_rate() {
        // M < M' = 2ω/(np) ⇒ σ-rate ≥ 1: no contraction guarantee.
        let p = prob();
        let omega = 9.0;
        let probs = vec![0.1; 4];
        let m_prime = 2.0 * omega / (4.0 * 0.1);
        let ss = rand_diana(&p, omega, &probs, Some(0.5 * m_prime));
        assert!(ss.rate >= 1.0, "rate {} should signal instability", ss.rate);
    }

    #[test]
    fn ef_uplink_identity_recovers_exact_gd() {
        // δ = 1 ⇒ θ = 1, β = 0 ⇒ γ = 1/L, and the rate is the GD rate
        let p = prob();
        let ss = ef_uplink(&p, &vec![1.0; 4]);
        assert!((ss.gamma - 1.0 / p.l()).abs() < 1e-15);
        assert!((ss.rate - (1.0 - ss.gamma * p.mu())).abs() < 1e-12);
    }

    #[test]
    fn ef_uplink_gamma_shrinks_with_contraction() {
        // harsher compression (smaller δ) must not enlarge the step
        let p = prob();
        let mut prev = f64::INFINITY;
        for &delta in &[1.0, 0.5, 0.1, 0.01] {
            let ss = ef_uplink(&p, &vec![delta; 4]);
            assert!(ss.gamma > 0.0 && ss.gamma <= prev + 1e-18, "δ = {delta}");
            assert!(ss.rate < 1.0, "δ = {delta}: rate {} must contract", ss.rate);
            prev = ss.gamma;
        }
        // the minimum δ across a heterogeneous fleet governs
        let hom = ef_uplink(&p, &vec![0.1; 4]);
        let het = ef_uplink(&p, &[0.9, 0.5, 0.1, 1.0]);
        assert!((hom.gamma - het.gamma).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "contractive")]
    fn ef_uplink_rejects_non_contractive() {
        let p = prob();
        let _ = ef_uplink(&p, &vec![0.0; 4]);
    }

    #[test]
    fn gdci_step_sizes_positive_and_rate_sane() {
        let p = Ridge::paper_default(0);
        let ss = gdci(&p, 9.0);
        assert!(ss.eta > 0.0 && ss.eta < 1.0);
        assert!(ss.gamma > 0.0);
        assert!(ss.rate < 1.0);
        let radius = gdci_neighborhood(&p, 9.0, ss.gamma, ss.eta);
        assert!(radius > 0.0, "non-interpolating ⇒ nonzero neighborhood");
    }

    #[test]
    fn vr_gdci_removes_neighborhood_with_sane_rates() {
        let p = Ridge::paper_default(0);
        let ss = vr_gdci(&p, 9.0);
        assert!(ss.alpha <= 1.0 / 10.0 + 1e-12);
        assert!(ss.eta > 0.0 && ss.gamma > 0.0);
        assert!(ss.rate < 1.0);
    }

    #[test]
    fn table1_orderings() {
        let t = table1_complexities(100.0, 9.0, 0.5, 0.1, 10);
        let get = |name: &str| t.iter().find(|(n, _)| *n == name).unwrap().1;
        // STAR ≤ FIXED
        assert!(get("DCGD-STAR").ours <= get("DCGD-FIXED").ours);
        // our DIANA ≤ previous DIANA
        let d = get("DIANA");
        assert!(d.ours <= d.previous);
        // our GDCI improves κ² → κ
        let g = get("GDCI");
        assert!(g.ours < g.previous);
        assert!((g.previous / g.ours - 100.0).abs() < 1e-9);
    }

    #[test]
    fn iteration_count_scales_with_rate() {
        let fast = StepSizes {
            gamma: 0.0,
            alpha: 0.0,
            eta: 0.0,
            m: 0.0,
            rate: 0.9,
        };
        let slow = StepSizes {
            rate: 0.99,
            ..fast
        };
        assert!(slow.iters_for(1e-6) > 5.0 * fast.iters_for(1e-6));
    }
}
