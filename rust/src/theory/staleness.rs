//! Bounded-staleness corrections for the semi-async coordinator.
//!
//! When the gather closes on a quorum of m < n arrivals, the tail
//! workers' frames land **after** the round advanced the iterate — they
//! are folded into round k+1 as one-round-stale gradients. A gradient
//! evaluated at `x^{k−τ}` and applied at `x^k` perturbs the descent
//! direction by at most `L · Σ_{j=k−τ}^{k−1} ‖x^{j+1} − x^j‖`, and the
//! classical delayed-gradient analyses (asynchronous SGD with bounded
//! delay) absorb that perturbation by shrinking the step:
//!
//! ```text
//! γ(τ) ≤ γ(0) / (1 + 2τ)
//! ```
//!
//! where `γ(0)` is the synchronous step of the underlying method and τ
//! the worst-case staleness admitted by the runner (τ = 1 for the
//! quorum-gather: a frame is either fresh or exactly one round late —
//! older frames are discarded). On top of the step-size rule, a stale
//! fold is **damped** by [`damping`]`(τ) = 1/(1 + τ)` so a
//! perpetually-late worker contributes a convex fraction of its weight
//! instead of double-counting against the fresh quorum.
//!
//! Both rules are conservative specializations: the semi-async runner
//! only ever produces τ ∈ {0, 1}, and τ = 0 recovers the synchronous
//! constants exactly (pinned in the tests below).

use super::{dcgd_fixed, StepSizes};
use crate::problems::Problem;

/// The stale-fold damping factor `λ(τ) = 1/(1 + τ)`: a fresh frame
/// (τ = 0) folds at full weight, a one-round-late frame at half weight.
/// Multiplies the estimator's `1/|R|` fold weight for the stale member
/// of the reporting set.
pub fn damping(tau: usize) -> f64 {
    1.0 / (1.0 + tau as f64)
}

/// DCGD with fixed shifts under bounded staleness τ: the Theorem-1 step
/// `γ(0) ≤ 1/(L + 2 max_i(L_i ω_i)/n)` shrinks by the delayed-gradient
/// factor `1 + 2τ`,
///
/// ```text
/// γ(τ) = γ(0) / (1 + 2τ),
/// ```
///
/// and the linear rate bound becomes `1 − γ(τ)μ`. `τ = 0` is exactly
/// [`dcgd_fixed`].
pub fn dcgd_delayed(p: &dyn Problem, omega: &[f64], tau: usize) -> StepSizes {
    let base = dcgd_fixed(p, omega);
    let gamma = base.gamma / (1.0 + 2.0 * tau as f64);
    StepSizes {
        gamma,
        alpha: 0.0,
        eta: 0.0,
        m: 0.0,
        rate: 1.0 - gamma * p.mu(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::Quadratic;

    fn prob() -> Quadratic {
        Quadratic::random(10, 4, 1.0, 20.0, 1)
    }

    #[test]
    fn zero_staleness_recovers_the_synchronous_rule() {
        let p = prob();
        let omega = vec![4.0; 4];
        let sync = dcgd_fixed(&p, &omega);
        let stale = dcgd_delayed(&p, &omega, 0);
        assert!((stale.gamma - sync.gamma).abs() < 1e-15);
        assert!((stale.rate - sync.rate).abs() < 1e-15);
    }

    #[test]
    fn gamma_shrinks_by_one_plus_two_tau() {
        let p = prob();
        let omega = vec![4.0; 4];
        let sync = dcgd_fixed(&p, &omega);
        let mut prev = f64::INFINITY;
        for tau in 0..4 {
            let ss = dcgd_delayed(&p, &omega, tau);
            let expect = sync.gamma / (1.0 + 2.0 * tau as f64);
            assert!((ss.gamma - expect).abs() < 1e-15, "τ = {tau}");
            assert!(ss.gamma < prev, "γ must shrink with τ");
            assert!(ss.rate < 1.0 && ss.rate > 0.0, "τ = {tau}: rate {}", ss.rate);
            prev = ss.gamma;
        }
    }

    #[test]
    fn damping_is_convex_and_halves_at_one_round() {
        assert!((damping(0) - 1.0).abs() < 1e-15);
        assert!((damping(1) - 0.5).abs() < 1e-15);
        assert!((damping(3) - 0.25).abs() < 1e-15);
        for tau in 0..16 {
            let l = damping(tau);
            assert!(l > 0.0 && l <= 1.0);
            assert!(l >= damping(tau + 1));
        }
    }
}
