//! Shared copy-on-write iterate: Arc snapshots + per-worker sparse overlays.
//!
//! Pre-refactor, every worker thread owned a private dense `Vec<f64>` mirror
//! of the iterate and replayed the downlink frame stream against it, so a
//! fleet of `n` workers paid `n * d * 8` bytes for state that is — on the
//! exact downlink path — bit-identical by construction. This module is the
//! replacement: the master publishes each round's post-step iterate **once**
//! as an immutable [`Arc`] snapshot, and the only per-round divergence a
//! replica is allowed to have (the EF-downlink invariant
//! `x_replica + e = x_master`) travels as a sparse [`OverlayPatch`] over that
//! snapshot. Fleet replica memory is `O(d + overlay nnz)` instead of
//! `O(n * d)`.
//!
//! Three pieces:
//!
//! - [`OverlayPatch`] — a sparse `(index, value)` patch. The master rebuilds
//!   it from the EF-downlink error accumulator after each fold
//!   (`value[j] = -e[j]` on the nonzero support of `e`), so
//!   `snapshot + patch` *is* the logical replica `x_master - e`. On the exact
//!   downlink path the accumulator does not exist and the patch is pinned
//!   empty.
//! - [`SnapshotPublisher`] — the master-side double buffer. Like the
//!   runner's `down_bufs`, it rotates two [`Arc`] slots (snapshot + patch)
//!   with [`Arc::get_mut`] in-place reuse, so steady-state publication is
//!   allocation-free; a quarantined worker pinning an old generation costs
//!   one fallback allocation, after which the rotation detaches from it.
//!   Every publication carries a monotonically increasing **generation** so
//!   a worker can detect a missed rotation (see [`ReplicaOverlay::install`]).
//! - [`ReplicaOverlay`] — the worker-side handle: retained snapshot `Arc`,
//!   retained patch `Arc`, and the generation both were published under.
//!   [`ReplicaOverlay::view`] is the zero-alloc read path the gradient
//!   oracle consumes: it borrows the snapshot directly when the patch is
//!   empty (exact path — zero copies, zero worker-private bytes) and
//!   materializes `snapshot + patch` into a caller-provided scratch
//!   otherwise.
//!
//! Bit-identity note: `-0.0 + 0.0 == +0.0`, so a dense `x - e` loop does
//! *not* reproduce `x` at coordinates where `e` is zero with the opposite
//! sign convention. Every consumer — worker view, master mirror,
//! `Inspect` reconstruction — therefore materializes through the one
//! algorithm in [`materialize_into`]: copy the snapshot, then add patch
//! values only at the patch's support. Master and workers see the same
//! bits because they run the same code on the same two buffers.

use std::sync::Arc;

/// Sparse divergence of a logical replica from the published snapshot.
///
/// Stores `(index, value)` pairs in ascending index order; the logical
/// replica is `snapshot[j] + value` at each stored index `j` and
/// `snapshot[j]` everywhere else. Under the EF downlink the patch holds
/// `-e` restricted to the nonzero support of the error accumulator `e`;
/// on the exact path it is empty.
#[derive(Clone, Debug, Default)]
pub struct OverlayPatch {
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl OverlayPatch {
    /// An empty patch (logical replica == snapshot).
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of patched coordinates.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// True when the logical replica equals the snapshot bit-for-bit.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Resident bytes of the patch payload (4-byte index + 8-byte value
    /// per entry).
    pub fn bytes(&self) -> u64 {
        (self.idx.len() * 4 + self.val.len() * 8) as u64
    }

    /// The stored `(index, value)` pairs in ascending index order
    /// (read-only; used by the debug-build invariant audits to check the
    /// patch support against the EF residual).
    pub fn entries(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.idx.iter().zip(self.val.iter()).map(|(&j, &v)| (j as usize, v))
    }

    /// Drop every entry (replica collapses back onto the snapshot).
    ///
    /// This is the overlay half of a resync: flushing the EF-downlink
    /// accumulator zeroes `e`, and the corresponding patch is empty.
    pub fn clear(&mut self) {
        self.idx.clear();
        self.val.clear();
    }

    /// Rebuild the patch as `-e` on the nonzero support of the EF error
    /// accumulator `e`, reusing the existing entry capacity.
    ///
    /// Exact zeros are skipped — after `e -= c` the repacked compressed
    /// coordinates cancel exactly, so the support (and hence the patch)
    /// is bounded by the compressor's *residual* support. The entry
    /// vectors are reserved to the full dimension on first use: the
    /// residual support varies round to round, and a mid-run capacity
    /// ratchet would break the steady-state zero-allocation contract the
    /// counting-allocator tests pin.
    pub fn rebuild_from_error(&mut self, e: &[f64]) {
        self.idx.clear();
        self.val.clear();
        self.idx.reserve(e.len());
        self.val.reserve(e.len());
        for (j, &ej) in e.iter().enumerate() {
            if ej != 0.0 {
                self.idx.push(j as u32);
                self.val.push(-ej);
            }
        }
    }

    /// Copy `other`'s entries into `self`, reusing capacity.
    pub fn clone_from_patch(&mut self, other: &OverlayPatch) {
        self.idx.clear();
        self.val.clear();
        self.idx.extend_from_slice(&other.idx);
        self.val.extend_from_slice(&other.val);
    }

    /// Ensure capacity for `n` entries (starting from empty). The
    /// publisher calls this with the full dimension before the first
    /// non-empty copy so the slot never re-ratchets as the EF residual
    /// support drifts round to round.
    pub fn reserve(&mut self, n: usize) {
        self.idx.reserve(n);
        self.val.reserve(n);
    }

    /// Add the patch into `out` (`out[idx] += val` at each entry).
    ///
    /// This is the single shared patch-application kernel: every
    /// materialization site goes through it so master-side mirrors and
    /// worker-side views agree bit-for-bit.
    pub fn apply(&self, out: &mut [f64]) {
        for (i, &j) in self.idx.iter().enumerate() {
            out[j as usize] += self.val[i];
        }
    }

    /// Add the patch entries with index in `[lo, hi)` into `sub`, where
    /// `sub` is the `[lo, hi)` window of the full output vector (so the
    /// write lands at `sub[idx − lo]`).
    ///
    /// The coordinate-range form of [`OverlayPatch::apply`] for sharded
    /// materialization: the entries are stored in ascending index order,
    /// so each range is one `partition_point` pair away, every entry is
    /// applied by exactly one shard, and the per-coordinate operation is
    /// the same single `+=` the serial kernel performs — bit-identical
    /// for any sharding.
    pub fn apply_range(&self, lo: usize, hi: usize, sub: &mut [f64]) {
        let a = self.idx.partition_point(|&j| (j as usize) < lo);
        let b = self.idx.partition_point(|&j| (j as usize) < hi);
        for i in a..b {
            sub[self.idx[i] as usize - lo] += self.val[i];
        }
    }
}

/// Materialize the logical replica `base + patch` into `out`, resizing
/// `out` to `base.len()` if needed (no-op on a warm buffer).
///
/// The one algorithm every consumer uses: copy the snapshot, then add
/// patch values at the patch support only. See the module docs for why a
/// dense `x - e` loop is not an acceptable substitute.
pub fn materialize_into(base: &[f64], patch: &OverlayPatch, out: &mut Vec<f64>) {
    if out.len() != base.len() {
        out.resize(base.len(), 0.0);
    }
    out.copy_from_slice(base);
    patch.apply(out);
}

/// Master-side double-buffered snapshot + overlay publisher.
///
/// Two `Arc` slots per payload rotate by generation parity, mirroring the
/// runner's `down_bufs` discipline: by the time generation `g` is
/// published, every active worker has installed generation `g - 1` and
/// released the slot `g` occupies, so [`Arc::get_mut`] reuses it in place.
/// A worker that stopped draining commands (quarantine, crash) pins its
/// slot once; publication then falls back to a single fresh allocation and
/// the rotation continues without it.
#[derive(Debug)]
pub struct SnapshotPublisher {
    snaps: [Arc<Vec<f64>>; 2],
    patches: [Arc<OverlayPatch>; 2],
    gen: u64,
}

impl SnapshotPublisher {
    /// A publisher for `d`-dimensional iterates. Both snapshot slots are
    /// pre-sized so the first two publications are already in-place.
    pub fn new(d: usize) -> Self {
        Self {
            snaps: [Arc::new(vec![0.0; d]), Arc::new(vec![0.0; d])],
            patches: [Arc::new(OverlayPatch::new()), Arc::new(OverlayPatch::new())],
            gen: 0,
        }
    }

    /// Generation of the most recent publication (0 = nothing published).
    pub fn gen(&self) -> u64 {
        self.gen
    }

    /// Publish `x` (and the current overlay patch) as the next generation,
    /// returning `(gen, snapshot, patch)` handles to broadcast.
    ///
    /// Allocation-free once warm: the parity slot is reused via
    /// [`Arc::get_mut`] whenever no worker still pins it.
    pub fn publish(
        &mut self,
        x: &[f64],
        overlay: &OverlayPatch,
    ) -> (u64, Arc<Vec<f64>>, Arc<OverlayPatch>) {
        self.gen += 1;
        let slot = (self.gen % 2) as usize;
        match Arc::get_mut(&mut self.snaps[slot]) {
            Some(buf) => {
                if buf.len() != x.len() {
                    buf.resize(x.len(), 0.0);
                }
                buf.copy_from_slice(x);
            }
            None => self.snaps[slot] = Arc::new(x.to_vec()),
        }
        match Arc::get_mut(&mut self.patches[slot]) {
            Some(p) => {
                // full-dimension reserve (no-op on the exact path, where
                // the overlay is pinned empty): the EF residual support
                // drifts, and a mid-run capacity ratchet would violate the
                // steady-state allocation contract
                if !overlay.is_empty() {
                    p.clear();
                    p.reserve(x.len());
                }
                p.clone_from_patch(overlay);
            }
            None => self.patches[slot] = Arc::new(overlay.clone()),
        }
        (self.gen, self.snaps[slot].clone(), self.patches[slot].clone())
    }

    /// Resident bytes of both snapshot slots (the fleet-shared iterate
    /// storage; independent of the number of workers).
    pub fn snapshot_bytes(&self) -> u64 {
        (self.snaps[0].len() * 8 + self.snaps[1].len() * 8) as u64
    }

    /// Resident bytes of both overlay-patch slots.
    pub fn patch_bytes(&self) -> u64 {
        self.patches[0].bytes() + self.patches[1].bytes()
    }
}

/// Worker-side handle to the shared iterate: the retained snapshot `Arc`,
/// the retained overlay patch `Arc`, and the generation both were
/// published under.
///
/// This replaces the worker's private dense `Vec<f64>` replica. The worker
/// installs the handles that arrive with each round command, checks
/// generation continuity (a delta-framed round must carry `last_gen + 1`;
/// a gap means a rotation was missed and the worker must request a resync
/// instead of silently computing against a stale base), and reads the
/// logical replica through [`ReplicaOverlay::view`].
#[derive(Clone, Debug)]
pub struct ReplicaOverlay {
    gen: u64,
    snap: Arc<Vec<f64>>,
    patch: Arc<OverlayPatch>,
}

impl Default for ReplicaOverlay {
    fn default() -> Self {
        Self::empty()
    }
}

impl ReplicaOverlay {
    /// A handle with nothing installed (generation 0, empty snapshot).
    pub fn empty() -> Self {
        Self {
            gen: 0,
            snap: Arc::new(Vec::new()),
            patch: Arc::new(OverlayPatch::new()),
        }
    }

    /// Install a freshly published `(gen, snapshot, patch)` triple,
    /// releasing the previously retained slot so the master's double
    /// buffer can reuse it.
    pub fn install(&mut self, gen: u64, snap: Arc<Vec<f64>>, patch: Arc<OverlayPatch>) {
        self.gen = gen;
        self.snap = snap;
        self.patch = patch;
    }

    /// Generation of the installed snapshot (0 = nothing installed).
    pub fn gen(&self) -> u64 {
        self.gen
    }

    /// Dimension of the installed snapshot.
    pub fn len(&self) -> usize {
        self.snap.len()
    }

    /// True when no snapshot has been installed yet.
    pub fn is_empty(&self) -> bool {
        self.snap.is_empty()
    }

    /// Number of overlay entries this replica currently carries.
    pub fn overlay_nnz(&self) -> usize {
        self.patch.nnz()
    }

    /// Zero-alloc view of the logical replica for the gradient oracle.
    ///
    /// When the patch is empty (exact downlink path) this borrows the
    /// shared snapshot directly — no copy, no worker-private bytes. When
    /// the patch is non-empty (EF downlink) it materializes
    /// `snapshot + patch` into `scratch` via [`materialize_into`] and
    /// borrows that; `scratch` is caller-owned and reused across rounds,
    /// so the only allocation is its one-time warm-up growth.
    pub fn view<'a>(&'a self, scratch: &'a mut Vec<f64>) -> &'a [f64] {
        if self.patch.is_empty() {
            &self.snap
        } else {
            materialize_into(&self.snap, &self.patch, scratch);
            scratch
        }
    }

    /// Materialize the logical replica into `out` unconditionally (used
    /// to boot the local-step iterate, which is mutated in place and so
    /// cannot borrow the shared snapshot).
    pub fn materialize_into_buf(&self, out: &mut Vec<f64>) {
        materialize_into(&self.snap, &self.patch, out);
    }

    /// Materialize the logical replica into a fresh vector (test /
    /// `Inspect` path — allocation is fine off the hot loop).
    pub fn materialize(&self) -> Vec<f64> {
        let mut out = Vec::new();
        materialize_into(&self.snap, &self.patch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_patch_view_borrows_the_snapshot() {
        let mut publ = SnapshotPublisher::new(4);
        let overlay = OverlayPatch::new();
        let x = [1.0, -2.0, 3.0, 0.5];
        let (gen, snap, patch) = publ.publish(&x, &overlay);
        assert_eq!(gen, 1);
        let mut rep = ReplicaOverlay::empty();
        rep.install(gen, snap, patch);
        let mut scratch = Vec::new();
        let view = rep.view(&mut scratch);
        assert_eq!(view, &x[..]);
        // Exact path: the view is the shared buffer, the scratch never grew.
        assert_eq!(scratch.capacity(), 0);
    }

    #[test]
    fn overlay_patch_tracks_the_error_support_and_applies_additively() {
        let e = [0.0, 0.25, 0.0, -1.5, 0.0];
        let mut patch = OverlayPatch::new();
        patch.rebuild_from_error(&e);
        assert_eq!(patch.nnz(), 2);
        let base = [1.0, 1.0, 1.0, 1.0, 1.0];
        let mut out = Vec::new();
        materialize_into(&base, &patch, &mut out);
        assert_eq!(out, vec![1.0, 0.75, 1.0, 2.5, 1.0]);
        patch.clear();
        assert!(patch.is_empty());
        materialize_into(&base, &patch, &mut out);
        assert_eq!(out, base.to_vec());
    }

    #[test]
    fn negative_zero_error_coords_do_not_perturb_the_snapshot() {
        // A dense `x - e` loop would turn x[j] into x[j] - (-0.0) at a
        // negative-zero accumulator coordinate, which is fine, but the
        // reverse composition (+ -0.0 onto +0.0) flips signs under naive
        // subtraction orderings. The support-only patch sidesteps the
        // whole class: -0.0 != 0.0 is false, so the coordinate is skipped
        // and the snapshot bits pass through untouched.
        let e = [-0.0, 2.0];
        let mut patch = OverlayPatch::new();
        patch.rebuild_from_error(&e);
        assert_eq!(patch.nnz(), 1);
        let base = [0.0f64, 1.0];
        let mut out = Vec::new();
        materialize_into(&base, &patch, &mut out);
        assert_eq!(out[0].to_bits(), 0.0f64.to_bits());
        assert_eq!(out[1], -1.0);
    }

    #[test]
    fn publisher_rotates_generations_and_reuses_released_slots() {
        let mut publ = SnapshotPublisher::new(3);
        let overlay = OverlayPatch::new();
        let mut rep = ReplicaOverlay::empty();
        let mut slot_ptrs: [*const f64; 2] = [std::ptr::null(), std::ptr::null()];
        for k in 0..6u64 {
            let x = [k as f64, 1.0, 2.0];
            let (gen, snap, patch) = publ.publish(&x, &overlay);
            assert_eq!(gen, k + 1);
            let slot = (gen % 2) as usize;
            // Installing generation g releases the slot generation g − 1
            // occupied, so after warm-up each parity slot is reused in
            // place: its buffer pointer is stable across publications.
            if k >= 2 {
                assert_eq!(snap.as_ptr(), slot_ptrs[slot]);
            }
            slot_ptrs[slot] = snap.as_ptr();
            rep.install(gen, snap, patch);
            assert_eq!(rep.gen(), gen);
            let mut scratch = Vec::new();
            assert_eq!(rep.view(&mut scratch)[0], k as f64);
        }
        assert_eq!(publ.snapshot_bytes(), 2 * 3 * 8);
    }

    #[test]
    fn pinned_slot_falls_back_to_a_fresh_allocation() {
        let mut publ = SnapshotPublisher::new(2);
        let overlay = OverlayPatch::new();
        let (_, pinned, _) = publ.publish(&[1.0, 2.0], &overlay);
        // A quarantined worker never installs past this generation; the
        // slot it pins must not be overwritten under it.
        let _hold = pinned.clone();
        let _ = publ.publish(&[3.0, 4.0], &overlay); // other slot, in place
        let (_, fresh, _) = publ.publish(&[5.0, 6.0], &overlay); // pinned slot: realloc
        assert_eq!(*pinned, vec![1.0, 2.0]);
        assert_eq!(*fresh, vec![5.0, 6.0]);
    }
}
