//! Deterministic fault injection for the threaded coordinator.
//!
//! A [`FaultPlan`] is a seeded, reproducible schedule of worker faults:
//! crash-at-round, garbage uplink frames, corrupted downlink bytes, and
//! straggler windows. The plan is compiled per worker into a
//! [`WorkerFaultScript`] that the worker loop consults at fixed points of
//! its round — so every failure path of
//! [`crate::coordinator::DistributedRunner`] (crash, timeout, protocol
//! defect) is exercisable on purpose, with the same seed producing the
//! same fault sequence on every run.
//!
//! Fault semantics, chosen so the surviving fleet stays bit-identical to a
//! degraded single-process mirror wherever the theory allows it:
//!
//! * [`FaultKind::Crash`] — the worker thread exits silently at the start
//!   of the given round, before any gradient or RNG draw. The master sees
//!   a gather timeout (and, on a later send, a disconnected channel).
//! * [`FaultKind::Straggle`] — for `rounds` consecutive rounds the worker
//!   consumes its command but performs **no** processing: no downlink
//!   apply, no gradient, no RNG draw, no reply. Its local state is frozen,
//!   which is exactly what the dense-resync rejoin path repairs.
//! * [`FaultKind::GarbageUplink`] — the worker computes the round normally
//!   (RNG advanced, shift updated) but corrupts its encoded Q-frame before
//!   sending. The master's decode rejects the frame and quarantines the
//!   worker as a protocol defect. Because local state has already advanced,
//!   this fault is *not* bit-identity-safe — it exists to exercise the
//!   master's malformed-frame path.
//! * [`FaultKind::CorruptDownlink`] — the worker corrupts its own copy of
//!   the broadcast bytes before decoding, detects the defect, reports a
//!   [`crate::coordinator::WorkerFailure`] and exits — the organic
//!   worker-reported protocol failure, injected deterministically (before
//!   any compute or RNG draw, so survivors keep bit-identity).

use crate::util::rng::Pcg64;

/// RNG stream tag for [`FaultPlan::seeded`] (disjoint from the runner's
/// `0xa160` root and its derived worker streams).
const FAULT_STREAM: u64 = 0xfa17;

/// One kind of injected fault, anchored at a round index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Thread exits silently at the start of `round`.
    Crash { round: usize },
    /// Q-frame bytes corrupted after a normal round's compute at `round`.
    GarbageUplink { round: usize },
    /// Worker-local downlink bytes corrupted at `round`; the worker
    /// reports the decode defect and exits.
    CorruptDownlink { round: usize },
    /// For `rounds` rounds starting at `round`, consume commands without
    /// processing or replying.
    Straggle { round: usize, rounds: usize },
}

/// A fault bound to a worker index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub worker: usize,
    pub kind: FaultKind,
}

/// A reproducible schedule of worker faults (see the module doc).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// An empty plan (no faults).
    pub fn new() -> Self {
        Self::default()
    }

    /// Crash `worker`'s thread at the start of `round`.
    pub fn crash(mut self, worker: usize, round: usize) -> Self {
        self.faults.push(FaultSpec {
            worker,
            kind: FaultKind::Crash { round },
        });
        self
    }

    /// Corrupt `worker`'s uplink Q-frame at `round`.
    pub fn garbage_uplink(mut self, worker: usize, round: usize) -> Self {
        self.faults.push(FaultSpec {
            worker,
            kind: FaultKind::GarbageUplink { round },
        });
        self
    }

    /// Corrupt `worker`'s local copy of the `round` broadcast.
    pub fn corrupt_downlink(mut self, worker: usize, round: usize) -> Self {
        self.faults.push(FaultSpec {
            worker,
            kind: FaultKind::CorruptDownlink { round },
        });
        self
    }

    /// Freeze `worker` for `rounds` rounds starting at `round`.
    pub fn straggle(mut self, worker: usize, round: usize, rounds: usize) -> Self {
        self.faults.push(FaultSpec {
            worker,
            kind: FaultKind::Straggle { round, rounds },
        });
        self
    }

    /// A seeded random plan over an `n`-worker fleet and a `horizon` of
    /// rounds: each worker except worker 0 (kept clean so the fleet always
    /// has a survivor) draws one fault with probability 1/2, with a kind
    /// and round chosen from the plan's own RNG stream. Deterministic for
    /// a given `(seed, n, horizon)`.
    pub fn seeded(seed: u64, n: usize, horizon: usize) -> Self {
        assert!(horizon >= 2, "fault horizon must cover at least 2 rounds");
        let mut rng = Pcg64::with_stream(seed, FAULT_STREAM);
        let mut plan = Self::new();
        for worker in 1..n {
            if !rng.bernoulli(0.5) {
                continue;
            }
            let round = 1 + rng.below(horizon as u64 - 1) as usize;
            let kind = match rng.below(4) {
                0 => FaultKind::Crash { round },
                1 => FaultKind::GarbageUplink { round },
                2 => FaultKind::CorruptDownlink { round },
                _ => FaultKind::Straggle {
                    round,
                    rounds: 1 + rng.below(3) as usize,
                },
            };
            plan.faults.push(FaultSpec { worker, kind });
        }
        plan
    }

    /// Compile the plan into one worker's script (the faults addressed to
    /// `worker`, in insertion order).
    pub fn script_for(&self, worker: usize) -> WorkerFaultScript {
        WorkerFaultScript {
            faults: self
                .faults
                .iter()
                .filter(|f| f.worker == worker)
                .map(|f| f.kind)
                .collect(),
        }
    }
}

/// One worker's compiled fault schedule; queried statelessly by round so
/// the worker loop stays trivially deterministic.
#[derive(Clone, Debug, Default)]
pub struct WorkerFaultScript {
    faults: Vec<FaultKind>,
}

impl WorkerFaultScript {
    /// No faults scheduled at all (lets the worker loop skip the checks).
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Should the thread exit silently at the start of round `k`?
    pub fn crash_at(&self, k: usize) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, FaultKind::Crash { round } if *round == k))
    }

    /// Is round `k` inside a straggle window?
    pub fn straggle_at(&self, k: usize) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, FaultKind::Straggle { round, rounds }
                if *round <= k && k < round + rounds)
        })
    }

    /// Should the round-`k` Q-frame be corrupted before sending?
    pub fn garbage_uplink_at(&self, k: usize) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, FaultKind::GarbageUplink { round } if *round == k))
    }

    /// Should the worker's copy of the round-`k` broadcast be corrupted?
    pub fn corrupt_downlink_at(&self, k: usize) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, FaultKind::CorruptDownlink { round } if *round == k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_compiles_per_worker_scripts() {
        let plan = FaultPlan::new()
            .crash(2, 5)
            .straggle(1, 3, 2)
            .garbage_uplink(1, 9)
            .corrupt_downlink(3, 4);
        let s0 = plan.script_for(0);
        assert!(s0.is_empty());
        let s1 = plan.script_for(1);
        assert!(s1.straggle_at(3) && s1.straggle_at(4) && !s1.straggle_at(5));
        assert!(s1.garbage_uplink_at(9) && !s1.garbage_uplink_at(8));
        assert!(!s1.crash_at(5));
        let s2 = plan.script_for(2);
        assert!(s2.crash_at(5) && !s2.crash_at(4));
        let s3 = plan.script_for(3);
        assert!(s3.corrupt_downlink_at(4) && !s3.corrupt_downlink_at(3));
    }

    #[test]
    fn seeded_plans_are_deterministic_and_spare_worker_zero() {
        let a = FaultPlan::seeded(42, 8, 50);
        let b = FaultPlan::seeded(42, 8, 50);
        assert_eq!(a, b);
        assert!(a.faults.iter().all(|f| f.worker != 0));
        assert!(a.faults.iter().all(|f| match f.kind {
            FaultKind::Crash { round }
            | FaultKind::GarbageUplink { round }
            | FaultKind::CorruptDownlink { round }
            | FaultKind::Straggle { round, .. } => (1..50).contains(&round),
        }));
        // a different seed moves the schedule
        let c = FaultPlan::seeded(43, 8, 50);
        assert_ne!(a, c);
    }
}
