//! Master/worker threaded runtime.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::algorithms::{Algorithm, StepStats};
use crate::compressors::{Compressor, Packet, ValPrec};
use crate::coordinator::protocol::{FrameSet, MethodKind, WorkerCommand, WorkerUpdate};
use crate::linalg::{axpy, sub_into, zero};
use crate::net::{LinkModel, NetworkAccountant};
use crate::problems::Problem;
use crate::util::rng::Pcg64;
use crate::wire;

/// Cluster-level configuration.
pub struct ClusterConfig {
    pub method: MethodKind,
    pub gamma: f64,
    pub prec: ValPrec,
    pub seed: u64,
    /// per-worker link models; `None` disables the time simulation
    pub links: Option<Vec<LinkModel>>,
}

struct WorkerThread {
    cmd_tx: Sender<WorkerCommand>,
    handle: Option<JoinHandle<()>>,
}

/// The leader: owns the iterate, reconstructs worker shifts from wire
/// traffic, and drives rounds.
pub struct DistributedRunner {
    method: MethodKind,
    gamma: f64,
    prec: ValPrec,
    x: Vec<f64>,
    /// master-side reconstruction of each worker's shift
    h: Vec<Vec<f64>>,
    /// ∇f_i(x*) (STAR only — the "impractical but insightful" method
    /// assumes these are known on both ends)
    grad_star: Vec<Vec<f64>>,
    workers: Vec<WorkerThread>,
    up_rx: Receiver<WorkerUpdate>,
    pub net: Option<NetworkAccountant>,
    // scratch
    est: Vec<f64>,
    decoded: Vec<f64>,
    round: usize,
}

/// Worker-side loop: one thread per worker.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wi: usize,
    problem: Arc<dyn Problem>,
    q: Box<dyn Compressor>,
    mut c: Option<Box<dyn Compressor>>,
    method: MethodKind,
    mut h: Vec<f64>,
    mut rng: Pcg64,
    prec: ValPrec,
    cmd_rx: Receiver<WorkerCommand>,
    up_tx: Sender<WorkerUpdate>,
) {
    let d = problem.dim();
    let mut grad = vec![0.0; d];
    let mut diff = vec![0.0; d];
    let mut decoded = vec![0.0; d];

    while let Ok(cmd) = cmd_rx.recv() {
        let (k, x) = match cmd {
            WorkerCommand::Round { k, x } => (k, x),
            WorkerCommand::Shutdown => break,
        };
        problem.local_grad_into(wi, &x, &mut grad);
        let mut frames = FrameSet::default();
        let mut payload_bits = 0u64;
        let mut refresh_bits = 0u64;

        match method {
            MethodKind::Fixed => {
                sub_into(&grad, &h, &mut diff);
                let pkt = q.compress(&mut rng, &diff);
                payload_bits += pkt.payload_bits(prec);
                frames.q_frame = wire::encode(&pkt, prec);
            }
            MethodKind::Star { with_c } => {
                let gs = problem.grad_star(wi);
                if with_c {
                    let cc = c.as_mut().expect("star with_c needs a C compressor");
                    sub_into(&grad, gs, &mut diff);
                    let pkt = cc.compress(&mut rng, &diff);
                    payload_bits += pkt.payload_bits(prec);
                    // worker's own new shift
                    pkt.decode_into(&mut decoded);
                    h.copy_from_slice(gs);
                    axpy(1.0, &decoded, &mut h);
                    frames.c_frame = Some(wire::encode(&pkt, prec));
                } else {
                    h.copy_from_slice(gs);
                }
                sub_into(&grad, &h, &mut diff);
                let pkt = q.compress(&mut rng, &diff);
                payload_bits += pkt.payload_bits(prec);
                frames.q_frame = wire::encode(&pkt, prec);
            }
            MethodKind::Diana { alpha, with_c } => {
                sub_into(&grad, &h, &mut diff);
                let mut update = vec![0.0; d];
                if with_c {
                    let cc = c.as_mut().expect("diana with_c needs a C compressor");
                    let c_pkt = cc.compress(&mut rng, &diff);
                    payload_bits += c_pkt.payload_bits(prec);
                    c_pkt.decode_into(&mut decoded);
                    update.copy_from_slice(&decoded);
                    for j in 0..d {
                        diff[j] -= decoded[j];
                    }
                    frames.c_frame = Some(wire::encode(&c_pkt, prec));
                }
                let q_pkt = q.compress(&mut rng, &diff);
                payload_bits += q_pkt.payload_bits(prec);
                q_pkt.decode_into(&mut decoded);
                axpy(1.0, &decoded, &mut update);
                axpy(alpha, &update, &mut h);
                frames.q_frame = wire::encode(&q_pkt, prec);
            }
            MethodKind::RandDiana { p } => {
                sub_into(&grad, &h, &mut diff);
                let pkt = q.compress(&mut rng, &diff);
                payload_bits += pkt.payload_bits(prec);
                frames.q_frame = wire::encode(&pkt, prec);
                if rng.bernoulli(p) {
                    h.copy_from_slice(&grad);
                    refresh_bits += d as u64 * prec.bits();
                    frames.refresh = Some(wire::encode(&Packet::Dense(h.clone()), prec));
                }
            }
        }

        let wire_bytes = frames.q_frame.len()
            + frames.c_frame.as_ref().map(|f| f.len()).unwrap_or(0)
            + frames.refresh.as_ref().map(|f| f.len()).unwrap_or(0);
        if up_tx
            .send(WorkerUpdate {
                worker: wi,
                k,
                frames,
                payload_bits,
                refresh_bits,
                wire_bytes,
            })
            .is_err()
        {
            break; // master gone
        }
    }
}

impl DistributedRunner {
    /// Construct the cluster. `qs` are the per-worker Q_i compressors,
    /// `cs` the optional per-worker C_i (required when the method carries a
    /// C-frame). Shifts, RNG streams and x⁰ match
    /// [`crate::algorithms::DcgdShift`] exactly for the same seed.
    pub fn new(
        problem: Arc<dyn Problem>,
        qs: Vec<Box<dyn Compressor>>,
        cs: Option<Vec<Box<dyn Compressor>>>,
        shifts: Vec<Vec<f64>>,
        cfg: ClusterConfig,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        assert_eq!(qs.len(), n);
        assert_eq!(shifts.len(), n);
        if let Some(links) = &cfg.links {
            assert_eq!(links.len(), n);
        }
        let needs_c = matches!(
            cfg.method,
            MethodKind::Star { with_c: true } | MethodKind::Diana { with_c: true, .. }
        );
        if needs_c {
            assert!(
                cs.as_ref().map(|v| v.len()) == Some(n),
                "method requires one C_i per worker"
            );
        }

        let mut root = Pcg64::with_stream(cfg.seed, 0xa160);
        let (up_tx, up_rx) = channel::<WorkerUpdate>();
        let mut cs_iter = cs.into_iter().flatten();

        let grad_star: Vec<Vec<f64>> = (0..n).map(|i| problem.grad_star(i).to_vec()).collect();
        let mut workers = Vec::with_capacity(n);
        for (wi, q) in qs.into_iter().enumerate() {
            let rng = root.stream(wi as u64 + 1);
            let (cmd_tx, cmd_rx) = channel::<WorkerCommand>();
            let up_tx = up_tx.clone();
            let problem = problem.clone();
            let method = cfg.method;
            let prec = cfg.prec;
            let h0 = shifts[wi].clone();
            let c = if needs_c { cs_iter.next() } else { None };
            let handle = std::thread::Builder::new()
                .name(format!("shiftcomp-worker-{wi}"))
                .spawn(move || worker_loop(wi, problem, q, c, method, h0, rng, prec, cmd_rx, up_tx))
                .expect("spawn worker thread");
            workers.push(WorkerThread {
                cmd_tx,
                handle: Some(handle),
            });
        }

        Self {
            method: cfg.method,
            gamma: cfg.gamma,
            prec: cfg.prec,
            x: crate::algorithms::paper_x0(d, cfg.seed),
            h: shifts,
            grad_star,
            workers,
            up_rx,
            net: cfg.links.map(NetworkAccountant::new),
            est: vec![0.0; d],
            decoded: vec![0.0; d],
            round: 0,
        }
    }

    pub fn set_x0(&mut self, x0: Vec<f64>) {
        assert_eq!(x0.len(), self.x.len());
        self.x = x0;
    }

    /// Master-side reconstruction of a worker's shift (tests).
    pub fn shift(&self, worker: usize) -> &[f64] {
        &self.h[worker]
    }

    pub fn simulated_time(&self) -> f64 {
        self.net.as_ref().map(|n| n.sim_time).unwrap_or(0.0)
    }

    fn decode_frame(&self, bytes: &[u8]) -> Packet {
        wire::decode(bytes).expect("malformed frame from worker")
    }
}

impl Algorithm for DistributedRunner {
    fn name(&self) -> String {
        match self.method {
            MethodKind::Fixed => "dist-dcgd-shift(fixed)".into(),
            MethodKind::Star { .. } => "dist-dcgd-star".into(),
            MethodKind::Diana { .. } => "dist-diana".into(),
            MethodKind::RandDiana { .. } => "dist-rand-diana".into(),
        }
    }

    fn compressor_desc(&self) -> String {
        "distributed".into()
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn step(&mut self, _p: &dyn Problem) -> StepStats {
        let n = self.workers.len();
        let d = self.x.len();
        let inv_n = 1.0 / n as f64;

        // broadcast
        let x_arc = Arc::new(self.x.clone());
        for w in &self.workers {
            w.cmd_tx
                .send(WorkerCommand::Round {
                    k: self.round,
                    x: x_arc.clone(),
                })
                .expect("worker thread died");
        }

        // gather (any arrival order; processed in worker order for exact
        // fp-reproducibility)
        let mut slots: Vec<Option<WorkerUpdate>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let upd = self.up_rx.recv().expect("worker channel closed");
            debug_assert_eq!(upd.k, self.round);
            let wi = upd.worker;
            slots[wi] = Some(upd);
        }

        zero(&mut self.est);
        let mut bits_up = 0u64;
        let mut bits_refresh = 0u64;
        let mut per_worker_wire_bits = vec![0u64; n];

        for wi in 0..n {
            let upd = slots[wi].take().unwrap();
            bits_up += upd.payload_bits;
            bits_refresh += upd.refresh_bits;
            per_worker_wire_bits[wi] = upd.wire_bytes as u64 * 8;

            match self.method {
                MethodKind::Fixed => {
                    let pkt = self.decode_frame(&upd.frames.q_frame);
                    pkt.decode_into(&mut self.decoded);
                    axpy(inv_n, &self.h[wi], &mut self.est);
                    axpy(inv_n, &self.decoded, &mut self.est);
                }
                MethodKind::Star { with_c } => {
                    // reconstruct the worker's same-round shift
                    let mut h_new = self.grad_star[wi].clone();
                    if with_c {
                        let c_pkt = self
                            .decode_frame(upd.frames.c_frame.as_ref().expect("missing C frame"));
                        c_pkt.decode_into(&mut self.decoded);
                        axpy(1.0, &self.decoded, &mut h_new);
                    }
                    self.h[wi] = h_new;
                    let pkt = self.decode_frame(&upd.frames.q_frame);
                    pkt.decode_into(&mut self.decoded);
                    axpy(inv_n, &self.h[wi], &mut self.est);
                    axpy(inv_n, &self.decoded, &mut self.est);
                }
                MethodKind::Diana { alpha, with_c } => {
                    let mut update = vec![0.0; d];
                    if with_c {
                        let c_pkt = self
                            .decode_frame(upd.frames.c_frame.as_ref().expect("missing C frame"));
                        c_pkt.decode_into(&mut self.decoded);
                        update.copy_from_slice(&self.decoded);
                    }
                    let q_pkt = self.decode_frame(&upd.frames.q_frame);
                    q_pkt.decode_into(&mut self.decoded);
                    axpy(1.0, &self.decoded, &mut update);
                    axpy(inv_n, &self.h[wi], &mut self.est);
                    axpy(inv_n, &update, &mut self.est);
                    axpy(alpha, &update, &mut self.h[wi]);
                }
                MethodKind::RandDiana { .. } => {
                    let pkt = self.decode_frame(&upd.frames.q_frame);
                    pkt.decode_into(&mut self.decoded);
                    axpy(inv_n, &self.h[wi], &mut self.est);
                    axpy(inv_n, &self.decoded, &mut self.est);
                    if let Some(refresh) = &upd.frames.refresh {
                        let pkt = self.decode_frame(refresh);
                        pkt.decode_into(&mut self.h[wi]);
                    }
                }
            }
        }

        // gradient step
        axpy(-self.gamma, &self.est.clone(), &mut self.x);
        self.round += 1;

        let bits_down = (n * d) as u64 * self.prec.bits();
        if let Some(net) = &mut self.net {
            net.round(&per_worker_wire_bits, d as u64 * self.prec.bits());
        }

        StepStats {
            bits_up,
            bits_down,
            bits_refresh,
        }
    }
}

impl Drop for DistributedRunner {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(WorkerCommand::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

// ------------------------------------------------------------ constructors

impl DistributedRunner {
    /// Distributed DIANA with homogeneous compressors and Theorem-3 steps.
    pub fn diana(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        let omega = q.omega().expect("DIANA needs unbiased Q");
        let ss = crate::theory::diana(problem.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
            },
        )
    }

    /// Distributed Rand-DIANA with Theorem-4 steps.
    pub fn rand_diana(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        p_refresh: Option<f64>,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        let omega = q.omega().expect("Rand-DIANA needs unbiased Q");
        let pr = p_refresh.unwrap_or_else(|| crate::theory::rand_diana_default_p(omega));
        let ss = crate::theory::rand_diana(problem.as_ref(), omega, &vec![pr; n], None);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::RandDiana { p: pr },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
            },
        )
    }

    /// Distributed plain DCGD (zero fixed shifts, Theorem-1 step).
    pub fn dcgd(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        let omega = q.omega().expect("DCGD needs unbiased Q");
        let ss = crate::theory::dcgd_fixed(problem.as_ref(), &vec![omega; n]);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Fixed,
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunOpts;
    use crate::compressors::RandK;
    use crate::problems::Ridge;

    #[test]
    fn distributed_diana_converges() {
        let p = Arc::new(Ridge::paper_default(5));
        let mut runner =
            DistributedRunner::diana(p.clone(), RandK::with_q(p.dim(), 0.5), 5, None);
        let trace = runner.run(
            p.as_ref(),
            &RunOpts {
                max_rounds: 15_000,
                tol: 1e-6,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(
            trace.converged || trace.final_relative_error() < 1e-5,
            "err {:e}",
            trace.final_relative_error()
        );
    }

    #[test]
    fn network_accounting_advances() {
        let p = Arc::new(Ridge::paper_default(6));
        let links = vec![LinkModel::default(); p.n_workers()];
        let mut runner =
            DistributedRunner::rand_diana(p.clone(), RandK::with_q(p.dim(), 0.2), None, 6, Some(links));
        for _ in 0..10 {
            runner.step(p.as_ref());
        }
        assert!(runner.simulated_time() > 0.0);
        let net = runner.net.as_ref().unwrap();
        assert_eq!(net.rounds, 10);
        assert!(net.total_up_bits > 0);
    }

    #[test]
    fn clean_shutdown_on_drop() {
        let p = Arc::new(Ridge::paper_default(7));
        {
            let mut runner =
                DistributedRunner::dcgd(p.clone(), RandK::with_q(p.dim(), 0.5), 7, None);
            runner.step(p.as_ref());
        } // drop must join all threads without hanging
    }
}
