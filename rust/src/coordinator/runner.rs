//! Master/worker threaded runtime.
//!
//! # Shared copy-on-write replica: snapshot + sparse overlay
//!
//! The fleet holds **one** iterate, not n. Worker threads own no private
//! dense `Vec<f64>` replica: each round the master publishes its post-step
//! iterate as a double-buffered immutable snapshot
//! ([`crate::coordinator::replica::SnapshotPublisher`] — two `Arc` slots
//! rotated by generation parity, `Arc::get_mut`-reused in place so
//! steady-state publication is allocation-free, exactly like the broadcast
//! frame's `down_bufs`), and every worker reads the iterate through the
//! shared snapshot. The only divergence a replica is allowed to have —
//! the EF-downlink invariant `x_replica + e = x_master` — travels as a
//! sparse [`crate::coordinator::replica::OverlayPatch`] (`−e` on the
//! error accumulator's support) published alongside the snapshot, so
//! fleet replica memory is **O(d + overlay nnz)** instead of O(n·d). On
//! the exact downlink path the patch is pinned empty and the worker's
//! gradient view borrows the snapshot directly (zero copies, zero
//! worker-private bytes); under the EF downlink the worker materializes
//! `snapshot + patch` into its round-transient gradient scratch through
//! the same kernel the master's mirror view uses, so both sides see
//! identical bits. Each publication carries a monotonically increasing
//! **generation**; a worker whose retained generation is not `gen − 1` on
//! a delta-framed round missed a rotation and answers
//! [`WorkerUpdate::needs_resync`] instead of silently computing against a
//! stale base (the master re-admits it through the `Rejoin` bootstrap,
//! with no deadline-miss penalty).
//!
//! # Delta-compressed broadcast downlink
//!
//! The wire broadcast remains one shared frame per round (see
//! [`crate::wire`]'s downlink format) — it is the *accounted* downlink
//! cost a real deployment would pay, and workers still validate it with
//! the decode path's full strictness ([`wire::validate_down`]) so a
//! corrupted frame surfaces as the same structured failure it always did:
//!
//! * a **delta** frame carrying x^{k} − x^{k−1} = −γ·g^{k−1} — already
//!   sparse when the aggregate is sparse (plain DCGD with Rand-K at
//!   K = 0.5 % ships ~0.5 % of the former d·8 bytes/worker);
//! * a dense **resync** frame on round 0 (replica bootstrap for joiners),
//!   every [`ClusterConfig::resync_every`] rounds (drift checks; round 0
//!   itself is skipped — the bootstrap resync already covers it), and
//!   after out-of-band iterate changes ([`DistributedRunner::set_x0`]);
//! * with [`ClusterConfig::downlink`] set, a lossy **EF delta** frame
//!   carrying `C(e^k + Δ^k)` from the master's error-fed-back downlink
//!   compressor ([`crate::downlink::EfDownlink`]) — the broadcast stays
//!   O(nnz) even when DIANA-family shifts densify the exact delta, the
//!   dropped residual is retried next round, and any resync flushes the
//!   accumulator, truncates the overlay, and collapses the replicas onto
//!   the snapshot exactly.
//!
//! On the exact path the snapshot *is* the master iterate, so master and
//! replicas are bit-equal by construction and trajectories are
//! bit-identical to the dense broadcast (pinned by
//! `tests/coordinator.rs`). On the EF path the master maintains a
//! bit-exact mirror of the replica view (same snapshot + overlay
//! materialization the workers run), and the EF invariant
//! `x_replica + e = x_master` bounds the drift. `StepStats::bits_down` is
//! the measured frame size, not a dense formula; `StepStats::replica_bytes`
//! totals the fleet's resident replica storage (snapshot buffers + overlay
//! patches + any worker-private dense bytes) so the O(d) scaling is
//! observable per round.
//!
//! Wire-precision symmetry: workers quantize every uplink packet to the
//! cluster precision *before* folding it into local shift state, so under
//! `prec = f32` the worker's `h` is bit-equal to the master's replica
//! reconstructed from the (identically quantized) wire frames — and the
//! whole cluster is bit-identical to [`crate::algorithms::DcgdShift`]
//! running at the same precision. (Encoding a quantized packet is
//! lossless, so the wire bytes are unchanged.)
//!
//! # Error-fed-back uplink (EF-BV workers)
//!
//! [`ClusterConfig::uplink_ef`] arms the uplink twin of the EF downlink:
//! each worker keeps an accumulator `e_i` ([`crate::ef::EfUplink`]), ships
//! `c_i = C_i(e_i + m_i)` where `m_i = ∇f_i(x̂) − h_i` is the shifted
//! message it would normally compress, and retries the residual
//! `e_i ← e_i + m_i − c_i` next round — so **contractive** compressors
//! (Top-K, or any `C ∈ B(δ)`) become valid on the worker → master path:
//! the per-round bias is corrected over rounds instead of accumulating in
//! the trajectory, and `bits_up` stays O(K). The master needs no new
//! state: it folds the wire packets exactly as before (DIANA shift
//! learning included — both ends apply the identical `c_i`), and the
//! packets are pre-quantized by the EF re-pack, so the f32 shift-replica
//! symmetry above carries over unchanged. A dense resync flushes every
//! worker accumulator (nothing stale is retried against re-established
//! state); [`crate::algorithms::DcgdShift`] mirrors the whole construction
//! op for op (`set_uplink_ef`), including the per-sub-step fold when
//! composed with `local_steps` batching. Step sizes for the contractive
//! regime come from [`crate::theory::ef_uplink`].
//!
//! # Fault-tolerant rounds: deadline, quarantine, rejoin
//!
//! The shifted-compression aggregate `g = (1/|R|) Σ_{i∈R} (h_i + q_i)` is
//! defined for whichever workers R actually report, so a failure degrades
//! the fleet instead of killing the run:
//!
//! * the gather is **deadline-bounded** — [`ClusterConfig::round_timeout_ms`]
//!   caps how long the master waits for the round's updates (`recv_timeout`,
//!   never a bare `recv`), so no fault configuration can deadlock it;
//! * an [`WorkerState::Active`] worker that misses
//!   [`ClusterConfig::quarantine_after`] consecutive deadlines, ships a
//!   malformed frame, or reports a [`WorkerFailure`] is **quarantined**:
//!   the master subtracts its shift replica `h_i` from the maintained
//!   `h_sum` in one O(d) pass, reweights the aggregate to `1/|active|`,
//!   stops sending it `Round` commands and skips its gather slot — the
//!   survivors' trajectory is bit-identical to an (n−f)-worker
//!   [`crate::algorithms::DcgdShift`] mirror degraded at the same round
//!   (pinned by `tests/chaos.rs`);
//! * a worker that reports *within* the round but after some other worker
//!   already missed is still folded: a transient miss only excludes the
//!   missing worker's `h_i` from that round's estimator (`est −= inv·h_i`,
//!   leaving `h_sum` untouched until quarantine actually triggers);
//! * a quarantined worker whose thread is alive (the straggler case) can
//!   **rejoin** ([`DistributedRunner::rejoin`]): the master re-adds its
//!   shift to `h_sum` and ships a [`WorkerCommand::Rejoin`] bootstrap — a
//!   dense resync of the current iterate plus the master's shift replica —
//!   and the worker flushes its EF uplink accumulator exactly as it would
//!   on any resync (the EF-BV state-reset rule: nothing stale is retried
//!   against re-established state). With the EF *downlink* armed, a rejoin
//!   also forces a full-fleet dense resync so the shared replica mirror
//!   stays uniform;
//! * [`DistributedRunner::health`] reports a [`RunnerHealth`] snapshot
//!   (per-worker state, consecutive-miss counters, degraded-round count)
//!   and `StepStats::active_workers` carries the reporter count per round,
//!   so degradation is observable from the harness.
//!
//! A failure is **fatal** — `Err` from [`DistributedRunner::try_step`],
//! panic from the [`Algorithm::step`] wrapper — only when no worker can
//! ever report again (every thread exited). Fatal errors are sticky: the
//! runner is poisoned and every later `try_step` returns the same
//! [`WorkerFailure`] instead of touching the half-degraded state. Failure
//! classes (crash / timeout / protocol, [`FailureClass`]) are carried on
//! every [`WorkerFailure`] so harness logs can tell injected faults
//! ([`crate::coordinator::faults::FaultPlan`], wired in via
//! [`ClusterConfig::faults`]) from organic ones.
//!
//! # Semi-async rounds: arrival → admit → close → late-fold
//!
//! The gather is **event-driven**, not a barrier-then-process loop. Each
//! round moves through four moments:
//!
//! 1. **arrival** — the master blocks for the first update of a burst,
//!    then greedily drains everything already queued. Every arrival marks
//!    its sender alive for the round's miss accounting, whether it folds
//!    or not.
//! 2. **admit** — a fresh, failure-free update claims its gather slot and
//!    joins the burst's *pooled on-arrival decode*: validation + frame
//!    decode + shard-bound caching run worker-sharded on the
//!    [`FoldPool`] **while the master is otherwise waiting** for the rest
//!    of the fleet, so decode CPU overlaps the gather wait and the
//!    post-close serial work shrinks to accounting plus the
//!    coordinate-sharded fold. (The τ > 1 batched protocol keeps its own
//!    sub-step-major validation pass instead.)
//! 3. **close** — the round closes when every commanded worker has
//!    answered, at the deadline, or — with [`ClusterConfig::quorum`] =
//!    m — as soon as m fresh updates are admitted. Admitted updates fold
//!    in worker order, so an m = n quorum (or none) is **bit-identical**
//!    to the historical barrier gather. A quorum close is weak evidence
//!    against the cut workers, so it raises their quarantine threshold
//!    by one consecutive miss; their stale arrivals keep resetting the
//!    counter, so a merely-slow worker is never cut.
//! 4. **late-fold** — with [`ClusterConfig::staleness`] armed, a frame
//!    that arrives one round late (the tail a quorum close cut) folds
//!    into the *next* round's estimator damped by
//!    λ = [`crate::theory::staleness::damping`]`(1)`: the round's
//!    aggregate becomes the weighted average
//!    `g = (Σ_fresh (h_i + q_i) + λ Σ_stale (h_i + q_i^{k−1})) /
//!    (|fresh| + λ|stale|)`. Older frames are discarded (τ = 1 staleness
//!    bound); step sizes for the delayed regime come from
//!    [`crate::theory::staleness::dcgd_delayed`].
//!
//! [`ClusterConfig::participation`] layers the FedAvg-style serving
//! regime on top: a seeded [`ParticipationSampler`] draws S_k each round
//! (worker 0 always in), only S_k is commanded, sampled-out workers get a
//! generation-keeping [`WorkerCommand::Sync`] (no compute, no reply) and
//! are excluded from the estimator — which reweights to `1/|S_k ∩ R|` —
//! with their shifts untouched. The sampler, the quorum admission
//! schedule, and the staleness window are all pure functions of the seed
//! and arrival order is folded away, so the single-process
//! [`crate::algorithms::DcgdShift`] mirror replays the identical
//! schedule and stays bit-exact. All three knobs require the fixed-shift
//! method with `local_steps = 1` (DIANA-family shift learning on both
//! ends would desynchronize under cut or sampled-out frames);
//! `quorum = n` and `participation = 1.0` degenerate to the barrier
//! round bit-for-bit. [`crate::net::NetworkAccountant::set_quorum`]
//! prices a quorum round at the m-th fastest arrival instead of the
//! slowest.
//!
//! # Zero-allocation round pipeline
//!
//! Steady-state rounds recycle every buffer in the system; after warm-up
//! neither the master thread nor a worker thread touches the allocator
//! (enforced by `tests/alloc_free.rs`):
//!
//! * **workers** own one scratch [`Packet`] per compressor
//!   ([`Compressor::compress_into`]) plus the wire frame buffers, which
//!   the master ships back inside the next [`WorkerCommand::Round`] after
//!   consuming them; the iterate arrives as the shared snapshot handle
//!   (no private replica, no downlink decode packet — the frame is
//!   validated by a walk that touches no allocator);
//! * the **master** owns one scratch [`Packet`] per worker and frame kind
//!   ([`wire::decode_into`]), pre-sized gather slots, a pre-sized
//!   [`wire::DeltaScratch`] for the downlink delta, and double-buffered
//!   `Arc` pairs for the broadcast frame and the iterate
//!   snapshot/overlay publication — by the time a buffer's turn comes
//!   round again, every worker has provably dropped its handle, so
//!   `Arc::get_mut` succeeds and the frame is encoded (snapshot copied)
//!   in place; the `Rejoin` resync frame is likewise built once per round
//!   into a recycled buffer shared by every rejoining arm;
//! * channels are **bounded** (`sync_channel`), so sends go through
//!   preallocated slots instead of heap nodes.
//!
//! Aggregation is sparse-aware: the gradient estimator is seeded from the
//! maintained shift sum in one O(d) pass and every compressed message is
//! folded in with [`Packet::add_scaled_into`] at O(nnz) — a Rand-K round at
//! K = 0.5 % costs ~0.5 % of the former dense-decode aggregation. The
//! single-process [`crate::algorithms::DcgdShift`] mirrors the same
//! operation order so trajectories stay bit-identical (see
//! `tests/coordinator.rs`). Rand-DIANA refreshes upload a sparse delta of
//! the shift vs the master's replica instead of the former dense d-length
//! spike.
//!
//! # Parallel fold (coordinate-sharded master hot path)
//!
//! Once both wire directions are O(K) bytes, the master's serial CPU work —
//! decoding n uplink frames and replaying the fold into `est`/`h`/`h_sum` —
//! is the round bottleneck. It is parallelized across a persistent pool of
//! [`ClusterConfig::master_threads`] shard threads
//! ([`crate::coordinator::pool::FoldPool`]) without giving up bit-identity:
//!
//! * **frame decode** is sharded *by worker* (`wi % T == s`): each shard
//!   decodes into that worker's private scratch packets, so there is no
//!   floating-point ordering hazard at all;
//! * the **fold** is sharded *by coordinate*: shard `s` owns the contiguous
//!   range `cuts[s]..cuts[s+1]` of `[0, d)` and replays the *same
//!   worker-order sequence* of `ax_into` / `axpy` /
//!   [`Packet::add_scaled_range`] ops restricted to its range. Sharding by
//!   coordinate never reorders or reassociates anything a single
//!   coordinate sees: `est[j]`, `h[wi][j]` and `h_sum[j]` receive exactly
//!   the serial op sequence for every `j` — only the thread executing it
//!   differs with `j` — so trajectories, shifts and accumulators are
//!   bit-identical for every `T` (pinned by `tests/parallel_fold.rs`
//!   across `T ∈ {1, 2, 8}` and against the single-process mirrors,
//!   faults and quarantine included);
//! * sparse packets locate their shard sub-ranges with one binary search
//!   per cut over their sorted indices ([`Packet::shard_bounds_into`]),
//!   cached per worker per round in reused buffers; ternary packets get
//!   their sign cursors from one prefix-popcount pass;
//! * quarantine/rejoin's O(d) shift moves run through the same sharded
//!   `axpy`.
//!
//! The pool threads are spawned once at construction and park on
//! rendezvous channels between rounds — arming a round costs `T − 1`
//! channel sends and zero allocations, so pooled rounds stay on the
//! zero-allocation contract above. `T = 1` runs every shard inline on the
//! master thread: literally the serial path, no hand-off, no barrier.
//!
//! # Local-step batched rounds and pipelined pricing
//!
//! Once frames shrink to O(K) bytes the round-trip *latency* dominates the
//! simulated wall clock. [`ClusterConfig::local_steps`] = τ attacks it
//! directly: each worker performs τ local shifted sub-steps per
//! communication round — sub-step t computes the gradient at a local
//! iterate x̂ (booted from the replica), compresses the shifted difference,
//! takes the local step `x̂ ← x̂ − γ(h + q_t)` with the *quantized* packet,
//! and (DIANA) learns `h += α·q_t` — then ships all τ packets in **one**
//! batched uplink frame (see [`crate::wire`]'s batch format): one latency
//! round trip instead of τ. The master replays the fold sub-step-major
//! from the wire packets — `est^t` seeded from the maintained shift sum as
//! of sub-step t, Diana shift learning applied per sub-step exactly as the
//! workers did locally — accumulates `Σ_t est^t`, and ships the composite
//! step as one downlink delta, so in exact arithmetic `x^{k+1}` is the
//! average of the workers' local trajectories (a local-steps/FedAvg-style
//! variant of the shifted-compression method; supported for the
//! fixed-shift and DIANA-without-C methods). `local_steps = 1` takes
//! today's code path verbatim and is bit-identical to the per-round
//! protocol; [`crate::algorithms::DcgdShift::set_local_steps`] is the
//! bit-identical single-process mirror of the τ-step fold.
//!
//! [`ClusterConfig::pipeline`] prices batched rounds with the
//! overlap-aware two-stage model
//! ([`crate::net::NetworkAccountant::round_pipelined`]): within a round
//! the worker streams each sub-step packet as it is produced, so sub-step
//! compute overlaps the uplink transfer (workers report their measured
//! compute seconds in each [`WorkerUpdate`]). The toggle affects only the
//! simulated wall clock — trajectories are bit-identical either way.
//!
//! # Debug-build invariant audits
//!
//! Every equivalence claim above is also *executed*: after each
//! publication the round loop calls into
//! [`crate::coordinator::invariants`], a set of `debug_assert!`-backed
//! audits compiled out of release builds — snapshot generations advance by
//! exactly one, the overlay support equals the EF error accumulator's
//! nonzero support, the EF invariant `x_replica + e = x_master` holds on
//! the master's own mirror, `replica_bytes` reconciles against the
//! publisher's buffers plus worker-private bytes, and (periodically) the
//! maintained `h_sum` re-sums over the active shift replicas. Debug tier-1
//! (`cargo test`) therefore exercises the invariants on every round of
//! every test; release builds pay nothing.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::algorithms::{Algorithm, StepStats};
use crate::compressors::{Compressor, Packet, PayloadBitsCache, ValPrec};
use crate::coordinator::faults::{FaultPlan, WorkerFaultScript};
use crate::coordinator::invariants::{self, AuditState};
use crate::coordinator::participation::ParticipationSampler;
use crate::coordinator::pool::{self, FoldPool, ShardView};
use crate::coordinator::protocol::{
    FailureClass, FrameSet, MethodKind, RunnerHealth, WorkerCommand, WorkerFailure, WorkerSnapshot,
    WorkerState, WorkerUpdate,
};
use crate::coordinator::replica::{ReplicaOverlay, SnapshotPublisher};
use crate::downlink::DownlinkState;
use crate::ef::{self, EfUplink};
use crate::linalg::{ax_into, axpy, sub_into, zero};
use crate::net::{LinkModel, NetworkAccountant};
use crate::problems::Problem;
use crate::util::rng::Pcg64;
use crate::wire::{self, DownKind};

/// Cluster-level configuration.
pub struct ClusterConfig {
    pub method: MethodKind,
    pub gamma: f64,
    pub prec: ValPrec,
    pub seed: u64,
    /// per-worker link models; `None` disables the time simulation
    pub links: Option<Vec<LinkModel>>,
    /// broadcast a dense resync frame every this many rounds (0 = only on
    /// round 0 and after `set_x0`); see the module doc
    pub resync_every: usize,
    /// local shifted sub-steps per communication round, batched into one
    /// uplink frame (1 = today's one-frame-per-round protocol, bit
    /// identical; > 1 requires the fixed-shift or DIANA-without-C method —
    /// see the module doc)
    pub local_steps: usize,
    /// price rounds with the overlap-aware pipelined model instead of the
    /// staged one (simulated wall clock only; trajectories are identical)
    pub pipeline: bool,
    /// error-fed-back downlink compressor (`None` = exact delta frames).
    /// Contractive operators (Top-K, Identity) are the intended choices:
    /// the dropped residual accumulates in the master's error state and is
    /// retried next round — see [`crate::downlink::EfDownlink`]. Identity
    /// reproduces the exact path bit for bit.
    pub downlink: Option<Box<dyn Compressor>>,
    /// arm worker-side error feedback on the uplink: workers ship
    /// `C_i(e_i + m_i)` from an accumulator instead of `Q_i(m_i)`,
    /// unlocking contractive (biased) per-worker compressors — see the
    /// module doc. With `Identity` compressors and f64 wire precision the
    /// path is bit-identical to the exact uplink (`e_i` stays exactly
    /// zero); under f32 even Identity leaves the quantization residual
    /// `m − quantize(m)` in the accumulator and retries it, which the
    /// exact path cannot — a (tiny, corrective) trajectory difference.
    ///
    /// Interaction with [`ClusterConfig::resync_every`]: scheduled dense
    /// resyncs flush every worker accumulator, dropping pending
    /// residuals. Like the EF *downlink* under periodic resync, this is a
    /// runner-only operational reset that the single-process
    /// [`crate::algorithms::DcgdShift`] mirror does not replay (it has no
    /// periodic-resync path) — combine `resync_every > 0` with EF and the
    /// two drivers legitimately diverge from the first scheduled resync
    /// on. The bit-identity guarantees hold for `resync_every = 0` plus
    /// `set_x0`-forced resyncs, which both drivers mirror.
    pub uplink_ef: bool,
    /// deterministic fault injection schedule (`None` = no faults); see
    /// [`crate::coordinator::faults`] for the per-kind semantics
    pub faults: Option<FaultPlan>,
    /// gather deadline per round, milliseconds (must be > 0): the master
    /// waits at most this long for the round's worker updates before
    /// counting the missing workers as deadline misses — see the module
    /// doc. [`DEFAULT_ROUND_TIMEOUT_MS`] is generous enough that healthy
    /// fleets never notice it.
    pub round_timeout_ms: u64,
    /// consecutive deadline misses before a worker is quarantined (≥ 1;
    /// 1 = quarantine on the first missed round)
    pub quarantine_after: usize,
    /// fold-pool width for the master's parallel decode + fold (see the
    /// "Parallel fold" section of the module doc). `None` (default) sizes
    /// the pool from the `SHIFTCOMP_MASTER_THREADS` environment variable
    /// when set, else `available_parallelism` capped at 16; `Some(t)` pins
    /// it (config parsing rejects 0). Trajectories, shifts and
    /// accumulators are bit-identical for every value — the knob trades
    /// wall-clock only.
    pub master_threads: Option<usize>,
    /// semi-async quorum gather: close the round as soon as this many
    /// fresh gradient updates have been admitted (the deadline still caps
    /// the tail). `None` or `Some(n)` is the barrier gather — the round
    /// waits for every commanded worker and the trajectory is
    /// bit-identical to the historical path. `Some(m)` with `m < n`
    /// requires the fixed-shift method with `local_steps = 1` (see the
    /// module doc's "Semi-async rounds" section) and, combined with the
    /// EF uplink, `staleness` must be armed so cut frames are folded late
    /// instead of silently dropping error-feedback signal.
    pub quorum: Option<usize>,
    /// FedAvg-style partial participation: sample a seeded subset S_k of
    /// the fleet each round (|S_k| = max(1, round(fraction·n)), worker 0
    /// always in — see [`crate::coordinator::ParticipationSampler`]),
    /// command only S_k, and reweight the estimator to the reporters.
    /// Sampled-out workers receive a [`WorkerCommand::Sync`] (publication
    /// install only — no compute, no RNG draw, no reply) so they never
    /// gen-gap; their shifts stay untouched and are excluded from the
    /// round's estimator by the same O(d)-axpy machinery quarantine uses.
    /// Requires the fixed-shift method with `local_steps = 1`.
    pub participation: Option<f64>,
    /// Admit one-round-late frames (the tail a quorum close cuts) into
    /// the *next* round's fold as stale gradients, damped by
    /// [`crate::theory::staleness::damping`]`(1)`; older frames are still
    /// discarded, so the staleness bound is τ = 1. Step sizes for the
    /// delayed regime come from [`crate::theory::staleness::dcgd_delayed`].
    /// Requires the fixed-shift method with `local_steps = 1`.
    pub staleness: bool,
}

/// Default [`ClusterConfig::round_timeout_ms`]: far above any healthy
/// round, so the deadline only ever fires on genuinely stuck workers.
pub const DEFAULT_ROUND_TIMEOUT_MS: u64 = 30_000;

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            method: MethodKind::Fixed,
            gamma: 0.0,
            prec: ValPrec::F64,
            seed: 0,
            links: None,
            resync_every: 0,
            local_steps: 1,
            pipeline: false,
            downlink: None,
            uplink_ef: false,
            faults: None,
            round_timeout_ms: DEFAULT_ROUND_TIMEOUT_MS,
            quarantine_after: 1,
            master_threads: None,
            quorum: None,
            participation: None,
            staleness: false,
        }
    }
}

struct WorkerThread {
    cmd_tx: SyncSender<WorkerCommand>,
    handle: Option<JoinHandle<()>>,
}

/// The leader: owns the iterate, reconstructs worker shifts from wire
/// traffic, and drives rounds.
pub struct DistributedRunner {
    method: MethodKind,
    gamma: f64,
    prec: ValPrec,
    x: Vec<f64>,
    /// master-side reconstruction of each worker's shift
    h: Vec<Vec<f64>>,
    /// maintained Σᵢ h_i (non-STAR methods; STAR rebuilds shifts per round
    /// and aggregates them densely, so its h_sum stays zero)
    h_sum: Vec<f64>,
    /// ∇f_i(x*) (STAR only — the "impractical but insightful" method
    /// assumes these are known on both ends)
    grad_star: Vec<Vec<f64>>,
    workers: Vec<WorkerThread>,
    up_rx: Receiver<WorkerUpdate>,
    pub net: Option<NetworkAccountant>,
    // ---- preallocated master scratch (zero-allocation round contract)
    /// gradient estimator g^k
    est: Vec<f64>,
    /// recycled decode packets for Q frames, one per worker (per-worker so
    /// heterogeneous-compressor fleets don't thrash the packet variant)
    q_scratch: Vec<Packet>,
    /// recycled decode packets for C / refresh frames, one per worker
    c_scratch: Vec<Packet>,
    /// gather slots (one per worker, taken each round)
    slots: Vec<Option<WorkerUpdate>>,
    /// per-worker wire bits for the network accountant
    wire_bits: Vec<u64>,
    /// consumed frame buffers, shipped back to their worker next round
    frames_pool: Vec<FrameSet>,
    /// double-buffered broadcast frame (parity = round % 2): the frame sent
    /// in round k is encoded either at the end of round k−1 (delta) or at
    /// the start of round k (resync)
    down_bufs: [Arc<Vec<u8>>; 2],
    /// downlink delta builder scratch (both representations pre-sized to d)
    delta: wire::DeltaScratch,
    /// shared driver-side downlink glue ([`crate::downlink::DownlinkState`]):
    /// the optional EF compressor state and — on the EF path — the
    /// bit-exact mirror of the worker replicas, updated by applying the
    /// same broadcast packets the workers apply. The mirror *leads by the
    /// one in-flight frame*: the round-k+1 EfDelta is folded and applied
    /// here at the end of round k, while workers apply it at the start of
    /// round k+1 — so between steps this equals what every worker's local
    /// `x` will be bit for bit *during the next round* (tests verify the
    /// lagged equality via [`WorkerCommand::Inspect`]). On the exact path
    /// the master iterate itself plays the mirror's role.
    dl: DownlinkState,
    /// double-buffered publisher of the fleet-shared iterate snapshot +
    /// sparse overlay (see [`crate::coordinator::replica`]): one `publish`
    /// per round, allocation-free in steady state
    publisher: SnapshotPublisher,
    /// cross-round debug-audit state (snapshot-generation monotonicity;
    /// see [`crate::coordinator::invariants`] — one u64 in release builds)
    audit: AuditState,
    /// per-worker private-dense-replica bytes, as reported in the last
    /// update each worker sent (health gauge; 0 except the τ > 1 iterate)
    worker_replica_bytes: Vec<u64>,
    /// per-worker overlay nnz of the replica handle behind each worker's
    /// last update (health gauge; 0 on the exact downlink path)
    worker_overlay_nnz: Vec<u64>,
    /// local sub-steps per communication round (≥ 1; see the module doc)
    local_steps: usize,
    /// overlap-aware wall-clock pricing for batched rounds
    pipeline: bool,
    /// Σ_t est^t accumulator for batched rounds (empty when τ = 1)
    g_acc: Vec<f64>,
    /// per-worker byte cursors into the batched uplink frames
    offsets: Vec<usize>,
    /// per-worker measured compute seconds of the current round (staged /
    /// pipelined pricing input — each worker is charged its own compute)
    compute: Vec<f64>,
    /// next broadcast must be a dense resync (round 0, after `set_x0`)
    needs_resync: bool,
    resync_every: usize,
    round: usize,
    // ---- fault tolerance (see the module doc)
    /// per-worker participation state
    states: Vec<WorkerState>,
    /// workers currently in the round rotation (`states[i] == Active`)
    n_active: usize,
    /// per-worker consecutive missed-deadline count (reset on report)
    misses: Vec<u32>,
    /// workers re-admitted via [`DistributedRunner::rejoin`] whose
    /// bootstrap command has not shipped yet
    rejoining: Vec<bool>,
    /// workers that answered *this* round with
    /// [`WorkerUpdate::needs_resync`] (cleared at round start): alive and
    /// well-behaved, so excused from miss accounting while they await the
    /// rejoin bootstrap
    resync_flags: Vec<bool>,
    /// most recent failure per worker (class + detail, kept for ops/tests)
    last_failures: Vec<Option<WorkerFailure>>,
    /// rounds completed with fewer reporters than configured workers
    degraded_rounds: usize,
    /// gather deadline per round
    round_timeout: Duration,
    /// consecutive misses before quarantine
    quarantine_after: u32,
    /// sticky fatal failure: set once the cluster can never gather again,
    /// returned verbatim by every later `try_step`
    poisoned: Option<WorkerFailure>,
    // ---- parallel fold (see the "Parallel fold" section of the module doc)
    /// persistent shard-thread pool for the decode + fold hot path
    pool: FoldPool,
    /// shard boundaries over `[0, d)`: `cuts[s]..cuts[s+1]` is shard s's
    /// coordinate range (T + 1 entries, fixed for the run)
    cuts: Vec<usize>,
    /// per-worker cached Q-packet shard bounds for the current fold
    /// (each refilled by [`Packet::shard_bounds_into`], capacity T + 1)
    q_bounds: Vec<Vec<u32>>,
    /// per-worker cached C/refresh-packet shard bounds for the current fold
    c_bounds: Vec<Vec<u32>>,
    /// per-worker decode verdict of the parallel validation pass, consumed
    /// by the serial accounting pass (quarantine happens in worker order)
    fold_failures: Vec<Option<WorkerFailure>>,
    /// per-worker "this reporter folds this round" flags (set by the
    /// serial accounting pass, read inside the sharded fold closure)
    fold_flags: Vec<bool>,
    /// per-worker "Rand-DIANA refresh present this round" flags
    refresh_flags: Vec<bool>,
    /// shard views over the worker shift replicas, rebuilt for each fold
    /// and cleared right after (never valid across rounds; capacity n)
    h_views: Vec<ShardView<f64>>,
    /// cumulative master-CPU seconds across rounds (broadcast encode +
    /// decode + fold + downlink build; gather wait excluded)
    master_secs: f64,
    // ---- semi-async rounds (see the "Semi-async rounds" section of the
    //      module doc)
    /// quorum target: close the gather once this many fresh gradient
    /// updates are admitted (`None` = wait for every commanded worker)
    quorum: Option<usize>,
    /// fold one-round-late frames as damped stale gradients instead of
    /// discarding them
    staleness: bool,
    /// seeded per-round participation sampler (`None` = full participation)
    sampler: Option<ParticipationSampler>,
    /// this round's participation mask S_k (all-true without a sampler)
    sampled: Vec<bool>,
    /// one-round-stale updates awaiting their damped fold (staleness only)
    stale_slots: Vec<Option<WorkerUpdate>>,
    /// per-worker decode packets for stale Q frames (fresh and stale
    /// frames from the same worker can fold in the same round, so the
    /// stale decode cannot share `q_scratch`)
    stale_scratch: Vec<Packet>,
    /// per-worker cached shard bounds of the stale packets
    stale_bounds: Vec<Vec<u32>>,
    /// per-worker "stale frame folds this round" flags (accounting pass)
    stale_flags: Vec<bool>,
    /// per-worker stale-frame decode verdicts (quarantine in worker order)
    stale_failures: Vec<Option<WorkerFailure>>,
    /// per-worker "any frame arrived this round" flags: proof of life for
    /// the miss accounting (a late frame still resets the counter)
    alive_flags: Vec<bool>,
    /// recycled (worker, is_stale) batch for the on-arrival decode
    pending_decode: Vec<(usize, bool)>,
    /// recycled shard-bound cache for the downlink delta's pooled apply
    delta_bounds: Vec<u32>,
}

/// Per-worker static configuration, fixed for the run (bundled so the
/// worker thread entry point stays readable).
struct WorkerCfg {
    wi: usize,
    method: MethodKind,
    prec: ValPrec,
    /// step size — workers need it for local sub-steps when τ > 1
    gamma: f64,
    /// local sub-steps per round (τ; 1 = per-round protocol)
    local_steps: usize,
    /// worker-side error feedback on the uplink (see the module doc)
    uplink_ef: bool,
    /// this worker's compiled fault schedule (empty = no injected faults)
    script: WorkerFaultScript,
}

/// Worker-side loop: one thread per worker.
///
/// The worker holds **no private dense replica** of the iterate: each
/// round's command carries the fleet-shared snapshot + sparse overlay
/// (see [`crate::coordinator::replica`]), and the worker retains only the
/// cheap [`ReplicaOverlay`] handle (two `Arc` clones + a generation
/// number). The broadcast downlink frame is still *validated* —
/// structure and dimension, the same strictness the old decode-apply
/// path enforced, so wire accounting and fault detection are unchanged —
/// but never decoded into an O(d) packet. All scratch (gradient/diff
/// vectors, compression packets, frame buffers) is owned by the loop and
/// recycled: frame buffers travel to the master inside the
/// [`WorkerUpdate`] and come back, consumed, inside the next
/// [`WorkerCommand::Round`]. With `local_steps = τ > 1` the worker
/// additionally owns a local iterate x̂ for the τ shifted sub-steps of
/// each round (the one legitimate private dense vector, reported through
/// [`WorkerUpdate::replica_bytes`]), and encodes the τ packets
/// incrementally into one batched frame as they are produced (the
/// code-level analog of streaming them).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: WorkerCfg,
    problem: Arc<dyn Problem>,
    q: Box<dyn Compressor>,
    mut c: Option<Box<dyn Compressor>>,
    mut h: Vec<f64>,
    mut rng: Pcg64,
    cmd_rx: Receiver<WorkerCommand>,
    up_tx: SyncSender<WorkerUpdate>,
) {
    let WorkerCfg {
        wi,
        method,
        prec,
        gamma,
        local_steps,
        uplink_ef,
        script,
    } = cfg;
    let d = problem.dim();
    // worker-side EF uplink accumulator (None = exact uplink)
    let mut uplink = if uplink_ef { Some(EfUplink::new(d)) } else { None };
    // handle onto the fleet-shared iterate: snapshot Arc + overlay Arc +
    // generation (bootstrapped by the round-0 resync command, then
    // re-installed from each round's publication)
    let mut replica = ReplicaOverlay::empty();
    // local iterate for the τ sub-steps of a batched round
    let mut x_loc = if local_steps > 1 { vec![0.0; d] } else { Vec::new() };
    let mut grad = vec![0.0; d];
    let mut diff = vec![0.0; d];
    let mut q_pkt = Packet::Zero { dim: d as u32 };
    let mut c_pkt = Packet::Zero { dim: d as u32 };
    // Rand-DIANA refresh-delta builder (capacity grows to the refresh
    // support on first use, then stays)
    let mut refresh_scratch = wire::DeltaScratch::with_capacity(0);
    // per-shape payload-bits caches (steady-state accounting is one
    // multiply-add instead of a formula recompute)
    let mut q_bits = PayloadBitsCache::new();
    let mut c_bits = PayloadBitsCache::new();
    let mut r_bits = PayloadBitsCache::new();
    // spare buffers reclaimed from recycled frames whose slot is optional
    let mut c_buf: Vec<u8> = Vec::new();
    let mut refresh_buf: Vec<u8> = Vec::new();

    // LINT-ALLOW(blocking-recv): worker-side command loop — workers park
    // between rounds with no deadline by design; only the *master's* waits
    // are deadline-bounded, and a Shutdown (or a hung-up channel) always
    // ends this loop.
    while let Ok(cmd) = cmd_rx.recv() {
        let (k, down, gen, snap, patch, mut frames) = match cmd {
            WorkerCommand::Round {
                k,
                down,
                gen,
                snap,
                patch,
                recycled,
            } => (k, down, gen, snap, patch, recycled),
            WorkerCommand::Rejoin {
                k,
                down,
                gen,
                snap,
                patch,
                h: h_boot,
                recycled,
            } => {
                // re-admission bootstrap: adopt the master's replica of
                // this worker's shift; the dense resync frame below
                // installs the fresh snapshot and flushes the EF uplink
                // accumulator, then the round runs normally
                h.copy_from_slice(&h_boot);
                (k, down, gen, snap, patch, recycled)
            }
            WorkerCommand::Sync {
                gen, snap, patch, ..
            } => {
                // sampled out of this round (partial participation): adopt
                // the publication so the next Round command never sees a
                // generation gap, but compute nothing, draw no RNG, and
                // send no reply — the master does not count this worker in
                // the gather
                replica.install(gen, snap, patch);
                continue;
            }
            WorkerCommand::Inspect { reply } => {
                let _ = reply.send(WorkerSnapshot {
                    worker: wi,
                    h: h.clone(),
                    x_replica: replica.materialize(),
                    uplink_error: uplink.as_ref().map(|u| u.error().to_vec()),
                });
                continue;
            }
            WorkerCommand::Shutdown => break,
        };
        // deterministic fault injection (no-ops without a script): a crash
        // exits the thread before any compute or RNG draw; a straggled
        // round consumes the command without processing or replying —
        // both leave local state exactly where the previous round left
        // it, so the surviving fleet keeps bit-identity with the mirror.
        if !script.is_empty() {
            if script.crash_at(k) {
                break;
            }
            if script.straggle_at(k) {
                continue;
            }
        }
        // measured compute stage (downlink apply → frame encode): the
        // staged network pricing's compute input
        let t0 = Instant::now();
        // injected downlink corruption replaces this worker's *view* of
        // the broadcast (the shared buffer itself is untouched — other
        // workers must validate it cleanly); the validation below rejects
        // it and the worker reports the defect like any organic one
        let garbage: Option<Vec<u8>> = (!script.is_empty() && script.corrupt_downlink_at(k))
            .then(|| vec![0xBA, 0xAD, 0xF0, 0x0D]);
        let down_bytes: &[u8] = garbage.as_deref().unwrap_or(&down);
        // validate the downlink frame (structure + dimension — the wire
        // broadcast stays the accounted traffic and the fault-detection
        // surface), then release the shared buffer before the heavy work —
        // the master re-encodes into it once every worker has dropped its
        // handle. The iterate itself arrives as the shared snapshot +
        // overlay, so the frame is never decoded into an O(d) packet. A
        // framing defect is a protocol failure: report it with round +
        // worker id through the update channel and exit, so the master
        // quarantines this worker instead of deadlocking on a gather that
        // will never complete.
        let validated = wire::validate_down(down_bytes);
        let defect: Option<String> = match &validated {
            Err(e) => Some(format!("malformed downlink frame: {e}")),
            Ok(info) if info.dim != d as u32 => Some(format!(
                "downlink frame dimension mismatch: frame carries {}, replica is {d}",
                info.dim
            )),
            Ok(info) if info.kind == DownKind::Resync && !info.is_dense() => {
                Some("resync frame must be dense".into())
            }
            Ok(_) => None,
        };
        if let Some(detail) = defect {
            let _ = up_tx.send(WorkerUpdate {
                worker: wi,
                k,
                frames,
                payload_bits: 0,
                refresh_bits: 0,
                wire_bytes: 0,
                compute_secs: 0.0,
                failure: Some(WorkerFailure {
                    worker: wi,
                    round: k,
                    class: FailureClass::Protocol,
                    detail,
                }),
                needs_resync: false,
                replica_bytes: 0,
                overlay_nnz: 0,
            });
            break;
        }
        let Ok(down_info) = validated else {
            // every Err was mapped to a defect report above, so this arm
            // can't run; exiting the worker loop keeps the path panic-free
            break;
        };
        match down_info.kind {
            DownKind::Resync => {
                // a resync re-establishes exact state on both ends
                // unconditionally (round 0, periodic drift checks, rejoin
                // bootstraps): nothing stale may be retried against it, so
                // the EF uplink accumulator flushes too (mirrored by
                // DcgdShift::set_x0)
                replica.install(gen, snap, patch);
                if let Some(u) = uplink.as_mut() {
                    u.flush();
                }
            }
            // exact and error-fed-back deltas install identically; the EF
            // residual already lives in the published overlay
            DownKind::Delta | DownKind::EfDelta => {
                if gen != replica.gen().wrapping_add(1) {
                    // generation gap: this worker missed at least one
                    // publication (straggled round, jammed queue), so its
                    // retained base is stale. Computing against it would
                    // silently corrupt the fold — decline and ask the
                    // master for a resync bootstrap instead. The thread is
                    // alive and well-behaved, so this is neither a failure
                    // nor a deadline miss.
                    drop(down);
                    if up_tx
                        .send(WorkerUpdate {
                            worker: wi,
                            k,
                            frames,
                            payload_bits: 0,
                            refresh_bits: 0,
                            wire_bytes: 0,
                            compute_secs: 0.0,
                            failure: None,
                            needs_resync: true,
                            replica_bytes: (x_loc.len() * 8) as u64,
                            overlay_nnz: replica.overlay_nnz() as u64,
                        })
                        .is_err()
                    {
                        break; // master gone
                    }
                    continue;
                }
                replica.install(gen, snap, patch);
            }
        }
        drop(down);
        // reclaim the optional buffers so this round can reuse them even if
        // the corresponding frame is absent this time
        if let Some(b) = frames.c_frame.take() {
            c_buf = b;
        }
        if let Some(b) = frames.refresh.take() {
            refresh_buf = b;
        }

        let mut payload_bits = 0u64;
        let mut refresh_bits = 0u64;

        if local_steps > 1 {
            // ---- batched round: τ local shifted sub-steps, one frame.
            // The local iterate boots from the freshly-updated replica;
            // each sub-step compresses the shifted difference, appends the
            // quantized packet to the batch frame, then steps locally with
            // the *packet* values — `x̂ ← x̂ − γ·h` then `x̂ += (−γ)·q_t` —
            // so the master can replay the identical aggregate from the
            // wire. DIANA learns `h += α·q_t` per sub-step, mirrored by
            // the master's sub-step-major fold.
            replica.materialize_into_buf(&mut x_loc);
            wire::begin_batch_frame(local_steps, &mut frames.q_frame);
            for _ in 0..local_steps {
                problem.local_grad_into(wi, &x_loc, &mut grad);
                sub_into(&grad, &h, &mut diff);
                // per-sub-step EF fold when the EF uplink is armed: each
                // sub-step's shifted message goes through the accumulator
                // and the batch frame carries the τ compressed c_t packets
                let pkt = ef::compress_uplink(
                    q.as_ref(),
                    &mut rng,
                    uplink.as_mut(),
                    &diff,
                    prec,
                    &mut q_pkt,
                );
                payload_bits += q_bits.bits(pkt, prec);
                wire::append_batch_packet(pkt, prec, &mut frames.q_frame);
                axpy(-gamma, &h, &mut x_loc);
                pkt.add_scaled_into(-gamma, &mut x_loc);
                match method {
                    MethodKind::Fixed => {}
                    MethodKind::Diana { alpha, .. } => pkt.add_scaled_into(alpha, &mut h),
                    _ => unreachable!("local_steps > 1 is validated at construction"),
                }
            }
            if !script.is_empty() && script.garbage_uplink_at(k) {
                // local state has already advanced — this corrupts only
                // the wire frame, exercising the master's malformed-
                // uplink quarantine path
                frames.q_frame.clear();
                frames.q_frame.extend_from_slice(&[0xBA, 0xAD, 0xF0, 0x0D]);
            }
            let wire_bytes = frames.q_frame.len();
            if up_tx
                .send(WorkerUpdate {
                    worker: wi,
                    k,
                    frames,
                    payload_bits,
                    refresh_bits,
                    wire_bytes,
                    compute_secs: t0.elapsed().as_secs_f64(),
                    failure: None,
                    needs_resync: false,
                    replica_bytes: (x_loc.len() * 8) as u64,
                    overlay_nnz: replica.overlay_nnz() as u64,
                })
                .is_err()
            {
                break; // master gone
            }
            continue;
        }

        // gradient at the logical replica: the exact downlink path borrows
        // the shared snapshot directly (zero private bytes); the EF path
        // materializes snapshot + overlay into the `diff` scratch, which
        // is free here and is consumed (overwritten by `sub_into`) right
        // after — the materialization is round-transient, not resident
        {
            let xh = replica.view(&mut diff);
            problem.local_grad_into(wi, xh, &mut grad);
        }

        // Every compressed packet is quantized to the wire precision at
        // the source, *before* it touches local state or the encoder:
        // encoding a quantized packet is lossless, so the wire bytes are
        // unchanged, and the shift updates below use exactly the values
        // the master will reconstruct from the frames — under f32 the
        // worker's h stays bit-equal to the master's replica.
        match method {
            MethodKind::Fixed => {
                sub_into(&grad, &h, &mut diff);
                let pkt = ef::compress_uplink(
                    q.as_ref(),
                    &mut rng,
                    uplink.as_mut(),
                    &diff,
                    prec,
                    &mut q_pkt,
                );
                payload_bits += q_bits.bits(pkt, prec);
                wire::encode_into(pkt, prec, &mut frames.q_frame);
            }
            MethodKind::Star { with_c } => {
                let gs = problem.grad_star(wi);
                if with_c {
                    // LINT-ALLOW(no-panic): `with_c` implies a C compressor
                    // by the constructor contract (validated before any
                    // thread spawns); worker state can't lose it mid-run.
                    let cc = c.as_mut().expect("star with_c needs a C compressor");
                    sub_into(&grad, gs, &mut diff);
                    cc.compress_into(&mut rng, &diff, &mut c_pkt);
                    c_pkt.quantize(prec);
                    payload_bits += c_bits.bits(&c_pkt, prec);
                    // worker's own new shift h = ∇f(x*) + C(∇f − ∇f(x*))
                    h.copy_from_slice(gs);
                    c_pkt.add_scaled_into(1.0, &mut h);
                    wire::encode_into(&c_pkt, prec, &mut c_buf);
                    frames.c_frame = Some(std::mem::take(&mut c_buf));
                } else {
                    h.copy_from_slice(gs);
                }
                sub_into(&grad, &h, &mut diff);
                let pkt = ef::compress_uplink(
                    q.as_ref(),
                    &mut rng,
                    uplink.as_mut(),
                    &diff,
                    prec,
                    &mut q_pkt,
                );
                payload_bits += q_bits.bits(pkt, prec);
                wire::encode_into(pkt, prec, &mut frames.q_frame);
            }
            MethodKind::Diana { alpha, with_c } => {
                sub_into(&grad, &h, &mut diff);
                if with_c {
                    // LINT-ALLOW(no-panic): `with_c` implies a C compressor
                    // by the constructor contract (validated before any
                    // thread spawns); worker state can't lose it mid-run.
                    let cc = c.as_mut().expect("diana with_c needs a C compressor");
                    cc.compress_into(&mut rng, &diff, &mut c_pkt);
                    c_pkt.quantize(prec);
                    payload_bits += c_bits.bits(&c_pkt, prec);
                    // residual v − c stays in diff (O(nnz) application)
                    c_pkt.add_scaled_into(-1.0, &mut diff);
                    wire::encode_into(&c_pkt, prec, &mut c_buf);
                    frames.c_frame = Some(std::mem::take(&mut c_buf));
                }
                let pkt = ef::compress_uplink(
                    q.as_ref(),
                    &mut rng,
                    uplink.as_mut(),
                    &diff,
                    prec,
                    &mut q_pkt,
                );
                payload_bits += q_bits.bits(pkt, prec);
                // shift learning h += α(c + q), straight from the packets —
                // the master applies the identical update to its replica
                // (on the EF path c is the wire packet C(e + v), same deal)
                if with_c {
                    c_pkt.add_scaled_into(alpha, &mut h);
                }
                pkt.add_scaled_into(alpha, &mut h);
                wire::encode_into(pkt, prec, &mut frames.q_frame);
            }
            MethodKind::RandDiana { p } => {
                sub_into(&grad, &h, &mut diff);
                let pkt = ef::compress_uplink(
                    q.as_ref(),
                    &mut rng,
                    uplink.as_mut(),
                    &diff,
                    prec,
                    &mut q_pkt,
                );
                payload_bits += q_bits.bits(pkt, prec);
                wire::encode_into(pkt, prec, &mut frames.q_frame);
                if rng.bernoulli(p) {
                    // Shift refresh as a delta vs the master's replica:
                    // h_new = ∇f = h + diff, so only diff's support travels
                    // (sparse when x moved sparsely since the last refresh).
                    // Both ends apply the identical quantized packet, so
                    // the replicas stay bit-equal; h lands within one
                    // rounding of ∇f_i(x^k).
                    let r_pkt = wire::build_update_packet(&diff, 1.0, prec, &mut refresh_scratch);
                    r_pkt.add_scaled_into(1.0, &mut h);
                    refresh_bits += r_bits.bits(r_pkt, prec);
                    wire::encode_into(r_pkt, prec, &mut refresh_buf);
                    frames.refresh = Some(std::mem::take(&mut refresh_buf));
                }
            }
        }

        if !script.is_empty() && script.garbage_uplink_at(k) {
            // see the batched-path twin above: frame-only corruption
            frames.q_frame.clear();
            frames.q_frame.extend_from_slice(&[0xBA, 0xAD, 0xF0, 0x0D]);
        }
        let wire_bytes = frames.q_frame.len()
            + frames.c_frame.as_ref().map(|f| f.len()).unwrap_or(0)
            + frames.refresh.as_ref().map(|f| f.len()).unwrap_or(0);
        if up_tx
            .send(WorkerUpdate {
                worker: wi,
                k,
                frames,
                payload_bits,
                refresh_bits,
                wire_bytes,
                compute_secs: t0.elapsed().as_secs_f64(),
                failure: None,
                needs_resync: false,
                replica_bytes: (x_loc.len() * 8) as u64,
                overlay_nnz: replica.overlay_nnz() as u64,
            })
            .is_err()
        {
            break; // master gone
        }
    }
}

impl DistributedRunner {
    /// Construct the cluster. `qs` are the per-worker Q_i compressors,
    /// `cs` the optional per-worker C_i (required when the method carries a
    /// C-frame). Shifts, RNG streams and x⁰ match
    /// [`crate::algorithms::DcgdShift`] exactly for the same seed.
    pub fn new(
        problem: Arc<dyn Problem>,
        qs: Vec<Box<dyn Compressor>>,
        cs: Option<Vec<Box<dyn Compressor>>>,
        shifts: Vec<Vec<f64>>,
        cfg: ClusterConfig,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        assert_eq!(qs.len(), n);
        assert_eq!(shifts.len(), n);
        if let Some(links) = &cfg.links {
            assert_eq!(links.len(), n);
        }
        let needs_c = matches!(
            cfg.method,
            MethodKind::Star { with_c: true } | MethodKind::Diana { with_c: true, .. }
        );
        if needs_c {
            assert!(
                cs.as_ref().map(|v| v.len()) == Some(n),
                "method requires one C_i per worker"
            );
        }
        assert!(
            cfg.local_steps >= 1 && cfg.local_steps <= u16::MAX as usize,
            "local_steps must be in 1..=65535 (the batch frame's count field)"
        );
        if cfg.local_steps > 1 {
            assert!(
                matches!(
                    cfg.method,
                    MethodKind::Fixed | MethodKind::Diana { with_c: false, .. }
                ),
                "local-step batching (local_steps > 1) supports the fixed-shift and \
                 DIANA-without-C methods; {:?} ships one frame per round",
                cfg.method
            );
        }
        assert!(
            cfg.round_timeout_ms > 0,
            "round_timeout_ms must be positive — a zero deadline would count every \
             round as missed for the whole fleet"
        );
        assert!(
            cfg.quarantine_after >= 1,
            "quarantine_after must be at least 1 (quarantine on the first miss)"
        );
        if let Some(m) = cfg.quorum {
            assert!(
                m >= 1 && m <= n,
                "quorum must lie in 1..={n} (the fleet size), got {m}"
            );
        }
        // Semi-async features cut or delay folds the workers already
        // committed locally. Under the fixed-shift method shifts never
        // move, so a cut frame only thins one round's estimator; every
        // shift-learning method folds h-updates on *both* ends and would
        // silently diverge master replica from worker state the first time
        // a frame is cut. Same story for local-step batches (the γ(τ) rule
        // for stale τ-step composites is future work), hence the gate.
        let semi_async =
            cfg.quorum.is_some_and(|m| m < n) || cfg.participation.is_some() || cfg.staleness;
        if semi_async {
            assert!(
                matches!(cfg.method, MethodKind::Fixed),
                "semi-async rounds (quorum < n, participation, staleness) require the \
                 fixed-shift method; {:?} learns shifts on both ends and a cut frame \
                 would desynchronize them",
                cfg.method
            );
            assert!(
                cfg.local_steps == 1,
                "semi-async rounds (quorum < n, participation, staleness) do not \
                 compose with local-step batching (local_steps = {})",
                cfg.local_steps
            );
        }
        if cfg.uplink_ef && cfg.quorum.is_some_and(|m| m < n) {
            assert!(
                cfg.staleness,
                "an m < n quorum with the EF uplink requires staleness: a cut frame \
                 carries error-feedback signal the worker has already retired from \
                 its accumulator, so it must fold late rather than drop"
            );
        }
        if let Some(plan) = &cfg.faults {
            for f in &plan.faults {
                assert!(
                    f.worker < n,
                    "fault plan addresses worker {} but the fleet has {n} workers",
                    f.worker
                );
            }
        }

        let mut root = Pcg64::with_stream(cfg.seed, 0xa160);
        // Bounded at n: at most one in-flight update per worker, so sends
        // go through the preallocated ring and never allocate.
        let (up_tx, up_rx) = sync_channel::<WorkerUpdate>(n);
        let mut cs_iter = cs.into_iter().flatten();

        let grad_star: Vec<Vec<f64>> = (0..n).map(|i| problem.grad_star(i).to_vec()).collect();
        let mut workers = Vec::with_capacity(n);
        for (wi, q) in qs.into_iter().enumerate() {
            let rng = root.stream(wi as u64 + 1);
            // Capacity 2: at most one outstanding Round plus a Shutdown.
            let (cmd_tx, cmd_rx) = sync_channel::<WorkerCommand>(2);
            let up_tx = up_tx.clone();
            let problem = problem.clone();
            let wcfg = WorkerCfg {
                wi,
                method: cfg.method,
                prec: cfg.prec,
                gamma: cfg.gamma,
                local_steps: cfg.local_steps,
                uplink_ef: cfg.uplink_ef,
                script: cfg
                    .faults
                    .as_ref()
                    .map(|p| p.script_for(wi))
                    .unwrap_or_default(),
            };
            let h0 = shifts[wi].clone();
            let c = if needs_c { cs_iter.next() } else { None };
            let handle = std::thread::Builder::new()
                .name(format!("shiftcomp-worker-{wi}"))
                .spawn(move || worker_loop(wcfg, problem, q, c, h0, rng, cmd_rx, up_tx))
                // LINT-ALLOW(no-panic): construction time, before any round
                // runs — a spawn failure here is an OS resource error the
                // caller can't degrade around, not a round-path fault.
                .expect("spawn worker thread");
            workers.push(WorkerThread {
                cmd_tx,
                handle: Some(handle),
            });
        }

        // Maintained Σ h_i — mirrors DcgdShift::build bit for bit (STAR
        // rebuilds shifts per round, so its sum stays zero and unused).
        let mut h_sum = vec![0.0; d];
        if !matches!(cfg.method, MethodKind::Star { .. }) {
            for h in &shifts {
                axpy(1.0, h, &mut h_sum);
            }
        }

        // Dedicated RNG stream for the downlink compressor (workers use
        // streams 1..=n) — the single-process drivers derive the identical
        // stream, so randomized downlink compressors stay bit-identical
        // across drivers. The round-0 bootstrap resync overwrites the
        // replica mirror before the first fold, so the arm-time boot value
        // never reaches a trajectory.
        let x = crate::algorithms::paper_x0(d, cfg.seed);
        let mut dl = DownlinkState::new(&x, root.stream(n as u64 + 1));
        if let Some(c) = cfg.downlink {
            dl.arm(c, &x);
        }

        // Fold pool: spawned once here, parked between rounds. The shard
        // cuts and the per-worker bound caches are sized now so pooled
        // rounds stay on the zero-allocation contract.
        let threads = pool::resolve_threads(cfg.master_threads);
        let fold_pool = FoldPool::new(threads);
        let mut cuts = Vec::with_capacity(threads + 1);
        pool::shard_cuts_into(d, threads, &mut cuts);

        // Quorum pricing: the simulated round time is the m-th fastest
        // arrival, not the max (only armed for a real m < n cut — the
        // degenerate m = n prices exactly like the barrier).
        let mut net = cfg.links.map(NetworkAccountant::new);
        if let (Some(net), Some(m)) = (net.as_mut(), cfg.quorum) {
            if m < n {
                net.set_quorum(Some(m));
            }
        }
        // The participation schedule is a pure function of (seed, n,
        // fraction) on its own RNG stream; the single-process mirror
        // constructs the identical sampler, which is what keeps cluster ≡
        // mirror bit-exact under partial participation.
        let sampler = cfg
            .participation
            .map(|f| ParticipationSampler::seeded(cfg.seed, n, f));

        Self {
            method: cfg.method,
            gamma: cfg.gamma,
            prec: cfg.prec,
            x,
            h: shifts,
            h_sum,
            grad_star,
            workers,
            up_rx,
            net,
            est: vec![0.0; d],
            q_scratch: (0..n).map(|_| Packet::Zero { dim: d as u32 }).collect(),
            c_scratch: (0..n).map(|_| Packet::Zero { dim: d as u32 }).collect(),
            slots: (0..n).map(|_| None).collect(),
            wire_bits: vec![0u64; n],
            frames_pool: (0..n).map(|_| FrameSet::default()).collect(),
            // Worst-case downlink frame: a sparse delta is only chosen
            // while its body is under the dense 8d bytes, and a resync is
            // 8d + 7 — so 8d + 32 bounds every frame. Pre-sizing keeps
            // steady-state encodes off the allocator even while the
            // delta's support is still growing.
            down_bufs: [
                Arc::new(Vec::with_capacity(d * 8 + 32)),
                Arc::new(Vec::with_capacity(d * 8 + 32)),
            ],
            delta: wire::DeltaScratch::with_capacity(d),
            dl,
            publisher: SnapshotPublisher::new(d),
            audit: AuditState::new(),
            worker_replica_bytes: vec![0u64; n],
            worker_overlay_nnz: vec![0u64; n],
            local_steps: cfg.local_steps,
            pipeline: cfg.pipeline,
            g_acc: if cfg.local_steps > 1 {
                vec![0.0; d]
            } else {
                Vec::new()
            },
            offsets: vec![0usize; n],
            compute: vec![0.0; n],
            needs_resync: true,
            resync_every: cfg.resync_every,
            round: 0,
            states: vec![WorkerState::Active; n],
            n_active: n,
            misses: vec![0u32; n],
            rejoining: vec![false; n],
            resync_flags: vec![false; n],
            last_failures: (0..n).map(|_| None).collect(),
            degraded_rounds: 0,
            round_timeout: Duration::from_millis(cfg.round_timeout_ms),
            quarantine_after: cfg.quarantine_after as u32,
            poisoned: None,
            pool: fold_pool,
            cuts,
            q_bounds: (0..n).map(|_| Vec::with_capacity(threads + 1)).collect(),
            c_bounds: (0..n).map(|_| Vec::with_capacity(threads + 1)).collect(),
            fold_failures: (0..n).map(|_| None).collect(),
            fold_flags: vec![false; n],
            refresh_flags: vec![false; n],
            h_views: Vec::with_capacity(n),
            master_secs: 0.0,
            quorum: cfg.quorum,
            staleness: cfg.staleness,
            sampler,
            sampled: vec![true; n],
            stale_slots: (0..n).map(|_| None).collect(),
            stale_scratch: (0..n).map(|_| Packet::Zero { dim: d as u32 }).collect(),
            stale_bounds: (0..n).map(|_| Vec::with_capacity(threads + 1)).collect(),
            stale_flags: vec![false; n],
            stale_failures: (0..n).map(|_| None).collect(),
            alive_flags: vec![false; n],
            pending_decode: Vec::with_capacity(n),
            delta_bounds: Vec::with_capacity(threads + 1),
        }
    }

    /// Replace the iterate out of band. The next broadcast ships a dense
    /// resync frame so worker replicas re-converge to the new state.
    pub fn set_x0(&mut self, x0: Vec<f64>) {
        assert_eq!(x0.len(), self.x.len());
        self.x = x0;
        self.needs_resync = true;
    }

    /// Master-side reconstruction of a worker's shift (tests).
    pub fn shift(&self, worker: usize) -> &[f64] {
        &self.h[worker]
    }

    /// Snapshot a worker thread's private state (shift + iterate replica)
    /// via an [`WorkerCommand::Inspect`] round-trip. Debug/ops only — the
    /// worker must be idle, which it is between [`Algorithm::step`] calls.
    pub fn worker_snapshot(&self, worker: usize) -> WorkerSnapshot {
        let (tx, rx) = sync_channel(1);
        self.workers[worker]
            .cmd_tx
            .send(WorkerCommand::Inspect { reply: tx })
            // LINT-ALLOW(no-panic): debug/ops introspection off the round
            // path — a dead worker here should fail the inspecting test
            // loudly, not degrade.
            .expect("worker thread died");
        // LINT-ALLOW(blocking-recv): same debug/ops path; the worker is
        // idle by contract and answers immediately or the send above has
        // already panicked.
        // LINT-ALLOW(no-panic): see the send above.
        rx.recv().expect("worker thread died")
    }

    /// The EF downlink's error accumulator `x_master − x_replica`
    /// (`None` on the exact path). Zero right after any resync.
    pub fn ef_error(&self) -> Option<&[f64]> {
        self.dl.ef_error()
    }

    /// Master-side bit-exact mirror of the worker replicas (`None` on the
    /// exact path, where the master iterate itself is the mirror). Between
    /// steps the mirror leads the workers by the one in-flight frame: it
    /// already includes the next round's EfDelta, which workers apply at
    /// the start of their next round — compare a [`Self::worker_snapshot`]
    /// taken after step k+1 against the mirror read after step k.
    pub fn replica_mirror(&self) -> Option<&[f64]> {
        self.dl.replica()
    }

    pub fn simulated_time(&self) -> f64 {
        self.net.as_ref().map(|n| n.sim_time).unwrap_or(0.0)
    }

    /// Cumulative master-CPU seconds across completed rounds: broadcast
    /// encode, uplink decode, fold and downlink build — the gather wait is
    /// excluded, so this isolates the work the fold pool parallelizes.
    /// `benches/perf_coordinator.rs` breaks it out per round and per T.
    pub fn master_seconds(&self) -> f64 {
        self.master_secs
    }

    /// Resolved fold-pool width (shards), after auto-sizing
    /// ([`ClusterConfig::master_threads`]).
    pub fn fold_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Sharded `h_sum += a · h[wi]` — the quarantine/rejoin O(d) shift
    /// move, run on the fold pool. Bit-identical to the serial `axpy`:
    /// shards own disjoint coordinate ranges and apply the identical
    /// per-coordinate expression.
    fn shift_sum_axpy(&mut self, a: f64, wi: usize) {
        let cuts = &self.cuts;
        let src = &self.h[wi];
        let dst = ShardView::new(&mut self.h_sum);
        self.pool.run(&|s| {
            let (lo, hi) = (cuts[s], cuts[s + 1]);
            if lo < hi {
                // SAFETY: shard ranges are disjoint, so each shard holds
                // the only live reference into h_sum[lo..hi].
                axpy(a, &src[lo..hi], unsafe { dst.slice(lo, hi) });
            }
        });
    }

    /// Master-side health snapshot: per-worker participation state,
    /// consecutive-miss counters, the degraded-round count — the
    /// observable surface of the quarantine machinery (see the module
    /// doc) — plus the per-worker replica-memory gauges
    /// (private-dense-replica bytes and overlay nnz, as each worker
    /// reported them with its last update).
    pub fn health(&self) -> RunnerHealth {
        RunnerHealth {
            states: self.states.clone(),
            active_workers: self.n_active,
            degraded_rounds: self.degraded_rounds,
            consecutive_misses: self.misses.clone(),
            replica_bytes: self.worker_replica_bytes.clone(),
            overlay_nnz: self.worker_overlay_nnz.clone(),
        }
    }

    /// The most recent failure recorded for `worker` (quarantine reason,
    /// or the failure the worker itself reported), if any.
    pub fn last_failure(&self, worker: usize) -> Option<&WorkerFailure> {
        self.last_failures[worker].as_ref()
    }

    /// Re-admit a quarantined worker whose thread is still alive (the
    /// straggler case). The master re-adds the worker's shift replica to
    /// the maintained `h_sum` (the exact inverse of the quarantine
    /// subtraction, so a quarantine/rejoin pair is fp-reproducible on
    /// both drivers) and, on the next round, ships a
    /// [`WorkerCommand::Rejoin`] bootstrap: a dense resync of the current
    /// iterate plus the shift replica. The worker overwrites its local
    /// state and flushes its EF uplink accumulator — the same state-reset
    /// rule every resync applies. With the EF downlink armed, the whole
    /// fleet resyncs too (a private bootstrap would break the shared
    /// replica mirror's uniformity; this also means EF-downlink rejoin
    /// rounds are not bit-pinned against the mirror).
    ///
    /// `Active` workers are a no-op; `Failed` workers (thread gone)
    /// return an error naming the crash.
    pub fn rejoin(&mut self, worker: usize) -> Result<(), WorkerFailure> {
        match self.states[worker] {
            WorkerState::Active => return Ok(()),
            WorkerState::Failed => {
                return Err(WorkerFailure {
                    worker,
                    round: self.round,
                    class: FailureClass::Crash,
                    detail: "worker thread has exited and cannot rejoin".into(),
                })
            }
            WorkerState::Quarantined => {}
        }
        self.states[worker] = WorkerState::Active;
        self.n_active += 1;
        self.misses[worker] = 0;
        self.rejoining[worker] = true;
        if !matches!(self.method, MethodKind::Star { .. }) {
            self.shift_sum_axpy(1.0, worker);
        }
        if let Some(net) = &mut self.net {
            net.set_worker_active(worker, true);
        }
        if self.dl.is_armed() {
            self.needs_resync = true;
        }
        Ok(())
    }

    /// Take `wi` out of the round rotation: subtract its shift replica
    /// from the maintained `h_sum` in one O(d) pass (the aggregate then
    /// reweights to the survivors), stop counting it toward gathers and
    /// record why. Promoting an already-quarantined worker to `Failed`
    /// must not subtract twice, and a `Failed` worker never demotes back
    /// to `Quarantined`.
    fn quarantine_worker(&mut self, wi: usize, state: WorkerState, failure: WorkerFailure) {
        if self.states[wi] == WorkerState::Active {
            if !matches!(self.method, MethodKind::Star { .. }) {
                self.shift_sum_axpy(-1.0, wi);
            }
            self.n_active -= 1;
            if let Some(net) = &mut self.net {
                net.set_worker_active(wi, false);
            }
        }
        if self.states[wi] != WorkerState::Failed {
            self.states[wi] = state;
        }
        self.misses[wi] = 0;
        self.rejoining[wi] = false;
        self.last_failures[wi] = Some(failure);
    }

    /// Record a fatal failure: every later `try_step` returns this same
    /// error without touching the degraded state (sticky poisoning).
    fn poison(&mut self, f: WorkerFailure) -> WorkerFailure {
        self.poisoned = Some(f.clone());
        f
    }
}

impl Algorithm for DistributedRunner {
    fn name(&self) -> String {
        match self.method {
            MethodKind::Fixed => "dist-dcgd-shift(fixed)".into(),
            MethodKind::Star { .. } => "dist-dcgd-star".into(),
            MethodKind::Diana { .. } => "dist-diana".into(),
            MethodKind::RandDiana { .. } => "dist-rand-diana".into(),
        }
    }

    fn compressor_desc(&self) -> String {
        "distributed".into()
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn step(&mut self, p: &dyn Problem) -> StepStats {
        // the panic-free path is try_step; this trait wrapper preserves
        // the Algorithm contract by panicking with the structured context
        // (round + worker id + detail) the failure carries
        match self.try_step(p) {
            Ok(stats) => stats,
            // LINT-ALLOW(no-panic): the infallible Algorithm::step trait
            // contract demands it — this is the documented panicking
            // wrapper around the panic-free try_step, not a round path.
            Err(f) => panic!("{f}"),
        }
    }
}

/// `what` names the offending frame in a master-side decode failure.
fn frame_failure(wi: usize, round: usize, what: &str, e: wire::WireError) -> WorkerFailure {
    WorkerFailure {
        worker: wi,
        round,
        class: FailureClass::Protocol,
        detail: format!("malformed {what} from worker: {e}"),
    }
}

/// Master-side uplink decode with the same dimension guard the workers
/// apply to downlink frames: a well-formed packet of the wrong dimension
/// must surface as a structured failure, not as the `assert` inside
/// `add_scaled_into` (which would break [`DistributedRunner::try_step`]'s
/// panic-free contract).
fn decode_checked(
    bytes: &[u8],
    out: &mut Packet,
    d: usize,
    wi: usize,
    round: usize,
    what: &str,
) -> Result<(), WorkerFailure> {
    wire::decode_into(bytes, out).map_err(|e| frame_failure(wi, round, what, e))?;
    if out.dim() != d {
        return Err(WorkerFailure {
            worker: wi,
            round,
            class: FailureClass::Protocol,
            detail: format!(
                "{what} dimension mismatch: frame carries {}, expected {d}",
                out.dim()
            ),
        });
    }
    Ok(())
}

impl DistributedRunner {
    /// One round over the active fleet, degrading gracefully on worker
    /// failures (quarantine + reweighted aggregation — see the module
    /// doc). Returns `Err` only when the cluster can never gather again
    /// (every worker thread exited); the error is sticky — the runner is
    /// poisoned and every later call returns the same [`WorkerFailure`]
    /// without touching state. [`Algorithm::step`] wraps this and panics
    /// with the same round + worker context.
    pub fn try_step(&mut self, _p: &dyn Problem) -> Result<StepStats, WorkerFailure> {
        if let Some(f) = &self.poisoned {
            return Err(f.clone());
        }
        let n = self.workers.len();
        let d = self.x.len();
        let round = self.round;
        let parity = self.round % 2;
        if self.states.iter().all(|s| *s == WorkerState::Failed) {
            return Err(self.poison(WorkerFailure {
                worker: WorkerFailure::NO_WORKER,
                round,
                class: FailureClass::Crash,
                detail: "every worker thread has exited; the cluster cannot recover".into(),
            }));
        }
        // non-reporters must not leak the previous round's traffic or
        // compute into this round's pricing
        for wi in 0..n {
            self.wire_bits[wi] = 0;
            self.compute[wi] = 0.0;
            self.resync_flags[wi] = false;
            self.alive_flags[wi] = false;
        }
        // partial participation: draw this round's seeded sample S_k
        // (exactly one draw per round — the mirror replays the identical
        // schedule). Without a sampler the mask stays all-true.
        if let Some(sampler) = self.sampler.as_mut() {
            sampler.next_round();
            self.sampled.copy_from_slice(sampler.mask());
        }
        // master-CPU accounting: the broadcast span is charged here, the
        // post-gather span inside finish_step — the gather wait between
        // them is the workers' time, not the master's
        let broadcast_started = Instant::now();

        // broadcast: this round's downlink frame. The delta was pre-encoded
        // at the end of the previous round into the double-buffered Arc;
        // resync rounds overwrite it with the dense iterate (always f64 —
        // resync re-establishes bit-exact replica state regardless of the
        // delta precision). The buffer for this parity was last broadcast
        // two rounds ago; every worker has since completed a later `recv`,
        // which happens only after it dropped that round's handle — so the
        // refcount is 1 and the encode is in place. (Defensive fallback
        // allocates; unreachable in steady state.)
        // Periodic resyncs skip round 0: the bootstrap resync
        // (`needs_resync`, set at construction) already covers it. The
        // `round != 0` guard makes that explicit rather than changing the
        // schedule — round 0 short-circuits on `needs_resync` either way —
        // so the periodic term can never silently become the only thing
        // standing between a fresh replica and an unsynced round 0.
        let resync = self.needs_resync
            || (self.resync_every != 0 && self.round != 0 && self.round % self.resync_every == 0);
        if resync {
            let buf = &mut self.down_bufs[parity];
            if let Some(b) = Arc::get_mut(buf) {
                wire::encode_down_dense(DownKind::Resync, &self.x, ValPrec::F64, b);
            } else {
                let mut b = Vec::with_capacity(d * 8 + 32);
                wire::encode_down_dense(DownKind::Resync, &self.x, ValPrec::F64, &mut b);
                *buf = Arc::new(b);
            }
            self.needs_resync = false;
            // a resync overwrites every replica with the master iterate:
            // flush the EF error accumulator (nothing is pending any more)
            // and bring the replica mirror back to exact equality
            self.dl.resync(&self.x);
        }
        let down_frame_bits = self.down_bufs[parity].len() as u64 * 8;
        // publish this round's shared iterate: one copy of x^k into the
        // double-buffered snapshot slot plus the EF overlay patch (−e^k on
        // its support; empty on the exact path and right after a resync).
        // Every worker reads the iterate through these two Arcs — the
        // fleet holds one iterate, not n.
        let (gen, snap, patch) = self.publisher.publish(&self.x, self.dl.overlay());
        // debug-build audits (no-ops in release — see
        // [`crate::coordinator::invariants`]): generations advance by
        // exactly one, and the published overlay is −e on the EF
        // residual support
        self.audit.note_publish(gen);
        invariants::audit_overlay_support(&self.dl);
        // rejoin bootstraps all share one dense resync frame, encoded
        // lazily on the first rejoining arm of the round into the recycled
        // buffer (a per-arm encode would spike O(d) allocations on
        // mass-rejoin rounds; rounds without a commanded rejoiner skip the
        // encode entirely)
        let mut rejoin_down: Option<Arc<Vec<u8>>> = None;
        // broadcast to the active fleet only. `try_send` keeps the master
        // deadlock-free: a hung worker eventually fills its capacity-2
        // command queue, and a blocking send there would stall the fleet
        // forever. A full queue counts as this round's miss; a
        // disconnected channel is a confirmed thread exit.
        let mut expected = 0usize;
        for wi in 0..n {
            if self.states[wi] != WorkerState::Active {
                continue;
            }
            if !self.sampled[wi] {
                // sampled out of S_k: a sync-only command keeps this
                // worker's replica generation-fresh at zero compute (no
                // RNG draw, no reply, no gather slot, no miss penalty).
                // A rejoining worker stays flagged for the next round it
                // is sampled — deferring its bootstrap is safe because
                // partial participation requires the fixed-shift method,
                // so its h_i cannot drift meanwhile. A jammed queue is
                // harmless (commands install in order, so the worker
                // catches up on the next successful send); a disconnect
                // is a confirmed thread exit either way.
                match self.workers[wi].cmd_tx.try_send(WorkerCommand::Sync {
                    k: self.round,
                    gen,
                    snap: snap.clone(),
                    patch: patch.clone(),
                }) {
                    Ok(()) | Err(TrySendError::Full(_)) => {}
                    Err(TrySendError::Disconnected(_)) => {
                        self.quarantine_worker(
                            wi,
                            WorkerState::Failed,
                            WorkerFailure {
                                worker: wi,
                                round,
                                class: FailureClass::Crash,
                                detail: "worker thread has exited (channel disconnected)".into(),
                            },
                        );
                    }
                }
                continue;
            }
            let recycled = std::mem::take(&mut self.frames_pool[wi]);
            let cmd = if self.rejoining[wi] {
                // rejoin bootstrap: the shared dense resync frame from the
                // *current* iterate plus the master's replica of this
                // worker's shift (the off-hot-path `h` clone is fine —
                // rejoin is exceptional)
                let down = match &rejoin_down {
                    Some(frame) => frame.clone(),
                    None => {
                        let frame = self.dl.rejoin_frame(&self.x);
                        rejoin_down = Some(frame.clone());
                        frame
                    }
                };
                WorkerCommand::Rejoin {
                    k: self.round,
                    down,
                    gen,
                    snap: snap.clone(),
                    patch: patch.clone(),
                    h: self.h[wi].clone(),
                    recycled,
                }
            } else {
                WorkerCommand::Round {
                    k: self.round,
                    down: self.down_bufs[parity].clone(),
                    gen,
                    snap: snap.clone(),
                    patch: patch.clone(),
                    recycled,
                }
            };
            match self.workers[wi].cmd_tx.try_send(cmd) {
                Ok(()) => {
                    self.rejoining[wi] = false;
                    expected += 1;
                }
                Err(TrySendError::Full(cmd)) => {
                    // queue jammed: reclaim the buffers, let the miss
                    // accounting below decide on quarantine
                    let (WorkerCommand::Round { recycled, .. }
                    | WorkerCommand::Rejoin { recycled, .. }) = cmd
                    else {
                        unreachable!("only round/rejoin commands are broadcast")
                    };
                    self.frames_pool[wi] = recycled;
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.quarantine_worker(
                        wi,
                        WorkerState::Failed,
                        WorkerFailure {
                            worker: wi,
                            round,
                            class: FailureClass::Crash,
                            detail: "worker thread has exited (channel disconnected)".into(),
                        },
                    );
                }
            }
        }

        self.master_secs += broadcast_started.elapsed().as_secs_f64();

        // gather (any arrival order; folded in worker order for exact
        // fp-reproducibility): an **event-driven** round. The master
        // blocks for the first arrival of each burst, then greedily
        // drains everything already queued and validates + decodes the
        // whole burst on the fold pool — overlapping the master's decode
        // CPU with the wait for the remaining workers, so by the time the
        // round closes only the serial accounting and the
        // coordinate-sharded fold remain. With a `quorum` configured the
        // round closes as soon as that many fresh updates have been
        // admitted; one deadline still bounds the whole wait either way,
        // so no fault configuration — hung workers, crashed threads, any
        // mix — can stall the master past `round_timeout_ms`.
        let method = self.method;
        let needs_c = matches!(
            method,
            MethodKind::Star { with_c: true } | MethodKind::Diana { with_c: true, .. }
        );
        // decode-on-arrival runs only on the per-round path: the τ > 1
        // batched fold re-walks each frame sub-step-major and keeps its
        // own pooled validation pass below
        let arrival_decode = self.local_steps == 1;
        // with no quorum (or m ≥ the commanded count) the early close
        // below can never fire before `received == expected` — the
        // degenerate barrier round, bit-identical to the pre-quorum
        // gather
        let quorum_target = self.quorum.map(|m| m.min(expected)).unwrap_or(expected);
        let mut closed_by_quorum = false;
        let deadline = Instant::now() + self.round_timeout;
        let mut received = 0usize;
        let mut admitted = 0usize;
        'gather: while received < expected {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let mut next = match self.up_rx.recv_timeout(remaining) {
                Ok(upd) => Some(upd),
                Err(RecvTimeoutError::Timeout) => break 'gather,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.poison(WorkerFailure {
                        worker: WorkerFailure::NO_WORKER,
                        round,
                        class: FailureClass::Crash,
                        detail: "every worker thread has exited".into(),
                    }));
                }
            };
            self.pending_decode.clear();
            while let Some(upd) = next {
                let wi = upd.worker;
                // any arrival — fresh or stale — proves the thread alive
                // this round; the miss accounting below credits it
                self.alive_flags[wi] = true;
                if upd.k != round {
                    if self.staleness
                        && upd.k + 1 == round
                        && upd.failure.is_none()
                        && !upd.needs_resync
                        && self.states[wi] == WorkerState::Active
                        && self.stale_slots[wi].is_none()
                    {
                        // one-round-late gradient (the tail a quorum close
                        // cut): admit it into THIS round's aggregate under
                        // the delayed-gradient damping, decoded in the
                        // same pooled burst as the fresh arrivals
                        self.stale_slots[wi] = Some(upd);
                        if arrival_decode {
                            self.pending_decode.push((wi, true));
                        }
                    } else {
                        // stale beyond the one-round window (or staleness
                        // unarmed, or the sender left the rotation):
                        // reclaim the buffers, don't fold
                        self.frames_pool[wi] = upd.frames;
                    }
                    next = self.up_rx.try_recv().ok();
                    continue;
                }
                self.worker_replica_bytes[wi] = upd.replica_bytes;
                self.worker_overlay_nnz[wi] = upd.overlay_nnz;
                if upd.needs_resync {
                    // the worker detected a snapshot-generation gap and
                    // declined to compute against the stale base:
                    // reclaim the buffers and schedule the rejoin
                    // bootstrap for the next round. The thread is alive
                    // and well-behaved — the arrival counts toward the
                    // gather and carries no miss penalty.
                    self.frames_pool[wi] = upd.frames;
                    self.rejoining[wi] = true;
                    self.resync_flags[wi] = true;
                    received += 1;
                } else {
                    // each worker is charged its own measured compute
                    // when the round is priced (staged/pipelined models)
                    self.compute[wi] = upd.compute_secs;
                    let clean = upd.failure.is_none();
                    self.slots[wi] = Some(upd);
                    received += 1;
                    if clean {
                        // a failure-carrying update occupies its slot for
                        // the quarantine pass below but is never decoded
                        // and never advances the quorum
                        admitted += 1;
                        if arrival_decode {
                            self.pending_decode.push((wi, false));
                        }
                    }
                }
                next = self.up_rx.try_recv().ok();
            }
            // pooled on-arrival decode of the burst: worker-sharded
            // (`wi % T == s`), each shard walking its own workers' frames
            // into their private scratch packets — worker-local state
            // only, so no fp hazard; verdicts land in `fold_failures` /
            // `stale_failures` for the serial passes to quarantine in
            // worker order. This is Pass 1 of the per-round fold, run
            // burst-by-burst while the gather is still waiting.
            if !self.pending_decode.is_empty() {
                let decode_started = Instant::now();
                let threads = self.pool.threads();
                let slots = &self.slots;
                let stale_slots = &self.stale_slots;
                let cuts = &self.cuts;
                let batch = &self.pending_decode;
                let q_scratch = ShardView::new(&mut self.q_scratch[..]);
                let c_scratch = ShardView::new(&mut self.c_scratch[..]);
                let q_bounds = ShardView::new(&mut self.q_bounds[..]);
                let c_bounds = ShardView::new(&mut self.c_bounds[..]);
                let failures = ShardView::new(&mut self.fold_failures[..]);
                let stale_scratch = ShardView::new(&mut self.stale_scratch[..]);
                let stale_bounds = ShardView::new(&mut self.stale_bounds[..]);
                let stale_failures = ShardView::new(&mut self.stale_failures[..]);
                self.pool.run(&|s| {
                    for &(wi, is_stale) in batch {
                        if wi % threads != s {
                            continue;
                        }
                        if is_stale {
                            // a queued (wi, true) entry always has a stale
                            // slot; skipping a missing one keeps the shard
                            // closure panic-free
                            let Some(upd) = stale_slots[wi].as_ref() else {
                                continue;
                            };
                            // SAFETY: worker wi belongs to exactly one
                            // shard (wi % threads == s), so these element
                            // borrows are disjoint across shards.
                            let (q, qb, fail) = unsafe {
                                (
                                    stale_scratch.at(wi),
                                    stale_bounds.at(wi),
                                    stale_failures.at(wi),
                                )
                            };
                            // staleness requires the fixed-shift method
                            // (asserted at construction), so a stale
                            // update carries exactly one Q frame
                            *fail = decode_checked(
                                &upd.frames.q_frame,
                                q,
                                d,
                                wi,
                                upd.k,
                                "stale Q frame",
                            )
                            .err();
                            if fail.is_none() {
                                q.shard_bounds_into(cuts, qb);
                            }
                        } else {
                            // as above: a queued (wi, false) entry always
                            // has a fresh slot
                            let Some(upd) = slots[wi].as_ref() else {
                                continue;
                            };
                            // SAFETY: as above — disjoint per-worker
                            // element borrows.
                            let (q, c, qb, cb, fail) = unsafe {
                                (
                                    q_scratch.at(wi),
                                    c_scratch.at(wi),
                                    q_bounds.at(wi),
                                    c_bounds.at(wi),
                                    failures.at(wi),
                                )
                            };
                            *fail = decode_update_frames(method, wi, round, d, upd, q, c).err();
                            if fail.is_none() {
                                q.shard_bounds_into(cuts, qb);
                                let c_folds = needs_c
                                    || (matches!(method, MethodKind::RandDiana { .. })
                                        && upd.frames.refresh.is_some());
                                if c_folds {
                                    c.shard_bounds_into(cuts, cb);
                                }
                            }
                        }
                    }
                });
                // decode CPU is master work even though it runs inside
                // the gather span — it displaces the former post-gather
                // Pass 1
                self.master_secs += decode_started.elapsed().as_secs_f64();
            }
            if admitted >= quorum_target && received < expected {
                closed_by_quorum = true;
                break 'gather;
            }
        }

        let work_started = Instant::now();

        // a worker-reported failure means the sender's thread exits right
        // after the update: quarantine it as Failed and keep going over
        // the survivors
        for wi in 0..n {
            if self.slots[wi].as_ref().is_some_and(|u| u.failure.is_some()) {
                // the guard above makes the pattern irrefutable in
                // practice; the else arm keeps the path panic-free
                let Some(WorkerUpdate {
                    frames,
                    failure: Some(failure),
                    ..
                }) = self.slots[wi].take()
                else {
                    continue;
                };
                self.frames_pool[wi] = frames;
                self.quarantine_worker(wi, WorkerState::Failed, failure);
            }
        }

        // deadline-miss accounting: an Active worker without a fresh slot
        // missed this round (gather timeout or jammed command queue).
        // Sampled-out workers are frozen — no credit, no penalty. Any
        // arrival this round (a stale frame included) resets the counter:
        // a worker that keeps reporting just behind the quorum close is
        // slow, not stuck.
        for wi in 0..n {
            if self.states[wi] != WorkerState::Active {
                continue;
            }
            if !self.sampled[wi] {
                continue;
            }
            if self.slots[wi].is_some() || self.resync_flags[wi] || self.alive_flags[wi] {
                self.misses[wi] = 0;
                continue;
            }
            self.misses[wi] += 1;
            // a quorum-closed round is weak evidence: the missing update
            // may simply be the (m+1)-th fastest, already in flight. One
            // extra consecutive miss is required before quarantining, so
            // a perpetually-just-late worker is never cut (its stale
            // arrivals keep resetting the counter above) while a
            // genuinely dead worker still quarantines deterministically,
            // one round later.
            let threshold = if closed_by_quorum {
                self.quarantine_after + 1
            } else {
                self.quarantine_after
            };
            if self.misses[wi] >= threshold {
                let failure = WorkerFailure {
                    worker: wi,
                    round,
                    class: FailureClass::Timeout,
                    detail: format!(
                        "missed the {}ms gather deadline on {} consecutive round(s)",
                        self.round_timeout.as_millis(),
                        self.misses[wi]
                    ),
                };
                self.quarantine_worker(wi, WorkerState::Quarantined, failure);
            }
        }

        let mut bits_up = 0u64;
        let mut bits_refresh = 0u64;

        if self.local_steps > 1 {
            // ---- batched fold: sub-step-major replay of the τ local
            // steps. est^t is seeded from the maintained shift sum *as of
            // sub-step t*, each worker's t-th wire packet is folded in at
            // O(nnz), and Diana shift learning advances per sub-step
            // exactly as the workers applied it locally; the round's
            // aggregate Σ_t est^t accumulates in g_acc and ships as one
            // composite downlink delta. DcgdShift::step_batched mirrors
            // this loop op for op.
            //
            // Validation first: frame structure and every sub-step packet
            // are decode-checked before any aggregate arithmetic, so a
            // malformed batch quarantines its sender instead of aborting
            // a half-replayed round. The pass is worker-sharded on the
            // fold pool (`wi % T == s`): each shard walks its own
            // workers' frames into their private scratch, so there is no
            // fp hazard; verdicts land in `fold_failures` and the serial
            // accounting below quarantines in worker order.
            let local_steps = self.local_steps;
            {
                let threads = self.pool.threads();
                let slots = &self.slots;
                let q_scratch = ShardView::new(&mut self.q_scratch[..]);
                let offsets = ShardView::new(&mut self.offsets[..]);
                let failures = ShardView::new(&mut self.fold_failures[..]);
                self.pool.run(&|s| {
                    let mut wi = s;
                    while wi < n {
                        if let Some(upd) = slots[wi].as_ref() {
                            // SAFETY: worker wi belongs to exactly one
                            // shard (wi % threads == s), so these element
                            // borrows are disjoint across shards.
                            let (q, off, fail) = unsafe {
                                (q_scratch.at(wi), offsets.at(wi), failures.at(wi))
                            };
                            match validate_batch_frame(local_steps, wi, round, d, upd, q) {
                                Ok(first) => {
                                    *off = first;
                                    *fail = None;
                                }
                                Err(f) => *fail = Some(f),
                            }
                        }
                        wi += threads;
                    }
                });
            }
            for wi in 0..n {
                if self.slots[wi].is_none() {
                    self.fold_flags[wi] = false;
                    continue;
                }
                if let Some(f) = self.fold_failures[wi].take() {
                    if let Some(upd) = self.slots[wi].take() {
                        self.frames_pool[wi] = upd.frames;
                    }
                    self.quarantine_worker(wi, WorkerState::Quarantined, f);
                    self.fold_flags[wi] = false;
                    continue;
                }
                // the is_none guard above makes this irrefutable; the else
                // arm keeps the path panic-free
                let Some(upd) = self.slots[wi].as_ref() else {
                    self.fold_flags[wi] = false;
                    continue;
                };
                bits_up += upd.payload_bits;
                bits_refresh += upd.refresh_bits;
                self.wire_bits[wi] = upd.wire_bytes as u64 * 8;
                self.fold_flags[wi] = true;
            }
            let reporters = self.fold_flags.iter().filter(|&&f| f).count();
            {
                // sharded zero of the accumulator (elementwise writes:
                // trivially bit-identical to the serial pass)
                let cuts = &self.cuts;
                let g_view = ShardView::new(&mut self.g_acc);
                self.pool.run(&|s| {
                    let (lo, hi) = (cuts[s], cuts[s + 1]);
                    if lo < hi {
                        // SAFETY: shard ranges are disjoint.
                        zero(unsafe { g_view.slice(lo, hi) });
                    }
                });
            }
            if reporters > 0 {
                let inv = 1.0 / reporters as f64;
                let star = matches!(method, MethodKind::Star { .. });
                for _t in 0..local_steps {
                    // sub-step decode: worker-sharded cursor advance into
                    // each reporter's scratch packet + shard-bound lookup
                    {
                        let threads = self.pool.threads();
                        let slots = &self.slots;
                        let cuts = &self.cuts;
                        let folds = &self.fold_flags;
                        let q_scratch = ShardView::new(&mut self.q_scratch[..]);
                        let q_bounds = ShardView::new(&mut self.q_bounds[..]);
                        let offsets = ShardView::new(&mut self.offsets[..]);
                        self.pool.run(&|s| {
                            let mut wi = s;
                            while wi < n {
                                // a set fold flag implies a slot; pattern-
                                // matching both keeps the closure panic-free
                                if let (true, Some(upd)) = (folds[wi], slots[wi].as_ref()) {
                                    // SAFETY: disjoint per-worker elements
                                    // (wi % threads == s).
                                    let (q, qb, off) = unsafe {
                                        (q_scratch.at(wi), q_bounds.at(wi), offsets.at(wi))
                                    };
                                    *off =
                                        wire::decode_batch_packet(&upd.frames.q_frame, *off, q)
                                            // LINT-ALLOW(no-panic): every
                                            // sub-step packet was decode-
                                            // checked by the batch validation
                                            // pass before any fold, so this
                                            // cursor advance cannot fail; the
                                            // pool turns a shard panic into a
                                            // loud master abort, never UB.
                                            .expect("batch frame validated above");
                                    q.shard_bounds_into(cuts, qb);
                                }
                                wi += threads;
                            }
                        });
                    }
                    // sub-step fold: coordinate-sharded replay of the
                    // serial worker-order op sequence (see the module doc)
                    self.h_views.clear();
                    for h in self.h.iter_mut() {
                        self.h_views.push(ShardView::new(&mut h[..]));
                    }
                    {
                        let cuts = &self.cuts;
                        let states = &self.states;
                        let folds = &self.fold_flags;
                        let q_scratch = &self.q_scratch;
                        let q_bounds = &self.q_bounds;
                        let h_views = &self.h_views;
                        let est_view = ShardView::new(&mut self.est);
                        let h_sum_view = ShardView::new(&mut self.h_sum);
                        let g_view = ShardView::new(&mut self.g_acc);
                        self.pool.run(&|s| {
                            let (lo, hi) = (cuts[s], cuts[s + 1]);
                            if lo == hi {
                                return;
                            }
                            // SAFETY: shard ranges are disjoint, so each
                            // shard holds the only live references into
                            // est/h_sum/g_acc/h[wi] over [lo, hi).
                            let est = unsafe { est_view.slice(lo, hi) };
                            // SAFETY: same disjoint shard range as est.
                            let h_sum = unsafe { h_sum_view.slice(lo, hi) };
                            ax_into(inv, h_sum, est);
                            if !star {
                                // transiently-missed Active workers:
                                // excluded from this sub-step's estimator
                                // without touching h_sum (Diana's permanent
                                // shift learning keeps flowing through the
                                // maintained sum)
                                for wi in 0..n {
                                    if states[wi] == WorkerState::Active && !folds[wi] {
                                        // SAFETY: disjoint shard range.
                                        let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                                        axpy(-inv, h_wi, est);
                                    }
                                }
                            }
                            for wi in 0..n {
                                if !folds[wi] {
                                    continue;
                                }
                                let qb = (q_bounds[wi][s], q_bounds[wi][s + 1]);
                                q_scratch[wi].add_scaled_range(inv, lo, hi, qb, est);
                                if let MethodKind::Diana { alpha, .. } = method {
                                    // SAFETY: disjoint shard range.
                                    let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                                    q_scratch[wi].add_scaled_range(alpha, lo, hi, qb, h_wi);
                                    q_scratch[wi].add_scaled_range(alpha, lo, hi, qb, h_sum);
                                }
                            }
                            // SAFETY: same disjoint shard range as est.
                            axpy(1.0, est, unsafe { g_view.slice(lo, hi) });
                        });
                    }
                }
                self.h_views.clear();
            }
            for wi in 0..n {
                if let Some(upd) = self.slots[wi].take() {
                    self.frames_pool[wi] = upd.frames;
                }
            }
            return Ok(self.finish_step(
                reporters,
                expected,
                down_frame_bits,
                bits_up,
                bits_refresh,
                work_started,
            ));
        }

        // ---- per-round fold (see the "Parallel fold" section of the
        // module doc). Pass 1 — the worker-sharded pooled decode —
        // already ran **inside the gather**, burst by burst as updates
        // arrived, so the scratch packets and their shard bounds are
        // populated and `fold_failures` / `stale_failures` carry the
        // verdicts.
        //
        // Pass 2 — serial accounting, in worker order: quarantine decode
        // failures, tally bits, recycle frame buffers, and mark who folds.
        for wi in 0..n {
            if self.slots[wi].is_none() {
                self.fold_flags[wi] = false;
                self.refresh_flags[wi] = false;
                continue;
            }
            if let Some(f) = self.fold_failures[wi].take() {
                if let Some(upd) = self.slots[wi].take() {
                    self.frames_pool[wi] = upd.frames;
                }
                self.quarantine_worker(wi, WorkerState::Quarantined, f);
                self.fold_flags[wi] = false;
                self.refresh_flags[wi] = false;
                continue;
            }
            // the is_none guard above makes this irrefutable; the else arm
            // keeps the path panic-free
            let Some(upd) = self.slots[wi].take() else {
                self.fold_flags[wi] = false;
                self.refresh_flags[wi] = false;
                continue;
            };
            bits_up += upd.payload_bits;
            bits_refresh += upd.refresh_bits;
            self.wire_bits[wi] = upd.wire_bytes as u64 * 8;
            self.fold_flags[wi] = true;
            self.refresh_flags[wi] = upd.frames.refresh.is_some();
            // recycle the consumed frame buffers back to this worker
            self.frames_pool[wi] = upd.frames;
        }
        let reporters = self.fold_flags.iter().filter(|&&f| f).count();

        if reporters == 0 {
            // fully-degraded round: nobody fresh reported, the iterate
            // holds (the zero estimator ships as an empty delta). Stale
            // admissions, if any, are reclaimed rather than folded — a
            // damped late gradient with no fresh reporter to anchor the
            // round is not worth a special-cased denominator.
            for wi in 0..n {
                self.stale_flags[wi] = false;
                self.stale_failures[wi] = None;
                if let Some(upd) = self.stale_slots[wi].take() {
                    self.frames_pool[wi] = upd.frames;
                }
            }
            zero(&mut self.est);
            return Ok(self.finish_step(
                0,
                expected,
                down_frame_bits,
                bits_up,
                bits_refresh,
                work_started,
            ));
        }

        // Pass 2b — stale admissions, same serial worker-order discipline
        // as the fresh pass: a one-round-late gradient (admitted by the
        // gather under `staleness`) folds into THIS round damped by
        // λ = [`crate::theory::staleness::damping`](1); decode failures
        // quarantine their sender, bits tally into this round's
        // accounting, frames recycle. A worker that reported both stale
        // and fresh this round keeps both contributions — the weighted
        // denominator below turns the pair into a proper weighted
        // average of its two gradients.
        for wi in 0..n {
            self.stale_flags[wi] = false;
            let Some(upd) = self.stale_slots[wi].take() else {
                continue;
            };
            if let Some(f) = self.stale_failures[wi].take() {
                self.frames_pool[wi] = upd.frames;
                self.quarantine_worker(wi, WorkerState::Quarantined, f);
                continue;
            }
            if self.states[wi] != WorkerState::Active {
                // left the rotation between admission and fold (e.g. its
                // fresh frame this round was malformed): reclaim, don't
                // fold
                self.frames_pool[wi] = upd.frames;
                continue;
            }
            bits_up += upd.payload_bits;
            self.wire_bits[wi] += upd.wire_bytes as u64 * 8;
            // buffer-recycling collision: when this worker ALSO reported
            // fresh, the fresh FrameSet already occupies the pool slot
            // and this overwrite drops it — one transient allocation on
            // the worker's next encode, accepted off the common path
            self.frames_pool[wi] = upd.frames;
            self.stale_flags[wi] = true;
        }
        let stale_folds = self.stale_flags.iter().filter(|&&f| f).count();
        // weighted denominator: fresh gradients at weight 1, stale at λ.
        // With no stale folds `reporters + λ·0` is bitwise `reporters`
        // (x + 0.0 ≡ x for x > 0), so the barrier path is untouched.
        let lam = crate::theory::staleness::damping(1);
        let inv = 1.0 / (reporters as f64 + lam * stale_folds as f64);

        // Pass 3 — coordinate-sharded fold: each shard replays the full
        // serial op sequence — shift-sum seed, missed-worker subtraction,
        // then the per-reporter method ops in worker order — restricted to
        // its coordinate range, so every coordinate sees the unchanged fp
        // sequence and the result is bit-identical for every T.
        self.h_views.clear();
        for h in self.h.iter_mut() {
            self.h_views.push(ShardView::new(&mut h[..]));
        }
        let star = matches!(method, MethodKind::Star { .. });
        {
            let cuts = &self.cuts;
            let states = &self.states;
            let folds = &self.fold_flags;
            let stales = &self.stale_flags;
            let refreshes = &self.refresh_flags;
            let q_scratch = &self.q_scratch;
            let c_scratch = &self.c_scratch;
            let stale_scratch = &self.stale_scratch;
            let q_bounds = &self.q_bounds;
            let c_bounds = &self.c_bounds;
            let stale_bounds = &self.stale_bounds;
            let grad_star = &self.grad_star;
            let h_views = &self.h_views;
            let est_view = ShardView::new(&mut self.est);
            let h_sum_view = ShardView::new(&mut self.h_sum);
            self.pool.run(&|s| {
                let (lo, hi) = (cuts[s], cuts[s + 1]);
                if lo == hi {
                    return;
                }
                // SAFETY: shard ranges are disjoint, so each shard holds
                // the only live references into est/h_sum/h[wi] over
                // [lo, hi).
                let est = unsafe { est_view.slice(lo, hi) };
                // SAFETY: same disjoint shard range as est.
                let h_sum = unsafe { h_sum_view.slice(lo, hi) };
                // g^k seeded from the maintained shift sum, then each
                // compressed message folded in at O(nnz of the shard).
                // Transiently-missed Active workers are excluded from this
                // round's estimator without touching h_sum.
                ax_into(inv, h_sum, est);
                if !star {
                    for wi in 0..n {
                        if states[wi] == WorkerState::Active && !folds[wi] {
                            // SAFETY: disjoint shard range.
                            let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                            axpy(-inv, h_wi, est);
                        }
                    }
                }
                for wi in 0..n {
                    if !folds[wi] {
                        continue;
                    }
                    let qb = (q_bounds[wi][s], q_bounds[wi][s + 1]);
                    match method {
                        MethodKind::Fixed => {
                            q_scratch[wi].add_scaled_range(inv, lo, hi, qb, est);
                        }
                        MethodKind::Star { with_c } => {
                            // reconstruct the worker's same-round shift in
                            // place
                            // SAFETY: disjoint shard range.
                            let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                            h_wi.copy_from_slice(&grad_star[wi][lo..hi]);
                            if with_c {
                                let cb = (c_bounds[wi][s], c_bounds[wi][s + 1]);
                                c_scratch[wi].add_scaled_range(1.0, lo, hi, cb, h_wi);
                            }
                            axpy(inv, h_wi, est);
                            q_scratch[wi].add_scaled_range(inv, lo, hi, qb, est);
                        }
                        MethodKind::Diana { alpha, with_c } => {
                            // SAFETY: disjoint shard range.
                            let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                            if with_c {
                                let cb = (c_bounds[wi][s], c_bounds[wi][s + 1]);
                                c_scratch[wi].add_scaled_range(inv, lo, hi, cb, est);
                                c_scratch[wi].add_scaled_range(alpha, lo, hi, cb, h_wi);
                                c_scratch[wi].add_scaled_range(alpha, lo, hi, cb, h_sum);
                            }
                            q_scratch[wi].add_scaled_range(inv, lo, hi, qb, est);
                            q_scratch[wi].add_scaled_range(alpha, lo, hi, qb, h_wi);
                            q_scratch[wi].add_scaled_range(alpha, lo, hi, qb, h_sum);
                        }
                        MethodKind::RandDiana { .. } => {
                            q_scratch[wi].add_scaled_range(inv, lo, hi, qb, est);
                            if refreshes[wi] {
                                // sparse shift-refresh delta: h_new = h + Δ,
                                // applied identically to the replica and the
                                // maintained sum (the worker applied the
                                // same packet to its h)
                                // SAFETY: disjoint shard range.
                                let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                                let cb = (c_bounds[wi][s], c_bounds[wi][s + 1]);
                                c_scratch[wi].add_scaled_range(1.0, lo, hi, cb, h_wi);
                                c_scratch[wi].add_scaled_range(1.0, lo, hi, cb, h_sum);
                            }
                        }
                    }
                }
                // stale folds ride after the fresh reporters, in worker
                // order: the estimator gains λ·inv·(h_i + q_i^{k−1}) per
                // stale admission. The missed-worker subtraction above
                // already removed the full inv·h_i for a stale-only
                // worker, so adding λ·inv·h_i back here leaves exactly
                // the damped weight. (Staleness requires the fixed-shift
                // method — asserted at construction — so no shift
                // learning replays here.)
                for wi in 0..n {
                    if !stales[wi] {
                        continue;
                    }
                    let sb = (stale_bounds[wi][s], stale_bounds[wi][s + 1]);
                    // SAFETY: disjoint shard range.
                    let h_wi = unsafe { h_views[wi].slice(lo, hi) };
                    axpy(lam * inv, h_wi, est);
                    stale_scratch[wi].add_scaled_range(lam * inv, lo, hi, sb, est);
                }
            });
        }
        self.h_views.clear();

        Ok(self.finish_step(
            reporters,
            expected,
            down_frame_bits,
            bits_up,
            bits_refresh,
            work_started,
        ))
    }

}

/// Validation-pass decode of one reporter's frames into that worker's
/// scratch packets (no aggregate state is touched): the Q frame always,
/// the C frame when the method requires one (missing ⇒ protocol failure),
/// the Rand-DIANA refresh delta when present. Runs before any fold
/// arithmetic so a malformed frame cleanly quarantines its sender. A free
/// function (worker-local inputs only) so the parallel decode pass can
/// call it from any shard thread.
fn decode_update_frames(
    method: MethodKind,
    wi: usize,
    round: usize,
    d: usize,
    upd: &WorkerUpdate,
    q_scratch: &mut Packet,
    c_scratch: &mut Packet,
) -> Result<(), WorkerFailure> {
    let needs_c = matches!(
        method,
        MethodKind::Star { with_c: true } | MethodKind::Diana { with_c: true, .. }
    );
    if needs_c {
        let cf = upd.frames.c_frame.as_deref().ok_or_else(|| WorkerFailure {
            worker: wi,
            round,
            class: FailureClass::Protocol,
            detail: "missing C frame".into(),
        })?;
        decode_checked(cf, c_scratch, d, wi, round, "C frame")?;
    }
    decode_checked(&upd.frames.q_frame, q_scratch, d, wi, round, "Q frame")?;
    if let (MethodKind::RandDiana { .. }, Some(refresh)) = (method, &upd.frames.refresh) {
        decode_checked(refresh, c_scratch, d, wi, round, "refresh frame")?;
    }
    Ok(())
}

/// Validation-pass decode of one reporter's batched frame: the header
/// must carry exactly `local_steps` packets and every packet must decode
/// at the cluster dimension. Returns the payload offset of the first
/// packet for the fold pass to re-walk. Free for the same reason as
/// [`decode_update_frames`].
fn validate_batch_frame(
    local_steps: usize,
    wi: usize,
    round: usize,
    d: usize,
    upd: &WorkerUpdate,
    q_scratch: &mut Packet,
) -> Result<usize, WorkerFailure> {
    let (count, first) = wire::split_batch_frame(&upd.frames.q_frame)
        .map_err(|e| frame_failure(wi, round, "batch frame", e))?;
    if count != local_steps {
        return Err(WorkerFailure {
            worker: wi,
            round,
            class: FailureClass::Protocol,
            detail: format!("batch frame carries {count} packets, expected {local_steps}"),
        });
    }
    let mut off = first;
    for _ in 0..count {
        off = wire::decode_batch_packet(&upd.frames.q_frame, off, q_scratch)
            .map_err(|e| frame_failure(wi, round, "batch packet", e))?;
        if q_scratch.dim() != d {
            return Err(WorkerFailure {
                worker: wi,
                round,
                class: FailureClass::Protocol,
                detail: format!(
                    "batch packet dimension mismatch: frame carries {}, expected {d}",
                    q_scratch.dim()
                ),
            });
        }
    }
    Ok(first)
}

impl DistributedRunner {
    /// Shared tail of both round shapes: take the gradient step through
    /// the downlink delta packet, pre-encode next round's broadcast into
    /// the retired buffer, advance the round counter and price the round.
    /// `reporters` is the number of workers whose updates folded into the
    /// round; `broadcast_count` the number that received this round's
    /// downlink frame (they differ when a worker missed its deadline).
    /// `work_started` marks when the post-gather master work began — its
    /// span lands in [`DistributedRunner::master_seconds`] here, once the
    /// downlink is built.
    fn finish_step(
        &mut self,
        reporters: usize,
        broadcast_count: usize,
        down_frame_bits: u64,
        bits_up: u64,
        bits_refresh: u64,
        work_started: Instant,
    ) -> StepStats {
        // a round is degraded when some worker's contribution went
        // missing *unexpectedly*: sampled-out workers were excluded by
        // design and a quorum-cut worker whose frame folded late (a
        // stale fold this round) did contribute. Without a sampler and
        // without staleness both extra terms are zero and this reduces
        // exactly to the historical `reporters < n`.
        let sampled_out = self
            .states
            .iter()
            .zip(self.sampled.iter())
            .filter(|&(s, &on)| *s == WorkerState::Active && !on)
            .count();
        let stale_folds = self.stale_flags.iter().filter(|&&f| f).count();
        if reporters + stale_folds + sampled_out < self.workers.len() {
            self.degraded_rounds += 1;
        }
        let d = self.x.len();
        // gradient step, via the same delta packet the workers will apply:
        // x += 1·(−γ·g) with identical roundings on both ends, so master
        // and replicas stay bit-equal (and bit-identical to the dense
        // axpy(−γ, g, x) reference on every touched coordinate). Batched
        // rounds ship the composite Σ_t est^t the same way. On the EF path
        // the master still steps exactly; the *broadcast* is the
        // compressed C(e + Δ) and the residual stays in the accumulator.
        let kind = if self.dl.is_armed() {
            DownKind::EfDelta
        } else {
            DownKind::Delta
        };
        let g: &[f64] = if self.local_steps > 1 {
            &self.g_acc
        } else {
            &self.est
        };
        let delta = wire::build_update_packet(g, -self.gamma, self.prec, &mut self.delta);
        // pooled apply: x += 1·delta, coordinate-sharded on the fold
        // pool. Elementwise-disjoint writes, so bit-identical to the
        // serial `add_scaled_into` for every pool width.
        delta.shard_bounds_into(&self.cuts, &mut self.delta_bounds);
        {
            let cuts = &self.cuts;
            let db = &self.delta_bounds;
            let xv = ShardView::new(&mut self.x);
            self.pool.run(&|s| {
                let (lo, hi) = (cuts[s], cuts[s + 1]);
                if lo < hi {
                    // SAFETY: shard ranges are disjoint.
                    delta.add_scaled_range(1.0, lo, hi, (db[s], db[s + 1]), unsafe {
                        xv.slice(lo, hi)
                    });
                }
            });
        }
        // keep the replica mirror bit-equal to the workers: same packet,
        // same operation — on the EF path this also rebuilds the overlay
        // (−e on its support) and re-materializes the mirror x̂ through
        // the same kernel the workers use. The EF compress itself stays
        // serial (compressor tie-breaking is order-sensitive); the O(d)
        // mirror materialization is sharded on the pool.
        let pool = &self.pool;
        let cuts = &self.cuts;
        let bcast: &Packet =
            self.dl
                .fold_packet_pooled(delta, &self.x, self.prec, &|f| pool.run(f), cuts);
        // pre-encode next round's downlink into the buffer this round
        // retired (all round-k updates are in, so every worker has dropped
        // its handle from round k−1)
        {
            let buf = &mut self.down_bufs[(self.round + 1) % 2];
            if let Some(b) = Arc::get_mut(buf) {
                wire::encode_down_into(kind, bcast, self.prec, b);
            } else {
                let mut b = Vec::with_capacity(d * 8 + 32);
                wire::encode_down_into(kind, bcast, self.prec, &mut b);
                *buf = Arc::new(b);
            }
        }
        // debug-build audits (no-ops in release): the EF mirror identity
        // x_replica + e ≈ x_master after the fold, and a periodic re-sum
        // of the incrementally maintained h_sum over the active shifts
        invariants::audit_ef_mirror(&self.x, &self.dl);
        if self.round % 64 == 0 {
            invariants::audit_h_sum(&self.h_sum, &self.h, &self.states, self.method);
        }
        self.round += 1;

        // measured downlink cost: the frame each worker actually received.
        // The legacy per-round protocol keeps the historical comm-only
        // pricing (existing τ = 1 sim clocks stay comparable across PRs);
        // batched rounds price each worker's own measured compute too,
        // overlapped with its uplink transfer when pipelining is on.
        let bits_down = broadcast_count as u64 * down_frame_bits;
        if let Some(net) = &mut self.net {
            if self.sampler.is_some() {
                // partial participation: only S_k's links carry traffic
                // this round, so the round clock races the sampled subset
                // (one-shot mask, consumed by the pricing call below)
                net.set_round_mask(&self.sampled);
            }
            if self.pipeline {
                net.round_pipelined(
                    &self.wire_bits,
                    down_frame_bits,
                    &self.compute,
                    self.local_steps,
                );
            } else if self.local_steps > 1 {
                net.round_staged(&self.wire_bits, down_frame_bits, &self.compute);
            } else {
                net.round(&self.wire_bits, down_frame_bits);
            }
        }

        self.master_secs += work_started.elapsed().as_secs_f64();

        let stats = StepStats {
            bits_up,
            bits_down,
            bits_refresh,
            active_workers: reporters,
            // fleet-resident iterate storage: the two shared publication
            // slots (snapshot + overlay patch, independent of n) plus the
            // private dense bytes the workers reported (the τ > 1 local
            // iterate; 0 otherwise) — flat in the worker count on the
            // exact downlink path
            replica_bytes: self.publisher.snapshot_bytes()
                + self.publisher.patch_bytes()
                + self.worker_replica_bytes.iter().sum::<u64>(),
        };
        // debug-build audit (no-op in release): the reported footprint
        // reconciles against an independent recomputation
        invariants::audit_replica_bytes(
            d,
            &self.dl,
            &self.publisher,
            self.worker_replica_bytes.iter().sum::<u64>(),
            stats.replica_bytes,
        );
        stats
    }
}

impl Drop for DistributedRunner {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(WorkerCommand::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

// ------------------------------------------------------------ constructors

impl DistributedRunner {
    /// Distributed DIANA with homogeneous compressors and Theorem-3 steps.
    pub fn diana(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        // LINT-ALLOW(no-panic): constructor precondition, enforced before
        // any thread exists; the config layer rejects biased Q for DIANA
        // at parse time, so only direct API misuse reaches this.
        let omega = q.omega().expect("DIANA needs unbiased Q");
        let ss = crate::theory::diana(problem.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Diana {
                    alpha: ss.alpha,
                    with_c: false,
                },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
                resync_every: 0,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        )
    }

    /// Distributed Rand-DIANA with Theorem-4 steps.
    pub fn rand_diana(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        p_refresh: Option<f64>,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        // LINT-ALLOW(no-panic): constructor precondition (see `diana`).
        let omega = q.omega().expect("Rand-DIANA needs unbiased Q");
        let pr = p_refresh.unwrap_or_else(|| crate::theory::rand_diana_default_p(omega));
        let ss = crate::theory::rand_diana(problem.as_ref(), omega, &vec![pr; n], None);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::RandDiana { p: pr },
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
                resync_every: 0,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        )
    }

    /// Distributed plain DCGD (zero fixed shifts, Theorem-1 step).
    pub fn dcgd(
        problem: Arc<dyn Problem>,
        q: impl Compressor + Clone + 'static,
        seed: u64,
        links: Option<Vec<LinkModel>>,
    ) -> Self {
        let n = problem.n_workers();
        let d = problem.dim();
        // LINT-ALLOW(no-panic): constructor precondition (see `diana`).
        let omega = q.omega().expect("DCGD needs unbiased Q");
        let ss = crate::theory::dcgd_fixed(problem.as_ref(), &vec![omega; n]);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        Self::new(
            problem,
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method: MethodKind::Fixed,
                gamma: ss.gamma,
                prec: ValPrec::F64,
                seed,
                links,
                resync_every: 0,
                local_steps: 1,
                pipeline: false,
                downlink: None,
                uplink_ef: false,
                ..Default::default()
            },
        )
    }
}

/// Bare-worker harness for protocol-level tests: direct channel handles to
/// a single worker thread plus command constructors for hand-crafted
/// frames. Used by the in-file protocol-failure tests and by
/// `rust/tests/shared_replica.rs` (generation-gap behaviour); not part of
/// the public API surface.
#[doc(hidden)]
pub mod test_harness {
    use super::*;
    use crate::compressors::RandK;
    use crate::coordinator::replica::OverlayPatch;
    use crate::problems::Ridge;

    /// Spawn a bare worker thread (fixed-shift method, exact uplink over
    /// a small Ridge problem) with direct channel handles so tests can
    /// feed it hand-crafted downlink commands. Returns
    /// `(cmd_tx, up_rx, join_handle, dim)`.
    pub fn spawn_bare_worker(
        wi: usize,
    ) -> (
        SyncSender<WorkerCommand>,
        Receiver<WorkerUpdate>,
        JoinHandle<()>,
        usize,
    ) {
        let p: Arc<dyn Problem> = Arc::new(Ridge::paper_default(9));
        let d = p.dim();
        let (cmd_tx, cmd_rx) = sync_channel(2);
        let (up_tx, up_rx) = sync_channel(1);
        let cfg = WorkerCfg {
            wi,
            method: MethodKind::Fixed,
            prec: ValPrec::F64,
            gamma: 0.1,
            local_steps: 1,
            uplink_ef: false,
            script: WorkerFaultScript::default(),
        };
        let q: Box<dyn Compressor> = Box::new(RandK::with_q(d, 0.5));
        let h = vec![0.0; d];
        let rng = Pcg64::with_stream(1, wi as u64 + 1);
        let handle =
            std::thread::spawn(move || worker_loop(cfg, p, q, None, h, rng, cmd_rx, up_tx));
        (cmd_tx, up_rx, handle, d)
    }

    /// A `Round` command carrying `frame` under an explicit snapshot
    /// publication `(gen, snap, patch)`.
    pub fn round_cmd_gen(
        k: usize,
        frame: Vec<u8>,
        gen: u64,
        snap: Arc<Vec<f64>>,
        patch: Arc<OverlayPatch>,
    ) -> WorkerCommand {
        WorkerCommand::Round {
            k,
            down: Arc::new(frame),
            gen,
            snap,
            patch,
            recycled: FrameSet::default(),
        }
    }

    /// A `Round` command for frame-defect tests: the worker must reject
    /// `frame` before ever touching the (empty) snapshot publication.
    pub fn round_cmd(k: usize, frame: Vec<u8>) -> WorkerCommand {
        round_cmd_gen(
            k,
            frame,
            1,
            Arc::new(Vec::new()),
            Arc::new(OverlayPatch::new()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::test_harness::{round_cmd, spawn_bare_worker};
    use super::*;
    use crate::algorithms::RunOpts;
    use crate::compressors::RandK;
    use crate::problems::Ridge;

    #[test]
    fn distributed_diana_converges() {
        let p = Arc::new(Ridge::paper_default(5));
        let mut runner =
            DistributedRunner::diana(p.clone(), RandK::with_q(p.dim(), 0.5), 5, None);
        let trace = runner.run(
            p.as_ref(),
            &RunOpts {
                max_rounds: 15_000,
                tol: 1e-6,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(
            trace.converged || trace.final_relative_error() < 1e-5,
            "err {:e}",
            trace.final_relative_error()
        );
    }

    #[test]
    fn network_accounting_advances() {
        let p = Arc::new(Ridge::paper_default(6));
        let links = vec![LinkModel::default(); p.n_workers()];
        let mut runner = DistributedRunner::rand_diana(
            p.clone(),
            RandK::with_q(p.dim(), 0.2),
            None,
            6,
            Some(links),
        );
        for _ in 0..10 {
            runner.step(p.as_ref());
        }
        assert!(runner.simulated_time() > 0.0);
        let net = runner.net.as_ref().unwrap();
        assert_eq!(net.rounds, 10);
        assert!(net.total_up_bits > 0);
    }

    #[test]
    fn clean_shutdown_on_drop() {
        let p = Arc::new(Ridge::paper_default(7));
        {
            let mut runner =
                DistributedRunner::dcgd(p.clone(), RandK::with_q(p.dim(), 0.5), 7, None);
            runner.step(p.as_ref());
        } // drop must join all threads without hanging
    }

    // -------------------------------------- protocol failures (fail fast)

    /// A garbage downlink frame must produce a structured failure carrying
    /// the round and worker id — and a clean thread exit, not a panic that
    /// leaves the master deadlocked on the gather.
    #[test]
    fn malformed_downlink_reports_structured_failure() {
        let (cmd_tx, up_rx, handle, _d) = spawn_bare_worker(3);
        cmd_tx
            .send(round_cmd(7, vec![0xBA, 0xAD, 0xF0, 0x0D]))
            .unwrap();
        let upd = up_rx.recv().expect("the failure update must arrive");
        let f = upd.failure.expect("failure must be set");
        assert_eq!(f.worker, 3);
        assert_eq!(f.round, 7);
        assert!(
            f.detail.contains("malformed downlink frame"),
            "unhelpful detail: {}",
            f.detail
        );
        // the Display form carries the full context the master panics with
        let msg = f.to_string();
        assert!(msg.contains("worker 3") && msg.contains("round 7"), "{msg}");
        handle.join().expect("worker must exit cleanly, not panic");
    }

    /// A resync frame whose packet is not dense is mis-kinded: structured
    /// failure, clean exit.
    #[test]
    fn non_dense_resync_reports_structured_failure() {
        let (cmd_tx, up_rx, handle, d) = spawn_bare_worker(1);
        let pkt = Packet::Sparse {
            dim: d as u32,
            indices: vec![0],
            values: vec![1.0],
            scale: 1.0,
        };
        let mut frame = Vec::new();
        wire::encode_down_into(DownKind::Resync, &pkt, ValPrec::F64, &mut frame);
        cmd_tx.send(round_cmd(2, frame)).unwrap();
        let f = up_rx.recv().unwrap().failure.expect("failure must be set");
        assert_eq!((f.worker, f.round), (1, 2));
        assert!(f.detail.contains("resync frame must be dense"), "{}", f.detail);
        handle.join().unwrap();
    }

    /// The master-side twin of the worker's dimension guard: a decodable
    /// uplink packet of the wrong dimension must yield a structured
    /// failure from `decode_checked`, not reach `add_scaled_into`'s
    /// assert (which would panic inside the panic-free `try_step`).
    #[test]
    fn master_decode_guard_catches_wrong_dimension() {
        let pkt = Packet::Zero { dim: 5 };
        let bytes = wire::encode(&pkt, ValPrec::F64);
        let mut out = Packet::Zero { dim: 0 };
        assert!(decode_checked(&bytes, &mut out, 5, 0, 0, "Q frame").is_ok());
        let err = decode_checked(&bytes, &mut out, 6, 2, 3, "Q frame").unwrap_err();
        assert_eq!((err.worker, err.round), (2, 3));
        assert!(err.detail.contains("dimension mismatch"), "{}", err.detail);
    }

    /// A well-formed frame of the wrong dimension must not abort the
    /// thread inside `copy_from_slice`/`add_scaled_into`: structured
    /// failure, clean exit.
    #[test]
    fn wrong_dimension_downlink_reports_structured_failure() {
        let (cmd_tx, up_rx, handle, d) = spawn_bare_worker(0);
        let pkt = Packet::Zero {
            dim: (d + 1) as u32,
        };
        let mut frame = Vec::new();
        wire::encode_down_into(DownKind::Delta, &pkt, ValPrec::F64, &mut frame);
        cmd_tx.send(round_cmd(0, frame)).unwrap();
        let f = up_rx.recv().unwrap().failure.expect("failure must be set");
        assert!(f.detail.contains("dimension mismatch"), "{}", f.detail);
        handle.join().unwrap();
    }
}
