//! Message types exchanged between the master and worker threads.

use std::sync::mpsc::SyncSender;
use std::sync::Arc;

/// What shift rule the cluster runs (worker- and master-side behaviour).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodKind {
    /// fixed shifts (plain DCGD when the shifts are zero)
    Fixed,
    /// DCGD-STAR (master knows ∇f_i(x*); `with_c` ⇒ a C-frame is sent)
    Star { with_c: bool },
    /// generalized DIANA (`with_c` ⇒ a C-frame precedes the Q-frame)
    Diana { alpha: f64, with_c: bool },
    /// Rand-DIANA with refresh probability p
    RandDiana { p: f64 },
}

/// Master → worker.
pub enum WorkerCommand {
    /// Start round k with the broadcast downlink frame.
    ///
    /// `down` is one wire-encoded frame (see [`crate::wire`]'s downlink
    /// format) shared by every worker through the `Arc`: either an iterate
    /// **delta** (x^k − x^{k−1}, applied to the worker's local replica at
    /// O(nnz)) or a dense **resync** (round 0, periodic drift checks,
    /// out-of-band iterate changes). The dense n·d broadcast of the old
    /// protocol is gone — downlink cost is the frame's actual byte size.
    ///
    /// `recycled` returns the frame buffers the master consumed from this
    /// worker's *previous* round so the worker can encode into them again —
    /// the buffer half of the zero-allocation round pipeline (the master's
    /// half recycles its decode packets; see
    /// [`crate::coordinator::DistributedRunner`]). The first round ships
    /// empty (default) frames.
    Round {
        k: usize,
        down: Arc<Vec<u8>>,
        recycled: FrameSet,
    },
    /// Debug/ops introspection: snapshot this worker's private state
    /// (current shift and iterate replica) and send it back on `reply`.
    /// Sent between rounds, when the worker is idle; the clones allocate,
    /// which is fine off the hot path. Tests use this to verify that the
    /// master's wire-reconstructed shift replicas and EF replica mirror
    /// are bit-equal to what the workers actually hold.
    Inspect { reply: SyncSender<WorkerSnapshot> },
    /// Clean shutdown.
    Shutdown,
}

/// A worker's private state at the time an [`WorkerCommand::Inspect`]
/// command was processed.
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub worker: usize,
    /// the worker's current shift h_i
    pub h: Vec<f64>,
    /// the worker's local replica of the broadcast iterate
    pub x_replica: Vec<f64>,
    /// the EF uplink's error accumulator `Σ (m − c)` (`None` when the
    /// exact uplink is running)
    pub uplink_error: Option<Vec<f64>>,
}

/// A fatal worker-side protocol failure (malformed or mis-kinded downlink
/// frame), reported through [`WorkerUpdate::failure`] so the master can
/// fail fast with full context — round and worker id — instead of
/// deadlocking on a reply that will never come. The worker thread exits
/// after sending it; the cluster is unrecoverable and must be dropped.
#[derive(Clone, Debug)]
pub struct WorkerFailure {
    /// failing worker id, or [`WorkerFailure::NO_WORKER`] when the
    /// failure cannot be attributed to one worker (every thread gone)
    pub worker: usize,
    pub round: usize,
    pub detail: String,
}

impl WorkerFailure {
    /// Sentinel `worker` value for cluster-wide failures that no single
    /// worker owns; [`Display`](std::fmt::Display) omits the worker id.
    pub const NO_WORKER: usize = usize::MAX;
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.worker == Self::NO_WORKER {
            write!(f, "cluster failed at round {}: {}", self.round, self.detail)
        } else {
            write!(
                f,
                "worker {} failed at round {}: {}",
                self.worker, self.round, self.detail
            )
        }
    }
}

impl std::error::Error for WorkerFailure {}

/// The encoded frames one worker uploads in one round.
#[derive(Debug, Default)]
pub struct FrameSet {
    /// C_i-compressor frame (STAR displacement / DIANA c-part), if any
    pub c_frame: Option<Vec<u8>>,
    /// main Q_i frame (always present): one packet frame per round, or —
    /// with `local_steps > 1` — one batched frame carrying the round's τ
    /// sub-step packets (see [`crate::wire`]'s batch format)
    pub q_frame: Vec<u8>,
    /// Rand-DIANA shift-refresh delta (sparse vs the master's replica of
    /// this worker's shift), if this round refreshed
    pub refresh: Option<Vec<u8>>,
}

impl FrameSet {
    /// Total payload bits: encoded body bits of each frame present.
    /// (Header overhead is excluded to match the single-process driver's
    /// packet-level accounting; headers are fixed 48-bit constants.)
    pub fn payload_bits(&self, header_free_bits: impl Fn(&[u8]) -> u64) -> u64 {
        let mut bits = header_free_bits(&self.q_frame);
        if let Some(c) = &self.c_frame {
            bits += header_free_bits(c);
        }
        if let Some(r) = &self.refresh {
            bits += header_free_bits(r);
        }
        bits
    }
}

/// Worker → master.
pub struct WorkerUpdate {
    pub worker: usize,
    pub k: usize,
    pub frames: FrameSet,
    /// gradient-message payload bits (packet-level, identical to the
    /// single-process driver's accounting)
    pub payload_bits: u64,
    /// shift-state sync payload bits (Rand-DIANA refreshes)
    pub refresh_bits: u64,
    /// encoded byte size actually shipped (wire accounting incl. headers)
    pub wire_bytes: usize,
    /// wall-clock seconds this worker spent in its compute phase (downlink
    /// apply + gradients + compression + local sub-steps + frame encode) —
    /// the compute input of the staged network pricing
    /// ([`crate::net::NetworkAccountant::round_staged`] /
    /// [`crate::net::NetworkAccountant::round_pipelined`])
    pub compute_secs: f64,
    /// set when the worker hit a fatal protocol error this round (all
    /// other fields are then zero/default); the sender thread exits right
    /// after this update
    pub failure: Option<WorkerFailure>,
}
