//! Message types exchanged between the master and worker threads.

use std::sync::mpsc::SyncSender;
use std::sync::Arc;

use crate::coordinator::replica::OverlayPatch;

/// What shift rule the cluster runs (worker- and master-side behaviour).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodKind {
    /// fixed shifts (plain DCGD when the shifts are zero)
    Fixed,
    /// DCGD-STAR (master knows ∇f_i(x*); `with_c` ⇒ a C-frame is sent)
    Star { with_c: bool },
    /// generalized DIANA (`with_c` ⇒ a C-frame precedes the Q-frame)
    Diana { alpha: f64, with_c: bool },
    /// Rand-DIANA with refresh probability p
    RandDiana { p: f64 },
}

/// Master → worker.
pub enum WorkerCommand {
    /// Start round k with the broadcast downlink frame and the shared
    /// iterate snapshot.
    ///
    /// `down` is one wire-encoded frame (see [`crate::wire`]'s downlink
    /// format) shared by every worker through the `Arc`: either an iterate
    /// **delta** (x^k − x^{k−1}) or a dense **resync** (round 0, periodic
    /// drift checks, out-of-band iterate changes). Workers *validate* the
    /// frame (structure + dimension, the same strictness the old
    /// decode-apply path enforced) but no longer replay it into a private
    /// replica: the iterate itself arrives as `snap` — the fleet-shared
    /// copy-on-write snapshot published under generation `gen` — plus the
    /// sparse EF-downlink overlay `patch`
    /// (see [`crate::coordinator::replica`]). A worker whose retained
    /// generation is not `gen − 1` on a delta-framed round missed a
    /// rotation and answers with [`WorkerUpdate::needs_resync`] instead of
    /// computing against a stale base.
    ///
    /// `recycled` returns the frame buffers the master consumed from this
    /// worker's *previous* round so the worker can encode into them again —
    /// the buffer half of the zero-allocation round pipeline (the master's
    /// half recycles its decode packets; see
    /// [`crate::coordinator::DistributedRunner`]). The first round ships
    /// empty (default) frames.
    Round {
        k: usize,
        down: Arc<Vec<u8>>,
        gen: u64,
        snap: Arc<Vec<f64>>,
        patch: Arc<OverlayPatch>,
        recycled: FrameSet,
    },
    /// Re-admit a quarantined-but-alive worker (the straggler case): a
    /// dense resync frame (one recycled buffer shared by every rejoin arm
    /// of the round — see `DownlinkState::rejoin_frame`), the current
    /// snapshot/patch publication, plus the master's replica of this
    /// worker's shift — the worker installs the snapshot, overwrites its
    /// `h`, flushes its EF uplink accumulator, and answers round `k` like
    /// any freshly bootstrapped worker. The off-hot-path `h` clone is
    /// fine: rejoin is an exceptional event, not a round primitive.
    Rejoin {
        k: usize,
        down: Arc<Vec<u8>>,
        gen: u64,
        snap: Arc<Vec<f64>>,
        patch: Arc<OverlayPatch>,
        h: Vec<f64>,
        recycled: FrameSet,
    },
    /// Keep a worker that was **sampled out** of round `k` (partial
    /// participation) generation-fresh without doing any work: the worker
    /// installs the publication (`gen`/`snap`/`patch`) exactly as a
    /// `Round` command would, but performs no downlink validation, no
    /// gradient, no RNG draw, and sends **no reply** — so a later `Round`
    /// command never sees a generation gap and its shift h_i is exactly
    /// where the master's replica says it is. No downlink frame rides
    /// along: under the shared-snapshot replica model the publication
    /// *is* the iterate, so frame validation has nothing to check for a
    /// worker that computes nothing.
    Sync {
        k: usize,
        gen: u64,
        snap: Arc<Vec<f64>>,
        patch: Arc<OverlayPatch>,
    },
    /// Debug/ops introspection: snapshot this worker's private state
    /// (current shift and logical iterate replica, the latter materialized
    /// from the retained snapshot + overlay) and send it back on `reply`.
    /// Sent between rounds, when the worker is idle; the clones allocate,
    /// which is fine off the hot path. Tests use this to verify that the
    /// master's wire-reconstructed shift replicas and EF replica mirror
    /// are bit-equal to what the workers actually hold.
    Inspect { reply: SyncSender<WorkerSnapshot> },
    /// Clean shutdown.
    Shutdown,
}

/// A worker's private state at the time an [`WorkerCommand::Inspect`]
/// command was processed.
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub worker: usize,
    /// the worker's current shift h_i
    pub h: Vec<f64>,
    /// the worker's **logical** replica of the broadcast iterate,
    /// materialized from the retained shared snapshot + sparse overlay
    /// (the worker holds no dense private copy)
    pub x_replica: Vec<f64>,
    /// the EF uplink's error accumulator `Σ (m − c)` (`None` when the
    /// exact uplink is running)
    pub uplink_error: Option<Vec<f64>>,
}

/// What broke: the failure class lets harness logs distinguish injected
/// faults from organic ones and pick the right operator response (a
/// [`Timeout`](Self::Timeout) worker may straggle back and rejoin; a
/// [`Protocol`](Self::Protocol) defect means corrupted wire state).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureClass {
    /// The worker's thread or channel is gone (crashed / disconnected).
    Crash,
    /// The worker missed the round deadline (straggler or hang).
    Timeout,
    /// A malformed or mis-kinded wire frame (either end's decode).
    Protocol,
}

impl FailureClass {
    /// Lower-case label used by [`WorkerFailure`]'s `Display`.
    pub fn label(self) -> &'static str {
        match self {
            FailureClass::Crash => "crash",
            FailureClass::Timeout => "timeout",
            FailureClass::Protocol => "protocol",
        }
    }
}

/// A worker-side failure (crash, deadline miss, or malformed wire frame),
/// reported through [`WorkerUpdate::failure`] or synthesized by the
/// master's deadline-bounded gather. A failing worker is quarantined and
/// the round completes over the survivors (see
/// [`crate::coordinator::DistributedRunner`]'s module doc); the failure
/// is only fatal — returned as `Err` from `try_step` — when no active
/// worker remains.
#[derive(Clone, Debug)]
pub struct WorkerFailure {
    /// failing worker id, or [`WorkerFailure::NO_WORKER`] when the
    /// failure cannot be attributed to one worker (every thread gone)
    pub worker: usize,
    pub round: usize,
    /// crash / timeout / protocol — see [`FailureClass`]
    pub class: FailureClass,
    pub detail: String,
}

impl WorkerFailure {
    /// Sentinel `worker` value for cluster-wide failures that no single
    /// worker owns; [`Display`](std::fmt::Display) omits the worker id.
    pub const NO_WORKER: usize = usize::MAX;
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.worker == Self::NO_WORKER {
            write!(
                f,
                "cluster failed at round {} [{}]: {}",
                self.round,
                self.class.label(),
                self.detail
            )
        } else {
            write!(
                f,
                "worker {} failed at round {} [{}]: {}",
                self.worker,
                self.round,
                self.class.label(),
                self.detail
            )
        }
    }
}

impl std::error::Error for WorkerFailure {}

/// A worker's participation state as the master sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// In the round rotation: receives `Round` commands, owns a gather slot.
    Active,
    /// Out of the rotation but its thread may still be alive (deadline
    /// miss / protocol defect); eligible for
    /// [`crate::coordinator::DistributedRunner::rejoin`].
    Quarantined,
    /// Thread confirmed gone (channel disconnected); cannot rejoin.
    Failed,
}

/// Master-side health snapshot
/// ([`crate::coordinator::DistributedRunner::health`]): which workers are
/// in the rotation, how degraded the run has been, and who is close to
/// quarantine.
#[derive(Clone, Debug)]
pub struct RunnerHealth {
    /// per-worker participation state
    pub states: Vec<WorkerState>,
    /// workers currently in the round rotation
    pub active_workers: usize,
    /// rounds completed with fewer reporters than configured workers
    pub degraded_rounds: usize,
    /// per-worker consecutive missed-deadline count (reset on report;
    /// quarantine triggers at the configured `quarantine_after`)
    pub consecutive_misses: Vec<u32>,
    /// per-worker bytes of **private dense iterate storage** the worker
    /// reported with its last update (0 under the shared-snapshot replica
    /// model except for the `local_steps > 1` local iterate; a regression
    /// back toward per-worker dense replicas shows up here first)
    pub replica_bytes: Vec<u64>,
    /// per-worker overlay-patch entry count (nnz) of the replica handle
    /// the worker computed its last update against (0 on the exact
    /// downlink path; bounded by the EF compressor's residual support)
    pub overlay_nnz: Vec<u64>,
}

impl RunnerHealth {
    /// True when every configured worker is active and no round degraded.
    pub fn all_healthy(&self) -> bool {
        self.degraded_rounds == 0 && self.states.iter().all(|s| *s == WorkerState::Active)
    }
}

/// The encoded frames one worker uploads in one round.
#[derive(Debug, Default)]
pub struct FrameSet {
    /// C_i-compressor frame (STAR displacement / DIANA c-part), if any
    pub c_frame: Option<Vec<u8>>,
    /// main Q_i frame (always present): one packet frame per round, or —
    /// with `local_steps > 1` — one batched frame carrying the round's τ
    /// sub-step packets (see [`crate::wire`]'s batch format)
    pub q_frame: Vec<u8>,
    /// Rand-DIANA shift-refresh delta (sparse vs the master's replica of
    /// this worker's shift), if this round refreshed
    pub refresh: Option<Vec<u8>>,
}

impl FrameSet {
    /// Total payload bits: encoded body bits of each frame present.
    /// (Header overhead is excluded to match the single-process driver's
    /// packet-level accounting; headers are fixed 48-bit constants.)
    pub fn payload_bits(&self, header_free_bits: impl Fn(&[u8]) -> u64) -> u64 {
        let mut bits = header_free_bits(&self.q_frame);
        if let Some(c) = &self.c_frame {
            bits += header_free_bits(c);
        }
        if let Some(r) = &self.refresh {
            bits += header_free_bits(r);
        }
        bits
    }
}

/// Worker → master.
pub struct WorkerUpdate {
    pub worker: usize,
    pub k: usize,
    pub frames: FrameSet,
    /// gradient-message payload bits (packet-level, identical to the
    /// single-process driver's accounting)
    pub payload_bits: u64,
    /// shift-state sync payload bits (Rand-DIANA refreshes)
    pub refresh_bits: u64,
    /// encoded byte size actually shipped (wire accounting incl. headers)
    pub wire_bytes: usize,
    /// wall-clock seconds this worker spent in its compute phase (downlink
    /// apply + gradients + compression + local sub-steps + frame encode) —
    /// the compute input of the staged network pricing
    /// ([`crate::net::NetworkAccountant::round_staged`] /
    /// [`crate::net::NetworkAccountant::round_pipelined`])
    pub compute_secs: f64,
    /// set when the worker hit a fatal protocol error this round (all
    /// other fields are then zero/default); the sender thread exits right
    /// after this update
    pub failure: Option<WorkerFailure>,
    /// set when the worker detected a snapshot-generation gap on a
    /// delta-framed round and declined to compute against the stale base;
    /// the master re-admits it through the `Rejoin` bootstrap (no
    /// deadline-miss penalty — the worker is alive and well-behaved)
    pub needs_resync: bool,
    /// bytes of private dense iterate storage this worker holds across
    /// rounds (the `local_steps` iterate and any materialization scratch
    /// that had to grow; 0 on the exact downlink path)
    pub replica_bytes: u64,
    /// overlay-patch nnz of the replica handle this update was computed
    /// against
    pub overlay_nnz: u64,
}
