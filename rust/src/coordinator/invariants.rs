//! Debug-build invariant audits for the distributed round path.
//!
//! Every audit here is a cross-check between two representations the
//! runner maintains redundantly for speed — the kind of redundancy that
//! silently drifts when a refactor touches one side and not the other.
//! Each function is a no-op in release builds (the body is gated on
//! `cfg!(debug_assertions)`, so the O(d) scans compile away together with
//! the asserts); tier-1 CI runs the test profile, which is a debug build,
//! so every audit is live on every tier-1 round.
//!
//! The audited invariants:
//!
//! * **Snapshot generations advance by exactly one** per publication
//!   ([`AuditState::note_publish`]). A skipped generation would make a
//!   healthy worker look like a gen-gap straggler and trigger a spurious
//!   resync; a repeated one would let a stale replica pass as fresh.
//! * **The overlay patch is `−e` on the EF residual support**
//!   ([`audit_overlay_support`]): same support, exactly negated values,
//!   and an empty patch whenever the downlink is exact. The patch is
//!   rebuilt from `e` every round; this catches a rebuild that went
//!   missing or ran against a stale accumulator.
//! * **The EF mirror closes the loop: `x_replica + e ≈ x_master`**
//!   ([`audit_ef_mirror`]). The mirror is re-materialized through the
//!   workers' own kernel each round; if it stops tracking
//!   `x_master − e`, master-side pricing and `Inspect` reconstructions
//!   are lying about what the fleet actually holds.
//! * **The maintained `h_sum` equals `Σ_{active} h_i`**
//!   ([`audit_h_sum`]). Quarantine subtracts a shift, rejoin adds it
//!   back, and every fold updates `h_sum` incrementally next to the
//!   per-worker replicas; a missed update shifts every later aggregate.
//!   Skipped for DCGD-STAR, which rebuilds shifts densely per round and
//!   keeps `h_sum` at zero by construction. Summation order differs
//!   between the incremental and re-summed paths, so the comparison is
//!   toleranced, not bit-exact.
//! * **`replica_bytes` accounting reconciles**
//!   ([`audit_replica_bytes`]): the published snapshot slots hold
//!   exactly two dense iterates, the patch slots shrink to zero on the
//!   exact path, and the [`crate::coordinator::runner::StepStats`] total
//!   equals publisher bytes plus the workers' reported private bytes.

use crate::coordinator::protocol::{MethodKind, WorkerState};
use crate::coordinator::replica::SnapshotPublisher;
use crate::downlink::DownlinkState;

/// Absolute floor plus relative slack for toleranced comparisons: the
/// audited quantities are re-associations of identical f64 terms, so the
/// true discrepancy is a few ulps per accumulated term — `1e-8` relative
/// leaves orders of magnitude of headroom without masking a real
/// bookkeeping bug (a dropped term shifts the sum by a whole `h_i[j]`).
const TOL: f64 = 1e-8;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= TOL * (1.0 + a.abs().max(b.abs()))
}

/// Cross-round audit state owned by the runner (one per
/// [`crate::coordinator::DistributedRunner`]).
///
/// Kept tiny and always-on: the release build pays one u64 store per
/// round, the debug build gets the generation-monotonicity assert.
#[derive(Debug, Default)]
pub struct AuditState {
    last_gen: u64,
}

impl AuditState {
    /// Fresh state; the first published generation must be `1`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a publication and assert the generation advanced by
    /// exactly one (the publisher owns the counter; the audit catches a
    /// second publish in the same round or a round that forgot to
    /// publish before handing out snapshot handles).
    pub fn note_publish(&mut self, gen: u64) {
        debug_assert_eq!(
            gen,
            self.last_gen + 1,
            "snapshot generation must advance by exactly 1 per round \
             (published {gen} after {})",
            self.last_gen
        );
        self.last_gen = gen;
    }
}

/// Audit the overlay patch against the EF error accumulator: the patch
/// must be exactly `−e` restricted to the nonzero support of `e`, and
/// must be empty when the downlink is exact (not armed).
pub fn audit_overlay_support(dl: &DownlinkState) {
    if !cfg!(debug_assertions) {
        return;
    }
    let overlay = dl.overlay();
    let Some(e) = dl.ef_error() else {
        debug_assert!(
            overlay.is_empty(),
            "exact downlink must keep an empty overlay (found {} entries)",
            overlay.nnz()
        );
        return;
    };
    let mut support = 0usize;
    for (j, v) in overlay.entries() {
        debug_assert!(
            j < e.len(),
            "overlay index {j} out of range for d = {}",
            e.len()
        );
        debug_assert!(
            e[j] != 0.0,
            "overlay entry at coordinate {j} outside the EF residual support"
        );
        debug_assert!(
            v == -e[j],
            "overlay[{j}] = {v:e} must be the exact negation of e[{j}] = {:e}",
            e[j]
        );
        support += 1;
    }
    let residual_nnz = e.iter().filter(|&&ej| ej != 0.0).count();
    debug_assert_eq!(
        support, residual_nnz,
        "overlay support ({support}) must cover the full EF residual \
         support ({residual_nnz})"
    );
}

/// Audit the EF mirror identity `x_replica + e ≈ x_master` coordinate by
/// coordinate. `(x − e) + e` re-rounds, so the check is toleranced; a
/// real bug (stale mirror, missed fold) is off by a whole step, not an
/// ulp. No-op on the exact path, where no mirror is kept.
pub fn audit_ef_mirror(x_master: &[f64], dl: &DownlinkState) {
    if !cfg!(debug_assertions) {
        return;
    }
    let (Some(replica), Some(e)) = (dl.replica(), dl.ef_error()) else {
        return;
    };
    debug_assert_eq!(replica.len(), x_master.len(), "mirror dimension drifted");
    debug_assert_eq!(e.len(), x_master.len(), "EF accumulator dimension drifted");
    for j in 0..x_master.len() {
        debug_assert!(
            close(replica[j] + e[j], x_master[j]),
            "EF invariant violated at coordinate {j}: \
             x_replica ({:e}) + e ({:e}) != x_master ({:e})",
            replica[j],
            e[j],
            x_master[j]
        );
    }
}

/// Audit the maintained aggregate shift: `h_sum[j] ≈ Σ h_i[j]` over the
/// workers still in the rotation ([`WorkerState::Active`] — quarantine
/// subtracts a shift from `h_sum` the moment it triggers, rejoin adds it
/// back). Skipped for DCGD-STAR, which aggregates dense per-round shifts
/// and pins `h_sum` at zero.
pub fn audit_h_sum(
    h_sum: &[f64],
    h: &[Vec<f64>],
    states: &[WorkerState],
    method: MethodKind,
) {
    if !cfg!(debug_assertions) {
        return;
    }
    if matches!(method, MethodKind::Star { .. }) {
        return;
    }
    debug_assert_eq!(h.len(), states.len(), "shift table / state table mismatch");
    for j in 0..h_sum.len() {
        let mut sum = 0.0;
        for (wi, hi) in h.iter().enumerate() {
            if states[wi] == WorkerState::Active {
                sum += hi[j];
            }
        }
        debug_assert!(
            close(h_sum[j], sum),
            "h_sum drifted from the active-shift re-sum at coordinate {j}: \
             maintained {:e}, re-summed {:e}",
            h_sum[j],
            sum
        );
    }
}

/// Audit the fleet-resident iterate-storage accounting reported in
/// [`crate::coordinator::runner::StepStats::replica_bytes`]:
///
/// * both publisher snapshot slots hold exactly one dense `d`-vector
///   (`2 · d · 8` bytes, independent of the worker count);
/// * on the exact path the patch slots are empty; on the EF path the
///   freshly published slot mirrors the current overlay, so the patch
///   bytes are at least the overlay's;
/// * the reported total is exactly publisher bytes plus the workers'
///   self-reported private bytes (no double counting, nothing dropped).
pub fn audit_replica_bytes(
    d: usize,
    dl: &DownlinkState,
    publisher: &SnapshotPublisher,
    worker_bytes: u64,
    reported: u64,
) {
    if !cfg!(debug_assertions) {
        return;
    }
    let snap = publisher.snapshot_bytes();
    let patch = publisher.patch_bytes();
    debug_assert_eq!(
        snap,
        (2 * d * 8) as u64,
        "snapshot slots must hold exactly two dense d-vectors"
    );
    if dl.ef_error().is_none() {
        debug_assert_eq!(
            patch, 0,
            "exact downlink must publish empty overlay patches"
        );
    } else {
        debug_assert!(
            patch >= dl.overlay().bytes(),
            "published patch bytes ({patch}) lost the current overlay \
             ({} bytes)",
            dl.overlay().bytes()
        );
    }
    debug_assert_eq!(
        reported,
        snap + patch + worker_bytes,
        "replica_bytes must reconcile: snapshot {snap} + patch {patch} \
         + worker-private {worker_bytes}"
    );
}
