//! Seeded per-round participation sampling (partial participation).
//!
//! The FedAvg-style serving regime: each round the master samples a
//! subset S_k of the fleet, |S_k| = m = max(1, round(`fraction`·n)),
//! broadcasts work to S_k only, and reweights the estimator to
//! `1/|S_k ∩ reporters|`. Workers outside S_k receive a sync-only
//! command — their replica stays generation-fresh but they perform no
//! compute, no RNG draw, and send no reply — and their shifts are left
//! untouched in the aggregate (subtracted for the round by the same
//! O(d)-axpy machinery quarantine uses). The shifted estimator stays
//! unbiased for any reporting set because the paper's shift sequence is
//! constructed independently of who reports.
//!
//! Like [`crate::coordinator::FaultPlan::seeded`], worker 0 is always
//! sampled (the fleet always has one clean, fresh reporter), and the
//! schedule is a pure function of `(seed, n, fraction)` on its own
//! disjoint RNG stream — the cluster runner and the single-process
//! mirror construct identical samplers and replay the identical
//! admission schedule, which is what keeps cluster ≡ mirror bit-exact
//! under partial participation.

use crate::util::rng::Pcg64;

/// RNG stream tag for the participation schedule (disjoint from the
/// runner's `0xa160` root, its derived worker streams, and the fault
/// plan's `0xfa17`).
const PARTICIPATION_STREAM: u64 = 0x5e1e;

/// A seeded per-round sampler of worker subsets (see the module doc).
#[derive(Clone, Debug)]
pub struct ParticipationSampler {
    rng: Pcg64,
    n: usize,
    m: usize,
    mask: Vec<bool>,
    scratch: Vec<u32>,
}

impl ParticipationSampler {
    /// Build the schedule for an `n`-worker fleet sampling a `fraction`
    /// of it per round. `fraction` must lie in (0, 1]; the sample size
    /// is `m = max(1, round(fraction·n))`, clamped to `n`.
    pub fn seeded(seed: u64, n: usize, fraction: f64) -> Self {
        assert!(n >= 1, "participation needs at least one worker");
        assert!(
            fraction > 0.0 && fraction <= 1.0,
            "participation fraction must lie in (0, 1], got {fraction}"
        );
        let m = ((fraction * n as f64).round() as usize).clamp(1, n);
        Self {
            rng: Pcg64::with_stream(seed, PARTICIPATION_STREAM),
            n,
            m,
            mask: vec![false; n],
            scratch: Vec::with_capacity(m),
        }
    }

    /// The per-round sample size m = |S_k|.
    pub fn sample_size(&self) -> usize {
        self.m
    }

    /// Draw the next round's sample S_k and return it as a mask
    /// (`mask[wi]` ⇔ wi ∈ S_k). Worker 0 is always in; the other m − 1
    /// members are a uniform subset of {1, …, n−1}. Exactly one draw per
    /// round — the cluster and the mirror must each call this once per
    /// round, in round order, to stay on the shared schedule.
    /// Allocation-free after construction.
    pub fn next_round(&mut self) -> &[bool] {
        self.mask.fill(false);
        self.mask[0] = true;
        self.rng.subset_into(self.n - 1, self.m - 1, &mut self.scratch);
        for &s in &self.scratch {
            self.mask[1 + s as usize] = true;
        }
        &self.mask
    }

    /// The most recently drawn mask (all-false before the first round).
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_spares_worker_zero() {
        let mut a = ParticipationSampler::seeded(42, 8, 0.5);
        let mut b = ParticipationSampler::seeded(42, 8, 0.5);
        assert_eq!(a.sample_size(), 4);
        for k in 0..50 {
            let ma: Vec<bool> = a.next_round().to_vec();
            let mb = b.next_round();
            assert_eq!(ma, mb, "round {k}");
            assert!(ma[0], "worker 0 must always be sampled (round {k})");
            assert_eq!(
                ma.iter().filter(|&&s| s).count(),
                4,
                "|S_k| must equal m (round {k})"
            );
        }
    }

    #[test]
    fn different_seeds_and_rounds_move_the_sample() {
        let mut a = ParticipationSampler::seeded(1, 16, 0.25);
        let mut c = ParticipationSampler::seeded(2, 16, 0.25);
        let first: Vec<bool> = a.next_round().to_vec();
        let mut any_round_differs = false;
        let mut any_seed_differs = false;
        for _ in 0..20 {
            if a.next_round() != first.as_slice() {
                any_round_differs = true;
            }
            if c.next_round() != first.as_slice() {
                any_seed_differs = true;
            }
        }
        assert!(any_round_differs, "the sample must move across rounds");
        assert!(any_seed_differs, "the sample must move across seeds");
    }

    #[test]
    fn full_participation_samples_everyone() {
        let mut s = ParticipationSampler::seeded(7, 6, 1.0);
        assert_eq!(s.sample_size(), 6);
        for _ in 0..10 {
            assert!(s.next_round().iter().all(|&on| on));
        }
    }

    #[test]
    fn tiny_fractions_clamp_to_one_worker() {
        let mut s = ParticipationSampler::seeded(7, 8, 0.01);
        assert_eq!(s.sample_size(), 1);
        let m = s.next_round();
        assert!(m[0] && m[1..].iter().all(|&on| !on));
    }

    #[test]
    #[should_panic(expected = "fraction must lie in (0, 1]")]
    fn rejects_out_of_range_fraction() {
        ParticipationSampler::seeded(7, 8, 1.5);
    }
}
