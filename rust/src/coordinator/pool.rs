//! Persistent coordinate-shard thread pool for the master's fold.
//!
//! The master's per-round work — decoding n uplink frames and replaying the
//! fold into `est`/`h`/`h_sum` — is the serial bottleneck once the wire is
//! O(K) bytes (see the "Parallel fold" section of [`crate::coordinator::runner`]).
//! [`FoldPool`] parallelizes it without touching the fp op sequence any
//! single coordinate observes:
//!
//! - `T − 1` worker threads (`shiftcomp-fold-{s}`) are spawned **once** at
//!   runner construction and parked on a rendezvous channel; arming a round
//!   costs one channel send per thread and zero allocations, preserving the
//!   steady-state zero-allocation round contract.
//! - [`FoldPool::run`] executes a borrowed closure on every shard: shard 0
//!   runs inline on the calling thread (so `T = 1` is *literally* the serial
//!   path — no hand-off, no barrier), shards `1..T` run on the pool threads,
//!   and `run` returns only after every shard has reported done. That
//!   completion barrier is what makes the lifetime-erased borrow sound.
//! - Shard panics are caught (`catch_unwind`) and re-raised on the calling
//!   thread after the barrier, so a poisoned fold can't leave the pool or
//!   the round state half-synchronized.
//!
//! [`ShardView`] is the companion aliasing escape hatch: a `Send + Sync`
//! raw-pointer view of a mutable slice from which each shard carves its own
//! *disjoint* sub-range. All `unsafe` of the parallel fold lives in this
//! module behind the two SAFETY contracts documented below.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;

/// Upper bound on fold threads: beyond this the per-round barrier cost
/// dwarfs any conceivable fold speedup on one NUMA node.
pub const MAX_FOLD_THREADS: usize = 256;

/// Auto-sizing cap: when `master_threads` is unset we take the machine's
/// [`std::thread::available_parallelism`] but never more than this — each
/// runner owns its own pool, and tests/benches construct several runners.
const AUTO_THREADS_CAP: usize = 16;

/// Environment override consulted when `cluster.master_threads` is unset:
/// lets CI force the parallel fold (`SHIFTCOMP_MASTER_THREADS=4`) through
/// every existing test without touching configs. Invalid or zero values
/// fall back to auto-sizing.
pub const MASTER_THREADS_ENV: &str = "SHIFTCOMP_MASTER_THREADS";

/// Resolve the fold-pool size: an explicit config value wins (validated to
/// `1..=`[`MAX_FOLD_THREADS`]), otherwise [`MASTER_THREADS_ENV`], otherwise
/// `available_parallelism` capped at 16.
pub fn resolve_threads(configured: Option<usize>) -> usize {
    if let Some(t) = configured {
        assert!(
            (1..=MAX_FOLD_THREADS).contains(&t),
            "master_threads must be in 1..={MAX_FOLD_THREADS} (got {t})"
        );
        return t;
    }
    if let Ok(s) = std::env::var(MASTER_THREADS_ENV) {
        if let Ok(t) = s.trim().parse::<usize>() {
            if (1..=MAX_FOLD_THREADS).contains(&t) {
                return t;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(AUTO_THREADS_CAP)
}

/// Contiguous coordinate range `[lo, hi)` owned by shard `s` of `t` over a
/// `d`-length vector: near-equal split, the first `d % t` shards one longer.
/// Shards cover `[0, d)` exactly and never overlap.
pub fn shard_range(d: usize, t: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < t);
    let base = d / t;
    let rem = d % t;
    let lo = s * base + s.min(rem);
    (lo, lo + base + usize::from(s < rem))
}

/// The `t + 1` ascending cut points of the shard partition: `cuts[s]..cuts[s+1]`
/// is shard `s`'s range, `cuts[0] == 0`, `cuts[t] == d`. Written into a
/// reused buffer so refilling per round is allocation-free.
pub fn shard_cuts_into(d: usize, t: usize, out: &mut Vec<usize>) {
    out.clear();
    out.push(0);
    for s in 0..t {
        out.push(shard_range(d, t, s).1);
    }
}

/// A lifetime-erased shard job: a raw pointer to the borrowed closure.
/// Sound because [`FoldPool::run`] blocks on the done barrier before
/// returning, so the pointee outlives every dereference.
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared access from many threads is fine)
// and `run`'s barrier guarantees it is alive for the duration of the job.
unsafe impl Send for Job {}

/// Persistent shard pool; see the module docs for the execution model.
pub struct FoldPool {
    threads: usize,
    job_txs: Vec<SyncSender<Job>>,
    done_rx: Receiver<bool>,
    handles: Vec<JoinHandle<()>>,
}

impl FoldPool {
    /// Spawn `threads − 1` shard workers (shard 0 stays on the caller).
    pub fn new(threads: usize) -> Self {
        assert!(
            (1..=MAX_FOLD_THREADS).contains(&threads),
            "fold pool needs 1..={MAX_FOLD_THREADS} threads (got {threads})"
        );
        let (done_tx, done_rx) = sync_channel::<bool>(threads);
        let mut job_txs = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for s in 1..threads {
            let (tx, rx) = sync_channel::<Job>(1);
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("shiftcomp-fold-{s}"))
                .spawn(move || {
                    // LINT-ALLOW(blocking-recv): shard-thread idle loop —
                    // pool threads park here between rounds with no
                    // deadline by design; Drop disconnects the channel and
                    // ends the loop.
                    while let Ok(job) = rx.recv() {
                        // SAFETY: `run` keeps the closure borrowed until the
                        // done barrier below releases it, so the pointer is
                        // live here.
                        let f = unsafe { &*job.0 };
                        let ok = catch_unwind(AssertUnwindSafe(|| f(s))).is_ok();
                        if done.send(ok).is_err() {
                            break; // pool dropped mid-job: exit quietly
                        }
                    }
                })
                // LINT-ALLOW(no-panic): construction time, before any round
                // runs — a spawn failure is an OS resource error, not a
                // round-path fault to degrade around.
                .expect("spawn fold shard thread");
            job_txs.push(tx);
            handles.push(handle);
        }
        Self {
            threads,
            job_txs,
            done_rx,
            handles,
        }
    }

    /// Number of shards (`T`); shard ids passed to the closure are `0..T`.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(s)` for every shard `s ∈ 0..T` and wait for all of them.
    /// Shard 0 runs inline on the calling thread. Panics (after the barrier)
    /// if any shard panicked.
    ///
    /// The closure only borrows — no allocation, no `Arc`, no `'static`
    /// bound — which is what keeps pooled rounds allocation-free.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 {
            f(0);
            return;
        }
        let job = f as *const (dyn Fn(usize) + Sync);
        for tx in &self.job_txs {
            // LINT-ALLOW(no-panic): a shard thread can only exit when the
            // pool is dropped (its panics are caught) — a dead channel here
            // means master-side memory corruption; aborting the fold loudly
            // beats folding a partial shard set silently.
            tx.send(Job(job)).expect("fold shard thread exited");
        }
        let ok0 = catch_unwind(AssertUnwindSafe(|| f(0))).is_ok();
        // Completion barrier: every shard must check in before `f`'s borrow
        // can end — this is the soundness linchpin of the lifetime erasure.
        let mut ok = ok0;
        for _ in &self.job_txs {
            // LINT-ALLOW(blocking-recv): the completion barrier `run`'s
            // lifetime erasure is sound by — every armed shard sends
            // exactly one done token (its panics are caught), so this wait
            // is bounded by the shard's own work, and a deadline that
            // released the borrow early would be UB, not resilience.
            // LINT-ALLOW(no-panic): see the send above — a vanished shard
            // thread is memory corruption, not a degradable fault.
            ok &= self.done_rx.recv().expect("fold shard thread exited");
        }
        assert!(ok, "a fold shard panicked (see thread output above)");
    }
}

impl Drop for FoldPool {
    fn drop(&mut self) {
        // Disconnect the job channels; workers fall out of their recv loop.
        self.job_txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// A `Send + Sync` raw view of a mutable slice, for carving *disjoint*
/// per-shard sub-ranges (or per-worker elements) inside a [`FoldPool::run`]
/// closure. The borrow checker cannot prove shard disjointness, so the
/// contract moves to the two `unsafe` accessors below; every call site in
/// `runner.rs` derives its range from the shard cut points or a
/// `wi % T == s` ownership rule, both of which partition the index space.
///
/// A view is only valid while the slice it was created from is otherwise
/// unborrowed — create it immediately before the `run` call and let it die
/// with the closure.
pub struct ShardView<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the view hands out disjoint &mut sub-slices across threads; that
// is exactly the Send-but-shared pattern, sound when T: Send and callers
// uphold the disjointness contract of `slice`/`at`.
unsafe impl<T: Send> Send for ShardView<T> {}
// SAFETY: as above — shared `&ShardView` access only ever materializes
// disjoint `&mut` sub-slices, so cross-thread sharing of the view is sound.
unsafe impl<T: Send> Sync for ShardView<T> {}

impl<T> Clone for ShardView<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for ShardView<T> {}

impl<T> ShardView<T> {
    pub fn new(slice: &mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The sub-slice `[lo, hi)`.
    ///
    /// # Safety
    /// `lo <= hi <= len`, and no concurrently live reference (from this or
    /// any copy of the view) may overlap `[lo, hi)`.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }

    /// The single element at `i`.
    ///
    /// # Safety
    /// `i < len`, and no concurrently live reference (from this or any copy
    /// of the view) may alias element `i`.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn at(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_exactly() {
        for d in [0usize, 1, 7, 64, 100_001] {
            for t in [1usize, 2, 3, 8, 13] {
                let mut expect_lo = 0;
                for s in 0..t {
                    let (lo, hi) = shard_range(d, t, s);
                    assert_eq!(lo, expect_lo, "d={d} t={t} s={s}");
                    assert!(hi >= lo);
                    expect_lo = hi;
                }
                assert_eq!(expect_lo, d, "shards must cover [0, d) for d={d} t={t}");
                let mut cuts = Vec::new();
                shard_cuts_into(d, t, &mut cuts);
                assert_eq!(cuts.len(), t + 1);
                assert_eq!(cuts[0], 0);
                assert_eq!(cuts[t], d);
            }
        }
    }

    #[test]
    fn pool_runs_every_shard_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for t in [1usize, 2, 5] {
            let pool = FoldPool::new(t);
            let hits: Vec<AtomicUsize> = (0..t).map(|_| AtomicUsize::new(0)).collect();
            for _ in 0..3 {
                pool.run(&|s| {
                    hits[s].fetch_add(1, Ordering::SeqCst);
                });
            }
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 3, "t={t} shard {s}");
            }
        }
    }

    #[test]
    fn pool_sharded_write_matches_serial() {
        let d = 1013;
        let pool = FoldPool::new(4);
        let mut cuts = Vec::new();
        shard_cuts_into(d, pool.threads(), &mut cuts);
        let mut v = vec![0.0f64; d];
        let view = ShardView::new(&mut v[..]);
        let cuts_ref = &cuts;
        pool.run(&|s| {
            let (lo, hi) = (cuts_ref[s], cuts_ref[s + 1]);
            // SAFETY: shard ranges are disjoint by construction.
            let sub = unsafe { view.slice(lo, hi) };
            for (j, out) in sub.iter_mut().enumerate() {
                *out = (lo + j) as f64 * 0.5;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as f64 * 0.5);
        }
    }

    #[test]
    fn pool_survives_a_shard_panic() {
        let pool = FoldPool::new(3);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|s| {
                if s == 2 {
                    panic!("injected shard fault");
                }
            });
        }));
        assert!(caught.is_err(), "shard panic must surface on the caller");
        // The pool stays usable for the next round.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn resolve_threads_explicit_wins() {
        assert_eq!(resolve_threads(Some(1)), 1);
        assert_eq!(resolve_threads(Some(8)), 8);
        let auto = resolve_threads(None);
        assert!((1..=MAX_FOLD_THREADS).contains(&auto));
    }
}
