//! The distributed runtime: a leader (master) and `n` worker threads
//! exchanging **wire-encoded** messages over channels.
//!
//! This is the deployment-shaped realization of Algorithm 1. Everything the
//! master learns comes off the wire (worker shifts are reconstructed from
//! the same packets a real parameter server would receive), bytes are
//! priced by the [`crate::net`] model, and per-worker RNG streams are
//! derived exactly as in the single-process driver — so a distributed run
//! is **bit-identical** to [`crate::algorithms::DcgdShift`] with the same
//! seed (property-tested in `rust/tests/coordinator.rs`).
//!
//! Protocol per round k:
//! ```text
//! master ──► workers : downlink frame (shared Arc): Delta | EfDelta | Resync
//! worker i ─► master : Frames { [c_i^k]?, m_i^k, [h-refresh]? }   (encoded)
//! master: decode, reconstruct h_i, g^k = (1/n)Σ(h_i + msgs), step, repeat
//! ```
//!
//! The downlink is delta-compressed (and optionally lossy with server-side
//! error feedback — see [`crate::downlink`]); workers read the iterate
//! through a fleet-shared copy-on-write snapshot plus a sparse overlay
//! (see [`replica`]) instead of each materializing a private dense x^k.
//! See [`crate::wire`] for the frame formats and [`runner`] for the
//! broadcast protocol details.

//! Rounds are fault-tolerant: the gather is deadline-bounded, a missing or
//! misbehaving worker is quarantined (the aggregate reweights to the
//! surviving subset, shift-consistently), stragglers can rejoin through
//! the dense resync bootstrap, and [`faults`] can inject every failure
//! path deterministically. See [`runner`]'s module doc for the semantics.

pub mod faults;
pub mod invariants;
pub mod participation;
pub mod pool;
pub mod protocol;
pub mod replica;
pub mod runner;

pub use faults::{FaultKind, FaultPlan, FaultSpec, WorkerFaultScript};
pub use participation::ParticipationSampler;
pub use pool::{FoldPool, ShardView};
pub use replica::{OverlayPatch, ReplicaOverlay, SnapshotPublisher};
pub use protocol::{
    FailureClass, FrameSet, MethodKind, RunnerHealth, WorkerCommand, WorkerFailure, WorkerSnapshot,
    WorkerState, WorkerUpdate,
};
pub use runner::{ClusterConfig, DistributedRunner, DEFAULT_ROUND_TIMEOUT_MS};
