//! The distributed runtime: a leader (master) and `n` worker threads
//! exchanging **wire-encoded** messages over channels.
//!
//! This is the deployment-shaped realization of Algorithm 1. Everything the
//! master learns comes off the wire (worker shifts are reconstructed from
//! the same packets a real parameter server would receive), bytes are
//! priced by the [`crate::net`] model, and per-worker RNG streams are
//! derived exactly as in the single-process driver — so a distributed run
//! is **bit-identical** to [`crate::algorithms::DcgdShift`] with the same
//! seed (property-tested in `rust/tests/coordinator.rs`).
//!
//! Protocol per round k:
//! ```text
//! master ──► workers : Broadcast(x^k)                      (dense, d·prec)
//! worker i ─► master : Frames { [c_i^k]?, m_i^k, [h-refresh]? }   (encoded)
//! master: decode, reconstruct h_i, g^k = (1/n)Σ(h_i + msgs), step, repeat
//! ```

pub mod protocol;
pub mod runner;

pub use protocol::{FrameSet, MethodKind, WorkerCommand, WorkerUpdate};
pub use runner::{ClusterConfig, DistributedRunner};
