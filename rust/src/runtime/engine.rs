//! The PJRT engine: manifest parsing, lazy compilation cache, literal
//! conversion helpers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::{any_err, AnyResult as Result};

use crate::util::json::Json;

/// One AOT entry as described by `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub name: String,
    pub file: String,
    /// `(shape, dtype)` per input, dtype as the manifest string ("float64")
    pub inputs: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<(Vec<usize>, String)>,
    /// entry-specific extras (param_count, config, …)
    pub extra: Json,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: HashMap<String, EntryInfo>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            any_err(format!(
                "reading {} — run `make artifacts` first: {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(|e| any_err(format!("manifest.json: {e}")))?;
        let mut entries = HashMap::new();
        let obj = j
            .get("entries")
            .as_obj()
            .ok_or_else(|| any_err("manifest.json: missing entries object"))?;
        for (name, e) in obj {
            let parse_specs = |key: &str| -> Result<Vec<(Vec<usize>, String)>> {
                e.get(key)
                    .as_arr()
                    .ok_or_else(|| any_err(format!("entry {name}: missing {key}")))?
                    .iter()
                    .map(|s| {
                        let shape = s
                            .get("shape")
                            .as_arr()
                            .ok_or_else(|| any_err(format!("entry {name}: bad shape")))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| any_err("bad dim")))
                            .collect::<Result<Vec<_>>>()?;
                        let dtype = s
                            .get("dtype")
                            .as_str()
                            .ok_or_else(|| any_err(format!("entry {name}: bad dtype")))?
                            .to_string();
                        Ok((shape, dtype))
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntryInfo {
                    name: name.clone(),
                    file: e
                        .get("file")
                        .as_str()
                        .ok_or_else(|| any_err(format!("entry {name}: missing file")))?
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    extra: e.clone(),
                },
            );
        }
        Ok(Self { entries, dir })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryInfo> {
        self.entries
            .get(name)
            .ok_or_else(|| any_err(format!("manifest has no entry '{name}'")))
    }
}

/// PJRT client + compiled-executable cache.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| any_err(format!("PJRT cpu client: {e:?}")))?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an entry's executable.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entry(name)?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| any_err(format!("parsing {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| any_err(format!("compiling {name}: {e:?}")))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry. The module was lowered with `return_tuple=True`,
    /// so the single output literal is a tuple; we decompose it.
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| any_err(format!("executing {name}: {e:?}")))?;
        let lit = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| any_err(format!("{name}: no output buffer")))?
            .to_literal_sync()
            .map_err(|e| any_err(format!("{name}: readback: {e:?}")))?;
        lit.to_tuple().map_err(|e| any_err(format!("{name}: tuple: {e:?}")))
    }
}

// -------------------------------------------------------- literal helpers

pub fn lit_f64(v: &[f64], dims: &[i64]) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(v);
    if dims.len() == 1 {
        return Ok(flat);
    }
    flat.reshape(dims).map_err(|e| any_err(format!("reshape: {e:?}")))
}

pub fn lit_f32(v: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(v);
    if dims.len() == 1 {
        return Ok(flat);
    }
    flat.reshape(dims).map_err(|e| any_err(format!("reshape: {e:?}")))
}

pub fn lit_i32(v: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(v);
    if dims.len() == 1 {
        return Ok(flat);
    }
    flat.reshape(dims).map_err(|e| any_err(format!("reshape: {e:?}")))
}

pub fn lit_scalar_f64(v: f64) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

pub fn to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    lit.to_vec::<f64>().map_err(|e| any_err(format!("to_vec f64: {e:?}")))
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| any_err(format!("to_vec f32: {e:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn manifest_parses_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let e = m.entry("ridge_grad").unwrap();
        assert_eq!(e.inputs.len(), 5);
        assert_eq!(e.inputs[0].0, vec![80]);
        assert_eq!(e.inputs[0].1, "float64");
        assert_eq!(e.outputs.len(), 1);
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn bad_manifest_is_rejected() {
        let dir = std::env::temp_dir().join("shiftcomp_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"entries\": 5}").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
