//! Typed wrappers over AOT entries: gradient oracles and the LM training
//! session used by `examples/train_lm.rs`.

use crate::util::{any_err, AnyResult as Result};

/// Local stand-in for `anyhow::ensure!` (offline build, no anyhow).
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::util::any_err(format!(
                "ensure failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err($crate::util::any_err(format!($($fmt)+)));
        }
    };
}

use crate::runtime::engine::{lit_f32, lit_f64, lit_i32, to_f32, to_f64, Engine};

/// The HLO-backed ridge gradient: the same math as
/// `problems::Ridge::local_grad_into`, but executed by PJRT from the
/// Layer-2 lowering (which itself calls the Layer-1 Pallas matmul). The
/// integration tests drive both and assert agreement — the whole-stack
/// correctness check.
pub struct HloRidgeOracle<'e> {
    engine: &'e Engine,
    pub m_i: usize,
    pub d: usize,
}

impl<'e> HloRidgeOracle<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let e = engine.manifest.entry("ridge_grad")?;
        let m_i = e.extra.get("m_i").as_usize().ok_or_else(|| any_err("m_i"))?;
        let d = e.extra.get("d").as_usize().ok_or_else(|| any_err("d"))?;
        Ok(Self { engine, m_i, d })
    }

    /// `∇f_i(x) = n·A_iᵀ(A_i x − y_i) + λx` via PJRT.
    pub fn grad(&self, x: &[f64], a: &[f64], y: &[f64], lam: f64, n: f64) -> Result<Vec<f64>> {
        ensure!(x.len() == self.d, "x dim");
        ensure!(a.len() == self.m_i * self.d, "A dims");
        ensure!(y.len() == self.m_i, "y dim");
        let args = vec![
            lit_f64(x, &[self.d as i64])?,
            lit_f64(a, &[self.m_i as i64, self.d as i64])?,
            lit_f64(y, &[self.m_i as i64])?,
            lit_f64(&[lam], &[1])?,
            lit_f64(&[n], &[1])?,
        ];
        let out = self.engine.run("ridge_grad", &args)?;
        to_f64(&out[0])
    }
}

/// A compiled LM training step: `(params, tokens) → (loss, flat grads)`.
pub struct LmSession<'e> {
    engine: &'e Engine,
    entry: &'static str,
    pub param_count: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl<'e> LmSession<'e> {
    /// Prefers the CPU-optimized `lm_step_fast` artifact (XLA-native gemm)
    /// when present; `lm_step` is the Pallas-kernel TPU artifact (see
    /// EXPERIMENTS.md section Perf for the measured difference).
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let entry: &'static str = if engine.manifest.entry("lm_step_fast").is_ok() {
            "lm_step_fast"
        } else {
            "lm_step"
        };
        Self::with_entry(engine, entry)
    }

    /// Force a specific LM artifact (used by the perf bench to compare the
    /// Pallas-interpret and XLA-gemm paths).
    pub fn with_entry(engine: &'e Engine, entry: &'static str) -> Result<Self> {
        let e = engine.manifest.entry(entry)?;
        let param_count = e
            .extra
            .get("param_count")
            .as_usize()
            .ok_or_else(|| any_err("param_count"))?;
        let batch = e.extra.get("batch").as_usize().ok_or_else(|| any_err("batch"))?;
        let cfg = e.extra.get("config");
        let seq = cfg.get("seq").as_usize().ok_or_else(|| any_err("seq"))?;
        let vocab = cfg.get("vocab").as_usize().ok_or_else(|| any_err("vocab"))?;
        Ok(Self {
            engine,
            entry,
            param_count,
            batch,
            seq,
            vocab,
        })
    }

    /// Load the Python-initialized parameter vector (`lm_init.bin`).
    pub fn initial_params(&self) -> Result<Vec<f32>> {
        let e = self.engine.manifest.entry(self.entry)?;
        let init = e
            .extra
            .get("init_file")
            .as_str()
            .ok_or_else(|| any_err("lm_step has no init_file"))?;
        let bytes = std::fs::read(self.engine.manifest.dir.join(init))?;
        ensure!(
            bytes.len() == self.param_count * 4,
            "lm_init.bin size {} != 4·{}",
            bytes.len(),
            self.param_count
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// One forward+backward: tokens is `[batch, seq+1]` row-major i32.
    pub fn step(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        ensure!(params.len() == self.param_count, "params len");
        ensure!(tokens.len() == self.batch * (self.seq + 1), "tokens len");
        for &t in tokens {
            ensure!((t as usize) < self.vocab, "token {t} out of vocab");
        }
        let args = vec![
            lit_f32(params, &[self.param_count as i64])?,
            lit_i32(tokens, &[self.batch as i64, (self.seq + 1) as i64])?,
        ];
        let out = self.engine.run(self.entry, &args)?;
        ensure!(out.len() == 2, "lm_step returns (loss, grads)");
        let loss = to_f32(&out[0])?;
        let grads = to_f32(&out[1])?;
        ensure!(grads.len() == self.param_count, "grads len");
        Ok((loss[0], grads))
    }
}

/// HLO-backed fused shifted-compress: `h + mask ⊙ (g − h) · scale`
/// (the Layer-1 kernel exercised end-to-end through PJRT).
pub struct HloShiftedCompress<'e> {
    engine: &'e Engine,
    pub d: usize,
}

impl<'e> HloShiftedCompress<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let e = engine.manifest.entry("shifted_compress")?;
        let d = e.extra.get("d").as_usize().ok_or_else(|| any_err("d"))?;
        Ok(Self { engine, d })
    }

    pub fn apply(&self, g: &[f64], h: &[f64], mask: &[f64], scale: f64) -> Result<Vec<f64>> {
        ensure!(g.len() == self.d && h.len() == self.d && mask.len() == self.d);
        let args = vec![
            lit_f64(g, &[self.d as i64])?,
            lit_f64(h, &[self.d as i64])?,
            lit_f64(mask, &[self.d as i64])?,
            lit_f64(&[scale], &[1])?,
        ];
        let out = self.engine.run("shifted_compress", &args)?;
        to_f64(&out[0])
    }
}

/// HLO-backed natural-dithering quantizer (s = 8 levels baked at AOT time).
pub struct HloNatDither<'e> {
    engine: &'e Engine,
    pub d: usize,
    pub s: usize,
}

impl<'e> HloNatDither<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let e = engine.manifest.entry("nat_dither_quantize")?;
        let d = e.extra.get("d").as_usize().ok_or_else(|| any_err("d"))?;
        let s = e.extra.get("s").as_usize().ok_or_else(|| any_err("s"))?;
        Ok(Self { engine, d, s })
    }

    /// `x` quantized to `norm·{0, 2^{1−s}, …, 1}` with external uniforms `u`.
    pub fn quantize(&self, x: &[f64], u: &[f64], norm: f64) -> Result<Vec<f64>> {
        ensure!(x.len() == self.d && u.len() == self.d);
        let args = vec![
            lit_f64(x, &[self.d as i64])?,
            lit_f64(u, &[self.d as i64])?,
            lit_f64(&[norm], &[1])?,
        ];
        let out = self.engine.run("nat_dither_quantize", &args)?;
        to_f64(&out[0])
    }
}
