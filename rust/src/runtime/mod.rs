//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them from the Rust hot path.
//!
//! Interchange is HLO **text** + `manifest.json`:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Python never runs after build time.

pub mod engine;
pub mod oracles;

pub use engine::{Engine, EntryInfo, Manifest};
pub use oracles::{HloRidgeOracle, LmSession};
