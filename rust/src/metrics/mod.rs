//! Run metrics: per-round traces, convergence detection, CSV/JSON export,
//! and an ASCII plotter used by the figure benches to render the paper's
//! plots directly in the terminal.

pub mod plot;
pub mod trace;

pub use plot::AsciiPlot;
pub use trace::{RoundRecord, Trace};
