//! Per-round measurements of one optimization run.

use crate::util::json::Json;

/// One recorded round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// relative squared argument error ‖x^k − x*‖² / ‖x⁰ − x*‖²
    pub rel_err: f64,
    /// cumulative worker→master payload bits (all workers)
    pub bits_up: u64,
    /// cumulative master→worker broadcast bits
    pub bits_down: u64,
    /// cumulative shift-state synchronization bits (e.g. Rand-DIANA's rare
    /// dense shift refreshes) — reported separately so both accounting
    /// conventions (messages-only vs total) can be plotted
    pub bits_refresh: u64,
    /// simulated wall-clock seconds (0 when no network model attached)
    pub sim_time: f64,
    /// objective value f(x^k), if the driver computes it (else NaN)
    pub loss: f64,
}

/// The full trajectory of a run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algorithm: String,
    pub compressor: String,
    pub records: Vec<RoundRecord>,
    /// true if the run was stopped because rel_err ≤ tol
    pub converged: bool,
    /// true if the iterate diverged (NaN / rel_err above the blow-up guard)
    pub diverged: bool,
}

impl Trace {
    pub fn new(algorithm: &str, compressor: &str) -> Self {
        Self {
            algorithm: algorithm.to_string(),
            compressor: compressor.to_string(),
            records: Vec::new(),
            converged: false,
            diverged: false,
        }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn final_relative_error(&self) -> f64 {
        self.records.last().map(|r| r.rel_err).unwrap_or(f64::NAN)
    }

    pub fn rounds(&self) -> usize {
        self.records.last().map(|r| r.round + 1).unwrap_or(0)
    }

    /// Total uplink: gradient messages + shift-state sync.
    pub fn total_bits_up(&self) -> u64 {
        self.records
            .last()
            .map(|r| r.bits_up + r.bits_refresh)
            .unwrap_or(0)
    }

    /// First round index at which rel_err ≤ tol, if reached.
    pub fn rounds_to_tol(&self, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.rel_err <= tol)
            .map(|r| r.round)
    }

    /// Cumulative uplink bits (messages + refreshes) at the first round
    /// where rel_err ≤ tol — the honest total-traffic accounting.
    pub fn bits_to_tol(&self, tol: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.rel_err <= tol)
            .map(|r| r.bits_up + r.bits_refresh)
    }

    /// Gradient-message bits only (shift refreshes excluded) — the
    /// convention under which the paper's Figure 1 compares methods.
    pub fn bits_to_tol_messages_only(&self, tol: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.rel_err <= tol)
            .map(|r| r.bits_up)
    }

    /// The error floor: minimum rel_err along the trajectory (neighborhood
    /// convergence shows up as a plateau here).
    pub fn error_floor(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.rel_err)
            .fold(f64::INFINITY, f64::min)
    }

    /// (total bits, log10 rel_err) series for plotting.
    pub fn bits_log_err(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .map(|r| {
                (
                    (r.bits_up + r.bits_refresh) as f64,
                    r.rel_err.max(1e-300).log10(),
                )
            })
            .collect()
    }

    // --------------------------------------------------------------- export

    pub fn to_csv(&self) -> String {
        let mut s = String::from("round,rel_err,bits_up,bits_refresh,bits_down,sim_time,loss\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{:e},{},{},{},{:e},{:e}\n",
                r.round, r.rel_err, r.bits_up, r.bits_refresh, r.bits_down, r.sim_time, r.loss
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::str(&self.algorithm)),
            ("compressor", Json::str(&self.compressor)),
            ("converged", Json::Bool(self.converged)),
            ("diverged", Json::Bool(self.diverged)),
            (
                "rounds",
                Json::arr(self.records.iter().map(|r| Json::num(r.round as f64))),
            ),
            (
                "rel_err",
                Json::arr(self.records.iter().map(|r| Json::num(r.rel_err))),
            ),
            (
                "bits_up",
                Json::arr(self.records.iter().map(|r| Json::num(r.bits_up as f64))),
            ),
        ])
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new("diana", "rand-k");
        for k in 0..5 {
            t.push(RoundRecord {
                round: k,
                rel_err: 10f64.powi(-(k as i32)),
                bits_up: (k as u64 + 1) * 100,
                bits_refresh: 0,
                bits_down: (k as u64 + 1) * 50,
                sim_time: k as f64 * 0.1,
                loss: f64::NAN,
            });
        }
        t
    }

    #[test]
    fn tol_queries() {
        let t = sample();
        assert_eq!(t.rounds_to_tol(1e-2), Some(2));
        assert_eq!(t.bits_to_tol(1e-2), Some(300));
        assert_eq!(t.rounds_to_tol(1e-9), None);
        assert_eq!(t.final_relative_error(), 1e-4);
        assert_eq!(t.error_floor(), 1e-4);
        assert_eq!(t.total_bits_up(), 500);
        assert_eq!(t.rounds(), 5);
    }

    #[test]
    fn csv_has_all_rows() {
        let t = sample();
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let t = sample();
        let j = t.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("algorithm").as_str().unwrap(), "diana");
        assert_eq!(parsed.get("rel_err").as_arr().unwrap().len(), 5);
    }

    #[test]
    fn empty_trace_is_sane() {
        let t = Trace::new("x", "y");
        assert!(t.final_relative_error().is_nan());
        assert_eq!(t.rounds(), 0);
        assert_eq!(t.rounds_to_tol(0.1), None);
    }
}
