//! Terminal ASCII line plots.
//!
//! The benches regenerate the paper's figures as CSVs *and* render them as
//! ASCII plots so a reviewer can eyeball the curves without leaving the
//! terminal. Multiple series share one canvas; each series gets a distinct
//! glyph; axes are labeled with min/max.

pub struct AsciiPlot {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub width: usize,
    pub height: usize,
    series: Vec<(String, char, Vec<(f64, f64)>)>,
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&', '~', '^'];

impl AsciiPlot {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Self {
        Self {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            width: 78,
            height: 22,
            series: Vec::new(),
        }
    }

    pub fn add_series(&mut self, name: &str, points: Vec<(f64, f64)>) {
        let glyph = GLYPHS[self.series.len() % GLYPHS.len()];
        self.series.push((name.to_string(), glyph, points));
    }

    pub fn render(&self) -> String {
        let pts: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|(_, _, p)| p.iter().copied())
            .filter(|(x, y)| x.is_finite() && y.is_finite())
            .collect();
        if pts.is_empty() {
            return format!("{}\n(no finite data)\n", self.title);
        }
        let xmin = pts.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let xmax = pts.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
        let ymin = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
        let ymax = pts.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
        let xspan = (xmax - xmin).max(1e-300);
        let yspan = (ymax - ymin).max(1e-300);

        let mut grid = vec![vec![' '; self.width]; self.height];
        for (_, glyph, points) in &self.series {
            for &(x, y) in points {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let col = (((x - xmin) / xspan) * (self.width - 1) as f64).round() as usize;
                let row = (((y - ymin) / yspan) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - row; // origin at bottom
                grid[row.min(self.height - 1)][col.min(self.width - 1)] = *glyph;
            }
        }

        let mut out = String::new();
        out.push_str(&format!("━━ {} ━━\n", self.title));
        out.push_str(&format!("{} (y: {:.3e} … {:.3e})\n", self.ylabel, ymin, ymax));
        for row in &grid {
            out.push('│');
            out.extend(row.iter());
            out.push('\n');
        }
        out.push('└');
        out.extend(std::iter::repeat('─').take(self.width));
        out.push('\n');
        out.push_str(&format!(
            "  {} (x: {:.3e} … {:.3e})\n",
            self.xlabel, xmin, xmax
        ));
        for (name, glyph, _) in &self.series {
            out.push_str(&format!("  {glyph} = {name}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_series_with_legend() {
        let mut p = AsciiPlot::new("test", "bits", "log err");
        p.add_series("a", vec![(0.0, 0.0), (1.0, -1.0), (2.0, -2.0)]);
        p.add_series("b", vec![(0.0, 0.0), (1.0, -0.5), (2.0, -1.0)]);
        let s = p.render();
        assert!(s.contains("test"));
        assert!(s.contains("* = a"));
        assert!(s.contains("o = b"));
        assert!(s.contains('*'));
        assert!(s.lines().count() > 20);
    }

    #[test]
    fn empty_plot_doesnt_panic() {
        let p = AsciiPlot::new("empty", "x", "y");
        assert!(p.render().contains("no finite data"));
    }

    #[test]
    fn nonfinite_points_skipped() {
        let mut p = AsciiPlot::new("nan", "x", "y");
        p.add_series("a", vec![(0.0, f64::NAN), (1.0, 1.0), (2.0, f64::INFINITY)]);
        let s = p.render();
        assert!(s.contains("nan"));
    }

    #[test]
    fn extremes_land_on_canvas_edges() {
        let mut p = AsciiPlot::new("edge", "x", "y");
        p.add_series("a", vec![(0.0, 0.0), (10.0, 10.0)]);
        let s = p.render();
        // both corners populated
        let lines: Vec<&str> = s.lines().collect();
        let first_grid = lines[2];
        let last_grid = lines[2 + p.height - 1];
        assert!(first_grid.ends_with('*') || first_grid.contains('*'));
        assert!(last_grid.contains('*'));
    }
}
