//! # shiftcomp — Shifted Compression Framework
//!
//! A production-grade implementation of *"Shifted Compression Framework:
//! Generalizations and Improvements"* (Shulgin & Richtárik, UAI 2022) for
//! communication-efficient distributed optimization.
//!
//! The paper generalizes unbiased compression operators `Q ∈ U(ω)` to
//! **shifted compressors** `Q_h(x) = h + Q(x − h) ∈ U(ω; h)` and derives a
//! meta-algorithm, **DCGD-SHIFT**, in which each worker compresses the
//! difference between its local gradient and a *shift* `h_i^k`. Different
//! shift-update rules recover (and improve) DCGD, DIANA, GDCI, VR-GDCI, and
//! produce the new DCGD-STAR and Rand-DIANA methods.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the distributed coordinator: a
//!   round-synchronous master + n workers runtime over channels carrying
//!   wire-encoded compressed messages, with exact bit accounting and a
//!   simulated network ([`coordinator`], [`net`], [`wire`]).
//! * **Layer 2 (JAX, build time)** — gradient computations and a
//!   transformer LM lowered once to HLO text (`python/compile/model.py`),
//!   loaded and executed from Rust via PJRT ([`runtime`]).
//! * **Layer 1 (Pallas, build time)** — tiled matmul and fused
//!   shift-compress kernels called from the L2 graphs
//!   (`python/compile/kernels/`).
//!
//! ## Quick start
//!
//! ```no_run
//! use shiftcomp::prelude::*;
//!
//! // Build the paper's ridge problem: make_regression(m=100, d=80), 10 workers.
//! let problem = Ridge::paper_default(42);
//! // Rand-DIANA with Rand-K compression at q = 0.5.
//! let d = problem.dim();
//! let mut alg = DcgdShift::rand_diana(&problem, RandK::with_q(d, 0.5), None, 42);
//! let trace = alg.run(&problem, &RunOpts::default());
//! println!("final error: {:.3e}", trace.final_relative_error());
//! ```

// Deliberate idioms used pervasively (CI runs `clippy -- -D warnings`):
// explicit `(bits + 7) / 8` mirrors the wire-format spec text, and indexed
// loops over parallel slices match the linalg kernels' style.
#![allow(
    clippy::manual_div_ceil,
    clippy::needless_range_loop,
    clippy::too_many_arguments
)]

pub mod algorithms;
pub mod compressors;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod downlink;
pub mod ef;
pub mod harness;
pub mod linalg;
pub mod lint;
#[cfg(feature = "pjrt")]
pub mod lm;
pub mod metrics;
pub mod net;
pub mod problems;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod theory;
pub mod util;
pub mod wire;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{
        Algorithm, Dcgd, DcgdShift, Gd, Gdci, RunOpts, ShiftRule, VrGdci,
    };
    pub use crate::compressors::{
        BernoulliP, Compressor, Identity, Induced, NaturalCompression, NaturalDithering, RandK,
        Scaled, SignScaled, Ternary, TopK, ZeroCompressor,
    };
    pub use crate::coordinator::{ClusterConfig, DistributedRunner};
    pub use crate::downlink::EfDownlink;
    pub use crate::ef::EfUplink;
    pub use crate::data::{
        make_regression, partition_evenly, synthetic_w2a, RegressionOpts, W2aOpts,
    };
    pub use crate::metrics::Trace;
    pub use crate::problems::{Logistic, Problem, Quadratic, Ridge};
    pub use crate::theory::{self, StepSizes};
    pub use crate::util::rng::Pcg64;
}
