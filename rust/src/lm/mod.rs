//! Distributed compressed training of the transformer LM — the end-to-end
//! workload of `examples/train_lm.rs`.
//!
//! The model lives in the AOT artifact (`lm_step`): Rust owns the
//! parameters, shards synthetic-corpus batches across n workers, executes
//! each worker's forward+backward via PJRT, compresses the gradients with
//! the paper's DIANA shift machinery (f32 → f64 lift on the compression
//! boundary), aggregates, and applies SGD-with-momentum on the leader.

pub mod corpus;
pub mod trainer;

pub use corpus::MarkovCorpus;
pub use trainer::{LmTrainOpts, LmTrainer, RoundLog};
