//! Synthetic token corpus with learnable structure.
//!
//! A first-order Markov chain over the vocabulary with a sparse, peaked
//! transition matrix: every token has a handful of likely successors. A
//! language model trained on this must drive its loss well below the
//! unigram entropy (≈ ln V for a flat start), giving the e2e example a
//! meaningful loss curve rather than noise-fitting.

use crate::util::rng::Pcg64;

pub struct MarkovCorpus {
    vocab: usize,
    /// per-token successor lists (token → candidates)
    successors: Vec<Vec<u32>>,
    /// probability of following the chain vs emitting uniform noise
    fidelity: f64,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, branching: usize, fidelity: f64, seed: u64) -> Self {
        assert!(vocab >= 2 && branching >= 1);
        assert!((0.0..=1.0).contains(&fidelity));
        let mut rng = Pcg64::with_stream(seed, 0xc0b5);
        let successors = (0..vocab)
            .map(|_| {
                (0..branching)
                    .map(|_| rng.below(vocab as u64) as u32)
                    .collect()
            })
            .collect();
        Self {
            vocab,
            successors,
            fidelity,
        }
    }

    /// The entropy floor of the chain (mean over states of the successor
    /// entropy, mixed with the noise share) — a loose lower bound on
    /// reachable LM loss, used by the example's reporting.
    pub fn entropy_estimate(&self) -> f64 {
        // successors are sampled with repetition; treat as uniform over the
        // distinct candidates
        let mean_distinct: f64 = self
            .successors
            .iter()
            .map(|s| {
                let mut d = s.clone();
                d.sort_unstable();
                d.dedup();
                (d.len() as f64).ln()
            })
            .sum::<f64>()
            / self.vocab as f64;
        self.fidelity * mean_distinct + (1.0 - self.fidelity) * (self.vocab as f64).ln()
    }

    /// Sample a `[batch, seq_plus_one]` token block (row-major i32).
    pub fn sample_batch(
        &self,
        batch: usize,
        seq_plus_one: usize,
        rng: &mut Pcg64,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_one);
        for _ in 0..batch {
            let mut tok = rng.below(self.vocab as u64) as u32;
            out.push(tok as i32);
            for _ in 1..seq_plus_one {
                tok = if rng.bernoulli(self.fidelity) {
                    let succ = &self.successors[tok as usize];
                    succ[rng.below(succ.len() as u64) as usize]
                } else {
                    rng.below(self.vocab as u64) as u32
                };
                out.push(tok as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let c = MarkovCorpus::new(128, 3, 0.9, 1);
        let mut rng = Pcg64::new(2);
        let b = c.sample_batch(4, 33, &mut rng);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn chain_structure_is_learnable() {
        // successor frequencies should be concentrated: following tokens
        // come from a small candidate set most of the time
        let c = MarkovCorpus::new(64, 2, 0.95, 3);
        let mut rng = Pcg64::new(4);
        let b = c.sample_batch(16, 200, &mut rng);
        let mut hits = 0usize;
        let mut total = 0usize;
        for row in b.chunks(200) {
            for w in row.windows(2) {
                total += 1;
                if c.successors[w[0] as usize].contains(&(w[1] as u32)) {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.85, "chain fidelity {rate}");
    }

    #[test]
    fn entropy_below_uniform() {
        let c = MarkovCorpus::new(512, 4, 0.9, 5);
        assert!(c.entropy_estimate() < (512f64).ln() * 0.6);
    }

    #[test]
    fn deterministic_by_seed() {
        let c = MarkovCorpus::new(64, 3, 0.9, 7);
        let mut r1 = Pcg64::new(8);
        let mut r2 = Pcg64::new(8);
        assert_eq!(c.sample_batch(2, 10, &mut r1), c.sample_batch(2, 10, &mut r2));
    }
}
