//! The distributed compressed LM trainer.
//!
//! Round structure (DIANA on gradients, Algorithm 1 applied to deep
//! learning):
//! ```text
//! leader: broadcast params            (counted: n·P·32 bits down)
//! worker i: (loss_i, g_i) = lm_step(params, batch_i)      [PJRT]
//!           m_i = Q_i(g_i − h_i);  h_i += α·m_i;  send m_i [compressed]
//! leader:  ĝ = (1/n)Σ(h_i + m_i);  momentum SGD step
//! ```
//! Workers are simulated in-process (the PJRT CPU client is already
//! multi-threaded; separate processes would fight over cores), but every
//! message is compressed/decoded exactly as the coordinator does, and
//! uplink bits are accounted per worker.

use crate::util::AnyResult as Result;

use crate::compressors::{Compressor, ValPrec};
use crate::lm::corpus::MarkovCorpus;
use crate::runtime::{Engine, LmSession};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct LmTrainOpts {
    pub n_workers: usize,
    pub rounds: usize,
    pub lr: f64,
    pub momentum: f64,
    /// global-norm clip applied to the aggregated gradient estimator
    /// (compressed estimators are high-variance early on, before the DIANA
    /// shifts have learned the gradient geometry; clipping is the standard
    /// deep-learning remedy)
    pub clip: f64,
    /// DIANA shift-learning rate; default 1/(ω+1)
    pub alpha: Option<f64>,
    pub seed: u64,
    /// log every k rounds
    pub log_every: usize,
}

impl Default for LmTrainOpts {
    fn default() -> Self {
        Self {
            n_workers: 4,
            rounds: 300,
            lr: 0.1,
            momentum: 0.9,
            clip: 1.0,
            alpha: None,
            seed: 0,
            log_every: 10,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct RoundLog {
    pub round: usize,
    pub mean_loss: f64,
    pub bits_up: u64,
    /// bits an uncompressed (f32 dense) round would have cost
    pub bits_dense: u64,
}

pub struct LmTrainer<'e> {
    session: LmSession<'e>,
    corpus: MarkovCorpus,
    params: Vec<f32>,
    velocity: Vec<f64>,
    /// per-worker DIANA shifts (f64 lift of f32 gradients)
    shifts: Vec<Vec<f64>>,
    compressors: Vec<Box<dyn Compressor>>,
    alpha: f64,
    opts: LmTrainOpts,
    rngs: Vec<Pcg64>,
    data_rng: Pcg64,
    pub history: Vec<RoundLog>,
}

impl<'e> LmTrainer<'e> {
    pub fn new(
        engine: &'e Engine,
        corpus: MarkovCorpus,
        make_compressor: impl Fn(usize) -> Box<dyn Compressor>,
        opts: LmTrainOpts,
    ) -> Result<Self> {
        let session = LmSession::new(engine)?;
        let params = session.initial_params()?;
        let p = session.param_count;
        let compressors: Vec<Box<dyn Compressor>> =
            (0..opts.n_workers).map(|_| make_compressor(p)).collect();
        let omega = compressors[0]
            .omega()
            .expect("LM training uses unbiased compressors");
        let alpha = opts.alpha.unwrap_or(1.0 / (omega + 1.0));
        let mut root = Pcg64::with_stream(opts.seed, 0x13a);
        let rngs = (0..opts.n_workers).map(|i| root.stream(i as u64 + 1)).collect();
        let data_rng = root.stream(0xdada);
        Ok(Self {
            velocity: vec![0.0; p],
            shifts: vec![vec![0.0; p]; opts.n_workers],
            session,
            corpus,
            params,
            compressors,
            alpha,
            opts,
            rngs,
            data_rng,
            history: Vec::new(),
        })
    }

    pub fn param_count(&self) -> usize {
        self.session.param_count
    }

    /// One synchronous round over all workers.
    pub fn round(&mut self, k: usize) -> Result<RoundLog> {
        let n = self.opts.n_workers;
        let p = self.session.param_count;
        let mut est = vec![0.0f64; p];
        let mut loss_sum = 0.0;
        let mut bits_up = 0u64;
        let inv_n = 1.0 / n as f64;

        for w in 0..n {
            // each worker draws its own batch shard
            let tokens = self.corpus.sample_batch(
                self.session.batch,
                self.session.seq + 1,
                &mut self.data_rng,
            );
            let (loss, grads) = self.session.step(&self.params, &tokens)?;
            loss_sum += loss as f64;

            // f32 grads → f64 compression domain
            let g: Vec<f64> = grads.iter().map(|&v| v as f64).collect();
            let h = &mut self.shifts[w];
            let diff: Vec<f64> = g.iter().zip(h.iter()).map(|(a, b)| a - b).collect();
            let pkt = self.compressors[w].compress(&mut self.rngs[w], &diff);
            // gradients ship at f32 (deep-learning convention)
            bits_up += pkt.payload_bits(ValPrec::F32);
            let m = pkt.decode();
            for j in 0..p {
                est[j] += inv_n * (h[j] + m[j]);
                h[j] += self.alpha * m[j];
            }
        }

        // leader: clip, then momentum SGD on the variance-reduced estimator
        let est_norm = crate::linalg::nrm2(&est);
        if est_norm > self.opts.clip {
            crate::linalg::scale(self.opts.clip / est_norm, &mut est);
        }
        for j in 0..p {
            self.velocity[j] = self.opts.momentum * self.velocity[j] + est[j];
            self.params[j] -= (self.opts.lr * self.velocity[j]) as f32;
        }

        let log = RoundLog {
            round: k,
            mean_loss: loss_sum / n as f64,
            bits_up,
            bits_dense: (n * p) as u64 * 32,
        };
        Ok(log)
    }

    /// Run the configured number of rounds; returns the history.
    pub fn train(&mut self) -> Result<&[RoundLog]> {
        for k in 0..self.opts.rounds {
            let log = self.round(k)?;
            if k % self.opts.log_every == 0 || k + 1 == self.opts.rounds {
                println!(
                    "round {:>4}  loss {:.4}  uplink {:>10} bits (dense {:>12})  \
                     compression {:>5.1}×",
                    log.round,
                    log.mean_loss,
                    log.bits_up,
                    log.bits_dense,
                    log.bits_dense as f64 / log.bits_up.max(1) as f64,
                );
            }
            self.history.push(log);
        }
        Ok(&self.history)
    }

    pub fn entropy_floor(&self) -> f64 {
        self.corpus.entropy_estimate()
    }
}
