//! Experiment configuration: JSON schema → validated spec → algorithm
//! factory.
//!
//! One JSON document fully describes a run:
//!
//! ```json
//! {
//!   "problem":    {"kind": "ridge", "m": 100, "d": 80, "workers": 10,
//!                  "lambda": 0.01, "seed": 42},
//!   "algorithm":  {"kind": "rand-diana", "p": 0.1},
//!   "compressor": {"kind": "rand-k", "q": 0.1},
//!   "run":        {"max_rounds": 20000, "tol": 1e-12, "record_every": 10}
//! }
//! ```
//!
//! `shiftcomp run --config file.json` drives exactly this path; the harness
//! builds the same specs programmatically.
//!
//! An optional `"cluster"` object configures the threaded coordinator
//! ([`ExperimentConfig::build_distributed`]): wire precision for the
//! compressed frames, the dense-resync cadence of the delta-compressed
//! broadcast downlink, the optional error-fed-back downlink compressor
//! (`top-k` with `q` = K/d or `k` = K, `identity` for the
//! exact-equivalent EF path; omit the object — or set `"exact": true` —
//! for today's exact delta frames), the error-fed-back **uplink** toggle
//! (`uplink: {"error_feedback": true}` — workers ship `C(e + m)` from an
//! accumulator, which is what makes a *biased* main compressor like
//! `top-k` a valid choice; see the pairing matrix on
//! [`ExperimentConfig::parse`]), the local-step batching factor
//! (`local_steps` ≥ 1 sub-steps per communication round, batched into one
//! uplink frame; requires the `dcgd` or plain `diana` algorithm when > 1)
//! the pipelined wall-clock pricing toggle (`pipeline`, affects the
//! simulated time only), and the fault-tolerance knobs: a deterministic
//! fault-injection schedule (`faults`, an array of
//! `{"worker", "kind", "round"[, "rounds"]}` objects with kind ∈ crash |
//! garbage_uplink | corrupt_downlink | straggle), the per-round gather
//! deadline (`round_timeout_ms` > 0) and the consecutive-miss quarantine
//! threshold (`quarantine_after` ≥ 1) — see [`crate::coordinator::faults`]
//! and the runner module doc:
//!
//! ```json
//! { "cluster": {"prec": "f32", "resync_every": 1000, "local_steps": 8,
//!               "pipeline": true,
//!               "uplink": {"error_feedback": true},
//!               "downlink": {"compressor": "top-k", "q": 0.005},
//!               "round_timeout_ms": 500, "quarantine_after": 2,
//!               "faults": [{"worker": 3, "kind": "crash", "round": 40}]} }
//! ```

use std::sync::Arc;

use crate::algorithms::{Algorithm, DcgdShift, Gd, Gdci, RunOpts, VrGdci};
use crate::compressors::{
    BernoulliP, Compressor, Identity, NaturalCompression, NaturalDithering, RandK,
    StandardDithering, Ternary, TopK, ValPrec,
};
use crate::coordinator::{
    ClusterConfig, DistributedRunner, FaultPlan, MethodKind, DEFAULT_ROUND_TIMEOUT_MS,
};
use crate::theory;
use crate::data::{RegressionOpts, W2aOpts};
use crate::problems::{Logistic, Problem, Quadratic, Ridge};
use crate::util::json::Json;

#[derive(Debug)]
pub enum ConfigError {
    Invalid(String),
    Json(crate::util::json::JsonError),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(msg) => write!(f, "config: {msg}"),
            ConfigError::Json(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for ConfigError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ConfigError::Json(e)
    }
}

fn bad(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

// ------------------------------------------------------------------ problem

#[derive(Clone, Debug, PartialEq)]
pub enum ProblemSpec {
    Ridge {
        m: usize,
        d: usize,
        workers: usize,
        lambda: f64,
        seed: u64,
    },
    LogisticW2a {
        workers: usize,
        kappa: f64,
        seed: u64,
        /// optional path to a real LibSVM file (else the synthetic stand-in)
        data: Option<String>,
    },
    Quadratic {
        d: usize,
        workers: usize,
        mu: f64,
        l: f64,
        seed: u64,
        interpolating: bool,
    },
}

impl ProblemSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| bad("problem.kind missing"))?;
        let seed = j.get("seed").as_f64().unwrap_or(42.0) as u64;
        match kind {
            "ridge" => Ok(ProblemSpec::Ridge {
                m: j.get("m").as_usize().unwrap_or(100),
                d: j.get("d").as_usize().unwrap_or(80),
                workers: j.get("workers").as_usize().unwrap_or(10),
                lambda: j
                    .get("lambda")
                    .as_f64()
                    .unwrap_or(1.0 / j.get("m").as_f64().unwrap_or(100.0)),
                seed,
            }),
            "logistic-w2a" | "logistic" => Ok(ProblemSpec::LogisticW2a {
                workers: j.get("workers").as_usize().unwrap_or(10),
                kappa: j.get("kappa").as_f64().unwrap_or(100.0),
                seed,
                data: j.get("data").as_str().map(|s| s.to_string()),
            }),
            "quadratic" => Ok(ProblemSpec::Quadratic {
                d: j.get("d").as_usize().unwrap_or(40),
                workers: j.get("workers").as_usize().unwrap_or(10),
                mu: j.get("mu").as_f64().unwrap_or(1.0),
                l: j.get("l").as_f64().unwrap_or(100.0),
                seed,
                interpolating: j.get("interpolating").as_bool().unwrap_or(false),
            }),
            other => Err(bad(format!("unknown problem kind '{other}'"))),
        }
    }

    /// The fleet size this problem spec declares (known at parse time, so
    /// cross-field validation can range-check cluster knobs like `quorum`
    /// before anything is built).
    pub fn workers(&self) -> usize {
        match self {
            ProblemSpec::Ridge { workers, .. }
            | ProblemSpec::LogisticW2a { workers, .. }
            | ProblemSpec::Quadratic { workers, .. } => *workers,
        }
    }

    pub fn build(&self) -> Result<Box<dyn Problem>, ConfigError> {
        match self {
            ProblemSpec::Ridge {
                m,
                d,
                workers,
                lambda,
                seed,
            } => Ok(Box::new(Ridge::new(
                &RegressionOpts {
                    n_samples: *m,
                    n_features: *d,
                    seed: *seed,
                    ..Default::default()
                },
                *workers,
                *lambda,
                *seed,
            ))),
            ProblemSpec::LogisticW2a {
                workers,
                kappa,
                seed,
                data,
            } => {
                let ds = match data {
                    Some(path) => crate::data::libsvm::read_file(path)
                        .map_err(|e| bad(format!("loading {path}: {e}")))?,
                    None => crate::data::synthetic_w2a(&W2aOpts {
                        seed: *seed,
                        ..Default::default()
                    }),
                };
                Ok(Box::new(Logistic::from_dataset(&ds, *workers, *kappa, *seed)))
            }
            ProblemSpec::Quadratic {
                d,
                workers,
                mu,
                l,
                seed,
                interpolating,
            } => Ok(Box::new(if *interpolating {
                Quadratic::interpolating(*d, *workers, *mu, *l, *seed)
            } else {
                Quadratic::random(*d, *workers, *mu, *l, *seed)
            })),
        }
    }
}

// --------------------------------------------------------------- compressor

#[derive(Clone, Debug, PartialEq)]
pub enum CompressorSpec {
    Identity,
    RandK { q: f64 },
    TopK { q: f64 },
    NaturalDithering { s: u8, p: f64 },
    StandardDithering { s: u32 },
    NaturalCompression,
    Bernoulli { p: f64 },
    Ternary,
}

impl CompressorSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| bad("compressor.kind missing"))?;
        match kind {
            "identity" => Ok(CompressorSpec::Identity),
            "rand-k" => Ok(CompressorSpec::RandK {
                q: j.get("q")
                    .as_f64()
                    .ok_or_else(|| bad("rand-k needs q = K/d"))?,
            }),
            "top-k" => Ok(CompressorSpec::TopK {
                q: j.get("q").as_f64().ok_or_else(|| bad("top-k needs q"))?,
            }),
            "natural-dithering" | "nd" => Ok(CompressorSpec::NaturalDithering {
                s: j.get("s").as_f64().ok_or_else(|| bad("nd needs s"))? as u8,
                p: j.get("p").as_f64().unwrap_or(2.0),
            }),
            "standard-dithering" => Ok(CompressorSpec::StandardDithering {
                s: j.get("s").as_f64().ok_or_else(|| bad("sd needs s"))? as u32,
            }),
            "natural-compression" | "nat-comp" => Ok(CompressorSpec::NaturalCompression),
            "bernoulli" => Ok(CompressorSpec::Bernoulli {
                p: j.get("p").as_f64().ok_or_else(|| bad("bernoulli needs p"))?,
            }),
            "ternary" => Ok(CompressorSpec::Ternary),
            other => Err(bad(format!("unknown compressor kind '{other}'"))),
        }
    }

    pub fn build(&self, d: usize) -> Box<dyn Compressor> {
        match self {
            CompressorSpec::Identity => Box::new(Identity::new(d)),
            CompressorSpec::RandK { q } => Box::new(RandK::with_q(d, *q)),
            CompressorSpec::TopK { q } => Box::new(TopK::with_q(d, *q)),
            CompressorSpec::NaturalDithering { s, p } => {
                Box::new(NaturalDithering::new(d, *s, *p))
            }
            CompressorSpec::StandardDithering { s } => Box::new(StandardDithering::new(d, *s)),
            CompressorSpec::NaturalCompression => Box::new(NaturalCompression::new(d)),
            CompressorSpec::Bernoulli { p } => Box::new(BernoulliP::new(d, *p)),
            CompressorSpec::Ternary => Box::new(Ternary::new(d)),
        }
    }

    /// ω of the built compressor, if unbiased.
    pub fn omega(&self, d: usize) -> Option<f64> {
        self.build(d).omega()
    }
}

// ------------------------------------------------------------------ cluster

/// The `"cluster.downlink"` object: which (contractive, deterministic)
/// compressor the master's error-fed-back broadcast uses, if any. The
/// dropped residual accumulates server-side and is retried next round —
/// see [`crate::downlink::EfDownlink`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum DownlinkSpec {
    /// exact delta frames (today's lossless path; the default)
    #[default]
    Exact,
    /// identity EF compressor — drops nothing; reproduces the exact path
    /// bit for bit (useful for A/B-validating EF configurations)
    Identity,
    /// Top-K EF compressor with fractional K = round(q·d), 0 < q ≤ 1
    TopK { q: f64 },
    /// Top-K EF compressor with absolute K ≥ 1 (clamped to d at build)
    TopKAbs { k: usize },
}

impl DownlinkSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        if j.is_null() || j.get("exact").as_bool() == Some(true) {
            return Ok(DownlinkSpec::Exact);
        }
        match j.get("compressor").as_str() {
            Some("identity") => Ok(DownlinkSpec::Identity),
            Some("top-k") => {
                let q = j.get("q").as_f64();
                let k = j.get("k").as_usize();
                match (q, k) {
                    (Some(qv), None) if qv > 0.0 && qv <= 1.0 => {
                        Ok(DownlinkSpec::TopK { q: qv })
                    }
                    (None, Some(kv)) if kv >= 1 => Ok(DownlinkSpec::TopKAbs { k: kv }),
                    (Some(_), Some(_)) => {
                        Err(bad("cluster.downlink: give either q or k, not both"))
                    }
                    (None, None) => Err(bad("cluster.downlink top-k needs q = K/d or k = K")),
                    _ => Err(bad(
                        "cluster.downlink top-k needs 0 < q ≤ 1 or k ≥ 1",
                    )),
                }
            }
            Some(other) => Err(bad(format!(
                "cluster.downlink compressor '{other}' unsupported \
                 (contractive & deterministic required: identity or top-k)"
            ))),
            None => Err(bad("cluster.downlink needs a compressor (or exact: true)")),
        }
    }

    /// Build the EF compressor for dimension `d` (`None` = exact path).
    pub fn build(&self, d: usize) -> Option<Box<dyn Compressor>> {
        match self {
            DownlinkSpec::Exact => None,
            DownlinkSpec::Identity => Some(Box::new(Identity::new(d))),
            DownlinkSpec::TopK { q } => Some(Box::new(TopK::with_q(d, *q))),
            DownlinkSpec::TopKAbs { k } => Some(Box::new(TopK::new(d, (*k).clamp(1, d)))),
        }
    }
}

/// The `"cluster.uplink"` object: whether workers run the error-fed-back
/// (EF-BV-style) uplink — each ships `C_i(e_i + m_i)` from a worker-side
/// accumulator instead of `Q_i(m_i)`, making contractive (biased)
/// compressors valid on the worker → master path. See
/// [`crate::ef::EfUplink`]; the algorithm × compressor pairing matrix is
/// validated at parse time (see [`ExperimentConfig::parse`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum UplinkSpec {
    /// exact uplink: workers ship `Q_i(m_i)` (the default; requires an
    /// unbiased Q for every algorithm that compresses gradients)
    #[default]
    Exact,
    /// error-fed-back uplink: workers ship `C_i(e_i + m_i)` and retry the
    /// residual next round
    ErrorFeedback,
}

impl UplinkSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        if j.is_null() {
            return Ok(UplinkSpec::Exact);
        }
        let exact = j.get("exact").as_bool();
        let ef = j.get("error_feedback").as_bool();
        match (exact, ef) {
            (Some(true), Some(true)) => Err(bad(
                "cluster.uplink: exact and error_feedback are mutually exclusive",
            )),
            (Some(false), Some(false)) => Err(bad(
                "cluster.uplink: both modes negated — say which one you want \
                 (exact: true or error_feedback: true)",
            )),
            (Some(false), None) => Err(bad(
                "cluster.uplink: exact: false is ambiguous — say error_feedback: true|false",
            )),
            (Some(true), _) | (None, Some(false)) => Ok(UplinkSpec::Exact),
            (_, Some(true)) => Ok(UplinkSpec::ErrorFeedback),
            (None, None) => Err(bad(
                "cluster.uplink needs error_feedback: true|false (or exact: true)",
            )),
        }
    }
}

/// Coordinator-level knobs (the `"cluster"` JSON object, all optional).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    /// broadcast a dense resync frame every this many rounds (0 = only on
    /// round 0 and after `set_x0`)
    pub resync_every: usize,
    /// wire precision for compressed frames (delta values are pre-quantized
    /// so replicas stay bit-exact; resync frames are always f64)
    pub prec: ValPrec,
    /// local shifted sub-steps per communication round, batched into one
    /// uplink frame (1 = the per-round protocol)
    pub local_steps: usize,
    /// price rounds with the overlap-aware pipelined wall-clock model
    /// (simulated time only; trajectories are identical)
    pub pipeline: bool,
    /// error-fed-back downlink compressor (default: exact delta frames)
    pub downlink: DownlinkSpec,
    /// error-fed-back uplink toggle (default: exact `Q_i(m_i)` frames)
    pub uplink: UplinkSpec,
    /// deterministic fault injection schedule (`"faults"` array; default:
    /// no faults) — see [`crate::coordinator::faults`]
    pub faults: FaultPlan,
    /// gather deadline per round in milliseconds (must be > 0)
    pub round_timeout_ms: u64,
    /// consecutive deadline misses before quarantine (must be ≥ 1)
    pub quarantine_after: usize,
    /// master fold-pool width (must be ≥ 1 when given; `None` = auto-size
    /// from the `SHIFTCOMP_MASTER_THREADS` environment variable, else
    /// `available_parallelism`). Bit-identical trajectories for every
    /// value — this knob trades master wall-clock only.
    pub master_threads: Option<usize>,
    /// semi-async quorum gather: close each round after this many fresh
    /// updates (must be in 2..=workers when given; `None` or `workers` =
    /// the barrier gather, bit-identical to the historical path). `m <
    /// workers` requires the dcgd algorithm with `local_steps = 1`; see
    /// [`crate::coordinator::ClusterConfig::quorum`]
    pub quorum: Option<usize>,
    /// FedAvg-style seeded partial participation fraction (must lie in
    /// (0, 1] when given; `None` = every worker every round). Requires
    /// the dcgd algorithm with `local_steps = 1`; see
    /// [`crate::coordinator::ClusterConfig::participation`]
    pub participation: Option<f64>,
    /// fold one-round-late frames into the next round as damped stale
    /// gradients (default off); see
    /// [`crate::coordinator::ClusterConfig::staleness`]
    pub staleness: bool,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            resync_every: 0,
            prec: ValPrec::F64,
            local_steps: 1,
            pipeline: false,
            downlink: DownlinkSpec::Exact,
            uplink: UplinkSpec::Exact,
            faults: FaultPlan::new(),
            round_timeout_ms: DEFAULT_ROUND_TIMEOUT_MS,
            quarantine_after: 1,
            master_threads: None,
            quorum: None,
            participation: None,
            staleness: false,
        }
    }
}

impl ClusterSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        if j.is_null() {
            return Ok(Self::default());
        }
        let prec = match j.get("prec").as_str() {
            None | Some("f64") => ValPrec::F64,
            Some("f32") => ValPrec::F32,
            Some(other) => return Err(bad(format!("unknown cluster.prec '{other}'"))),
        };
        let re_j = j.get("resync_every");
        let resync_every = if re_j.is_null() {
            0
        } else {
            re_j.as_usize()
                .ok_or_else(|| bad("cluster.resync_every must be a non-negative integer"))?
        };
        let ls_j = j.get("local_steps");
        let local_steps = if ls_j.is_null() {
            1
        } else {
            // the batch frame's count field is a u16 — reject out-of-range
            // values here so build_distributed never trips the runner's
            // assert on a config-supplied value
            match ls_j.as_usize() {
                Some(v) if (1..=u16::MAX as usize).contains(&v) => v,
                _ => {
                    return Err(bad(
                        "cluster.local_steps must be an integer in 1..=65535",
                    ))
                }
            }
        };
        let pl_j = j.get("pipeline");
        let pipeline = if pl_j.is_null() {
            false
        } else {
            pl_j.as_bool()
                .ok_or_else(|| bad("cluster.pipeline must be a boolean"))?
        };
        let downlink = DownlinkSpec::parse(j.get("downlink"))?;
        let uplink = UplinkSpec::parse(j.get("uplink"))?;
        let faults = Self::parse_faults(j.get("faults"))?;
        let rt_j = j.get("round_timeout_ms");
        let round_timeout_ms = if rt_j.is_null() {
            DEFAULT_ROUND_TIMEOUT_MS
        } else {
            match rt_j.as_usize() {
                Some(v) if v >= 1 => v as u64,
                _ => return Err(bad("cluster.round_timeout_ms must be a positive integer")),
            }
        };
        let qa_j = j.get("quarantine_after");
        let quarantine_after = if qa_j.is_null() {
            1
        } else {
            match qa_j.as_usize() {
                Some(v) if v >= 1 => v,
                _ => return Err(bad("cluster.quarantine_after must be an integer >= 1")),
            }
        };
        let mt_j = j.get("master_threads");
        let master_threads = if mt_j.is_null() {
            None
        } else {
            // reject 0 and absurd widths here so build_distributed never
            // trips the fold pool's assert on a config-supplied value
            match mt_j.as_usize() {
                Some(v) if (1..=crate::coordinator::pool::MAX_FOLD_THREADS).contains(&v) => {
                    Some(v)
                }
                _ => {
                    return Err(bad(format!(
                        "cluster.master_threads must be an integer in 1..={} (omit it to \
                         auto-size the fold pool)",
                        crate::coordinator::pool::MAX_FOLD_THREADS
                    )))
                }
            }
        };
        let qm_j = j.get("quorum");
        let quorum = if qm_j.is_null() {
            None
        } else {
            // the upper bound (the fleet size) is cross-checked against
            // the problem spec in validate(); a 1-quorum would let every
            // round close on worker 0 alone and is rejected outright
            match qm_j.as_usize() {
                Some(v) if v >= 2 => Some(v),
                _ => {
                    return Err(bad(
                        "cluster.quorum must be an integer >= 2 (and at most problem.workers; \
                         omit it for the barrier gather)",
                    ))
                }
            }
        };
        let pf_j = j.get("participation");
        let participation = if pf_j.is_null() {
            None
        } else {
            match pf_j.as_f64() {
                Some(f) if f > 0.0 && f <= 1.0 => Some(f),
                _ => {
                    return Err(bad(
                        "cluster.participation must be a fraction in (0, 1] (omit it for \
                         full participation)",
                    ))
                }
            }
        };
        let st_j = j.get("staleness");
        let staleness = if st_j.is_null() {
            false
        } else {
            st_j.as_bool()
                .ok_or_else(|| bad("cluster.staleness must be a boolean"))?
        };
        Ok(Self {
            resync_every,
            prec,
            local_steps,
            pipeline,
            downlink,
            uplink,
            faults,
            round_timeout_ms,
            quarantine_after,
            master_threads,
            quorum,
            participation,
            staleness,
        })
    }

    /// The `"cluster.faults"` array: each element is an object
    /// `{"worker": i, "kind": "...", "round": k}` where kind is one of
    /// `crash`, `garbage_uplink`, `corrupt_downlink` or `straggle`
    /// (straggle additionally takes `"rounds": s ≥ 1`, the window length).
    /// Worker indices are range-checked against the fleet later, by
    /// [`DistributedRunner::new`].
    fn parse_faults(j: &Json) -> Result<FaultPlan, ConfigError> {
        if j.is_null() {
            return Ok(FaultPlan::new());
        }
        let items = j
            .as_arr()
            .ok_or_else(|| bad("cluster.faults must be an array of fault objects"))?;
        let mut plan = FaultPlan::new();
        for (i, item) in items.iter().enumerate() {
            let worker = item.get("worker").as_usize().ok_or_else(|| {
                bad(format!(
                    "cluster.faults[{i}].worker must be a non-negative integer"
                ))
            })?;
            let round = item.get("round").as_usize().ok_or_else(|| {
                bad(format!(
                    "cluster.faults[{i}].round must be a non-negative integer"
                ))
            })?;
            let kind = item
                .get("kind")
                .as_str()
                .ok_or_else(|| bad(format!("cluster.faults[{i}].kind missing")))?;
            plan = match kind {
                "crash" => plan.crash(worker, round),
                "garbage_uplink" => plan.garbage_uplink(worker, round),
                "corrupt_downlink" => plan.corrupt_downlink(worker, round),
                "straggle" => {
                    let rounds = item
                        .get("rounds")
                        .as_usize()
                        .filter(|r| *r >= 1)
                        .ok_or_else(|| {
                            bad(format!(
                                "cluster.faults[{i}]: straggle needs an integer rounds >= 1"
                            ))
                        })?;
                    plan.straggle(worker, round, rounds)
                }
                other => {
                    return Err(bad(format!(
                        "cluster.faults[{i}]: unknown kind '{other}' (crash | \
                         garbage_uplink | corrupt_downlink | straggle)"
                    )))
                }
            };
        }
        Ok(plan)
    }
}

// ---------------------------------------------------------------- algorithm

#[derive(Clone, Debug, PartialEq)]
pub enum AlgorithmSpec {
    Dgd,
    Dcgd,
    DcgdStar,
    Diana { with_top_k_c: Option<f64> },
    RandDiana { p: Option<f64>, m_factor: Option<f64> },
    Gdci,
    VrGdci,
}

impl AlgorithmSpec {
    pub fn parse(j: &Json) -> Result<Self, ConfigError> {
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| bad("algorithm.kind missing"))?;
        match kind {
            "dgd" | "gd" => Ok(AlgorithmSpec::Dgd),
            "dcgd" => Ok(AlgorithmSpec::Dcgd),
            "dcgd-star" | "star" => Ok(AlgorithmSpec::DcgdStar),
            "diana" => Ok(AlgorithmSpec::Diana {
                with_top_k_c: j.get("c_top_q").as_f64(),
            }),
            "rand-diana" => Ok(AlgorithmSpec::RandDiana {
                p: j.get("p").as_f64(),
                m_factor: j.get("m_factor").as_f64(),
            }),
            "gdci" => Ok(AlgorithmSpec::Gdci),
            "vr-gdci" => Ok(AlgorithmSpec::VrGdci),
            other => Err(bad(format!("unknown algorithm kind '{other}'"))),
        }
    }

    /// Build a ready-to-run algorithm instance. `uplink_ef` arms the
    /// error-fed-back uplink on the DCGD-SHIFT family (the single-process
    /// mirror of `cluster.uplink`). Invalid algorithm × compressor ×
    /// uplink pairings return a descriptive [`ConfigError`] — the matrix
    /// [`ExperimentConfig::parse`] already checks up front, kept here as a
    /// second line of defense for programmatic callers (this used to be a
    /// `panic!` deep inside the compressor dispatch).
    pub fn build(
        &self,
        p: &dyn Problem,
        comp: &CompressorSpec,
        seed: u64,
        uplink_ef: bool,
    ) -> Result<Box<dyn Algorithm>, ConfigError> {
        let d = p.dim();
        macro_rules! with_q {
            ($ctor:expr) => {
                match comp {
                    CompressorSpec::Identity => Ok($ctor(Identity::new(d))),
                    CompressorSpec::RandK { q } => Ok($ctor(RandK::with_q(d, *q))),
                    CompressorSpec::NaturalDithering { s, p: np } => {
                        Ok($ctor(NaturalDithering::new(d, *s, *np)))
                    }
                    CompressorSpec::StandardDithering { s } => {
                        Ok($ctor(StandardDithering::new(d, *s)))
                    }
                    CompressorSpec::NaturalCompression => Ok($ctor(NaturalCompression::new(d))),
                    CompressorSpec::Bernoulli { p: bp } => Ok($ctor(BernoulliP::new(d, *bp))),
                    CompressorSpec::Ternary => Ok($ctor(Ternary::new(d))),
                    CompressorSpec::TopK { .. } => Err(bad(format!(
                        "{self:?} needs an unbiased Q on the exact uplink; top-k is \
                         biased — arm cluster.uplink {{\"error_feedback\": true}} with \
                         the dcgd algorithm to use contractive compressors"
                    ))),
                }
            };
        }
        // the EF uplink is a DCGD-SHIFT-family construction; algorithms
        // without a worker-accumulator mapping reject it up front
        if uplink_ef
            && !matches!(
                self,
                AlgorithmSpec::Dcgd
                    | AlgorithmSpec::Diana { with_top_k_c: None }
                    | AlgorithmSpec::RandDiana { .. }
            )
        {
            return Err(bad(format!(
                "cluster.uplink error feedback supports dcgd, plain diana and \
                 rand-diana; {self:?} has no EF-uplink mapping"
            )));
        }
        match self {
            AlgorithmSpec::Dgd => Ok(Box::new(Gd::new(p, seed))),
            AlgorithmSpec::Dcgd if uplink_ef => {
                // EF unlocks contractive compressors for plain DCGD: every
                // in-tree operator reports a contraction δ, and γ comes
                // from the EF-BV rule inside DcgdShift::dcgd_ef
                Ok(match comp {
                    CompressorSpec::Identity => {
                        Box::new(DcgdShift::dcgd_ef(p, Identity::new(d), seed))
                            as Box<dyn Algorithm>
                    }
                    CompressorSpec::RandK { q } => {
                        Box::new(DcgdShift::dcgd_ef(p, RandK::with_q(d, *q), seed))
                    }
                    CompressorSpec::TopK { q } => {
                        Box::new(DcgdShift::dcgd_ef(p, TopK::with_q(d, *q), seed))
                    }
                    CompressorSpec::NaturalDithering { s, p: np } => {
                        Box::new(DcgdShift::dcgd_ef(p, NaturalDithering::new(d, *s, *np), seed))
                    }
                    CompressorSpec::StandardDithering { s } => {
                        Box::new(DcgdShift::dcgd_ef(p, StandardDithering::new(d, *s), seed))
                    }
                    CompressorSpec::NaturalCompression => {
                        Box::new(DcgdShift::dcgd_ef(p, NaturalCompression::new(d), seed))
                    }
                    CompressorSpec::Bernoulli { p: bp } => {
                        Box::new(DcgdShift::dcgd_ef(p, BernoulliP::new(d, *bp), seed))
                    }
                    CompressorSpec::Ternary => {
                        Box::new(DcgdShift::dcgd_ef(p, Ternary::new(d), seed))
                    }
                })
            }
            AlgorithmSpec::Dcgd => {
                with_q!(|q| Box::new(DcgdShift::dcgd(p, q, seed)) as Box<dyn Algorithm>)
            }
            AlgorithmSpec::DcgdStar => {
                with_q!(|q| Box::new(DcgdShift::star(p, q, None, seed)) as Box<dyn Algorithm>)
            }
            AlgorithmSpec::Diana { with_top_k_c } => {
                let c: Option<Box<dyn Compressor>> = with_top_k_c
                    .map(|cq| Box::new(TopK::with_q(d, cq)) as Box<dyn Compressor>);
                with_q!(|q| {
                    let mut alg = DcgdShift::diana(p, q, c.clone(), seed);
                    if uplink_ef {
                        alg.set_uplink_ef();
                    }
                    Box::new(alg) as Box<dyn Algorithm>
                })
            }
            AlgorithmSpec::RandDiana { p: pr, m_factor } => {
                let m_override = match m_factor {
                    Some(b) => {
                        let omega = comp
                            .omega(d)
                            .ok_or_else(|| bad("rand-diana m_factor needs an unbiased Q"))?;
                        let n = p.n_workers() as f64;
                        let prr = pr.unwrap_or(1.0 / (omega + 1.0));
                        Some(b * 2.0 * omega / (n * prr))
                    }
                    None => None,
                };
                with_q!(|q| {
                    let mut alg = DcgdShift::rand_diana_with_m(p, q, *pr, m_override, seed);
                    if uplink_ef {
                        alg.set_uplink_ef();
                    }
                    Box::new(alg) as Box<dyn Algorithm>
                })
            }
            AlgorithmSpec::Gdci => {
                with_q!(|q| Box::new(Gdci::new(p, q, seed)) as Box<dyn Algorithm>)
            }
            AlgorithmSpec::VrGdci => {
                with_q!(|q| Box::new(VrGdci::new(p, q, seed)) as Box<dyn Algorithm>)
            }
        }
    }
}

// --------------------------------------------------------------- experiment

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub problem: ProblemSpec,
    pub algorithm: AlgorithmSpec,
    pub compressor: CompressorSpec,
    pub run: RunOpts,
    pub cluster: ClusterSpec,
    pub seed: u64,
}

impl ExperimentConfig {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let j = Json::parse(text)?;
        let problem = ProblemSpec::parse(j.get("problem"))?;
        let algorithm = AlgorithmSpec::parse(j.get("algorithm"))?;
        let compressor = CompressorSpec::parse(j.get("compressor"))?;
        let run_j = j.get("run");
        let run = RunOpts {
            max_rounds: run_j.get("max_rounds").as_usize().unwrap_or(10_000),
            tol: run_j.get("tol").as_f64().unwrap_or(1e-12),
            record_every: run_j.get("record_every").as_usize().unwrap_or(1).max(1),
            record_loss: run_j.get("record_loss").as_bool().unwrap_or(false),
            ..Default::default()
        };
        let cluster = ClusterSpec::parse(j.get("cluster"))?;
        let seed = j.get("seed").as_f64().unwrap_or(42.0) as u64;
        let cfg = Self {
            problem,
            algorithm,
            compressor,
            run,
            cluster,
            seed,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The algorithm × compressor × uplink pairing matrix, checked in one
    /// place at parse time so an invalid configuration is a descriptive
    /// [`ConfigError`] up front — not a build-time panic deep inside the
    /// algorithm factory:
    ///
    /// | `cluster.uplink`     | unbiased Q                    | biased (top-k)       |
    /// |----------------------|-------------------------------|----------------------|
    /// | exact (default)      | every algorithm               | dgd only             |
    /// | error feedback       | dcgd, plain diana, rand-diana | dcgd (γ from EF-BV)  |
    ///
    /// The EF row is the point of the uplink section: worker-side error
    /// feedback makes contractive compressors sound on the worker → master
    /// path ([`crate::ef::EfUplink`]). DIANA-family methods keep their
    /// ω-based step rules, so they stay unbiased-only even under EF.
    fn validate(&self) -> Result<(), ConfigError> {
        // A lossy EF downlink keeps a residual accumulator whose support
        // (and hence the replica overlay patch every round broadcasts)
        // can only be truncated by a dense resync — without one scheduled,
        // the overlay's nnz is unbounded over a long run.
        if self.cluster.resync_every == 0
            && matches!(
                self.cluster.downlink,
                DownlinkSpec::TopK { .. } | DownlinkSpec::TopKAbs { .. }
            )
        {
            return Err(bad(
                "cluster.downlink is lossy (top-k) but cluster.resync_every is 0: \
                 overlays need a periodic truncation point to stay sparse. Set \
                 cluster.resync_every to a positive round interval",
            ));
        }
        let biased = matches!(self.compressor, CompressorSpec::TopK { .. });
        match self.cluster.uplink {
            UplinkSpec::Exact => {
                if biased && !matches!(self.algorithm, AlgorithmSpec::Dgd) {
                    return Err(bad(format!(
                        "algorithm {:?} needs an unbiased Q on the exact uplink; top-k \
                         is biased. Arm the error-fed-back uplink (cluster.uplink: \
                         {{\"error_feedback\": true}}, dcgd algorithm) to use \
                         contractive compressors",
                        self.algorithm
                    )));
                }
            }
            UplinkSpec::ErrorFeedback => match (&self.algorithm, biased) {
                (AlgorithmSpec::Dcgd, _) => {}
                (AlgorithmSpec::Diana { with_top_k_c: None }, false) => {}
                (AlgorithmSpec::RandDiana { .. }, false) => {}
                (
                    AlgorithmSpec::Diana { with_top_k_c: None }
                    | AlgorithmSpec::RandDiana { .. },
                    true,
                ) => {
                    return Err(bad(format!(
                        "{:?} with a biased Q has no step-size rule (α and M need ω); \
                         use the dcgd algorithm for the contractive EF uplink",
                        self.algorithm
                    )));
                }
                (other, _) => {
                    return Err(bad(format!(
                        "cluster.uplink error feedback supports dcgd, plain diana and \
                         rand-diana; {other:?} has no EF-uplink mapping"
                    )));
                }
            },
        }
        // ---- semi-async knobs (quorum / participation / staleness).
        // These reshape who contributes to a round, which only the
        // fixed-shift estimator tolerates: shift-learning (DIANA-family)
        // methods advance h_i on both ends every round, so a cut,
        // sampled-out or late frame would desynchronize master and
        // worker shift state. `quorum = workers` is the barrier gather
        // and stays legal everywhere.
        let n = self.problem.workers();
        if let Some(m) = self.cluster.quorum {
            if m > n {
                return Err(bad(format!(
                    "cluster.quorum = {m} exceeds problem.workers = {n}; a quorum the \
                     fleet can never reach would deadline every round"
                )));
            }
        }
        let semi_async = self.cluster.quorum.is_some_and(|m| m < n)
            || self.cluster.participation.is_some()
            || self.cluster.staleness;
        if semi_async {
            if !matches!(self.algorithm, AlgorithmSpec::Dcgd) {
                return Err(bad(format!(
                    "cluster.quorum < workers, cluster.participation and cluster.staleness \
                     require the dcgd algorithm (fixed shifts); {:?} learns shifts on both \
                     ends and would desynchronize under cut or sampled-out frames",
                    self.algorithm
                )));
            }
            if self.cluster.local_steps > 1 {
                return Err(bad(format!(
                    "cluster.quorum < workers, cluster.participation and cluster.staleness \
                     do not compose with cluster.local_steps = {} (batched frames cannot \
                     fold partially)",
                    self.cluster.local_steps
                )));
            }
        }
        if self.cluster.uplink == UplinkSpec::ErrorFeedback
            && self.cluster.quorum.is_some_and(|m| m < n)
            && !self.cluster.staleness
        {
            return Err(bad(
                "cluster.quorum < workers with the error-fed-back uplink needs \
                 cluster.staleness: true — a cut worker has already retired the shipped \
                 frame from its EF accumulator, so the frame must fold late instead of \
                 being dropped",
            ));
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("reading {path}: {e}")))?;
        Self::parse(&text)
    }

    /// Build problem + algorithm and run to completion. The cluster's
    /// uplink mode applies to the single-process driver too (the EF-uplink
    /// mirror), so one config means one method across drivers.
    pub fn execute(&self) -> Result<crate::metrics::Trace, ConfigError> {
        let problem = self.problem.build()?;
        let mut alg = self.algorithm.build(
            problem.as_ref(),
            &self.compressor,
            self.seed,
            self.cluster.uplink == UplinkSpec::ErrorFeedback,
        )?;
        Ok(alg.run(problem.as_ref(), &self.run))
    }

    /// Build the threaded coordinator for this experiment (same seeds,
    /// shifts and step sizes as the single-process driver, plus the
    /// `"cluster"` knobs). Errors on algorithms without a distributed
    /// method mapping (GD/GDCI families) or biased compressors.
    pub fn build_distributed(&self) -> Result<(Arc<dyn Problem>, DistributedRunner), ConfigError> {
        let problem: Arc<dyn Problem> = Arc::from(self.problem.build()?);
        let d = problem.dim();
        let n = problem.n_workers();
        let ef = self.cluster.uplink == UplinkSpec::ErrorFeedback;
        // a biased compressor is only reachable here with the EF uplink
        // armed (parse validates the pairing matrix); every other mapping
        // needs ω
        let omega = self.compressor.omega(d);
        let need_omega = || {
            omega.ok_or_else(|| {
                bad("distributed runs need an unbiased compressor (or the error-fed-back uplink)")
            })
        };
        let (method, gamma) = match &self.algorithm {
            AlgorithmSpec::Dcgd if ef => {
                // EF-BV step from the compressor's contraction δ — the
                // same γ DcgdShift::dcgd_ef derives, so the config-built
                // cluster and single-process mirror agree bit for bit
                let delta = self
                    .compressor
                    .build(d)
                    .delta()
                    .filter(|dl| *dl > 0.0)
                    .ok_or_else(|| bad("the EF uplink needs a contractive compressor (δ > 0)"))?;
                let ss = theory::ef_uplink(problem.as_ref(), &vec![delta; n]);
                (MethodKind::Fixed, ss.gamma)
            }
            AlgorithmSpec::Dcgd => {
                let ss = theory::dcgd_fixed(problem.as_ref(), &vec![need_omega()?; n]);
                (MethodKind::Fixed, ss.gamma)
            }
            AlgorithmSpec::Diana { with_top_k_c: None } => {
                let omega = need_omega()?;
                let ss = theory::diana(problem.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
                (
                    MethodKind::Diana {
                        alpha: ss.alpha,
                        with_c: false,
                    },
                    ss.gamma,
                )
            }
            AlgorithmSpec::RandDiana { p, .. } => {
                let omega = need_omega()?;
                let pr = p.unwrap_or_else(|| theory::rand_diana_default_p(omega));
                let ss = theory::rand_diana(problem.as_ref(), omega, &vec![pr; n], None);
                (MethodKind::RandDiana { p: pr }, ss.gamma)
            }
            other => {
                return Err(bad(format!(
                    "algorithm {other:?} has no distributed-runner mapping"
                )))
            }
        };
        if self.cluster.local_steps > 1
            && !matches!(
                method,
                MethodKind::Fixed | MethodKind::Diana { with_c: false, .. }
            )
        {
            return Err(bad(format!(
                "cluster.local_steps > 1 supports the fixed-shift and \
                 DIANA-without-C methods, not {method:?}"
            )));
        }
        let qs: Vec<Box<dyn Compressor>> = (0..n).map(|_| self.compressor.build(d)).collect();
        let runner = DistributedRunner::new(
            problem.clone(),
            qs,
            None,
            vec![vec![0.0; d]; n],
            ClusterConfig {
                method,
                gamma,
                prec: self.cluster.prec,
                seed: self.seed,
                links: None,
                resync_every: self.cluster.resync_every,
                local_steps: self.cluster.local_steps,
                pipeline: self.cluster.pipeline,
                downlink: self.cluster.downlink.build(d),
                uplink_ef: ef,
                faults: (!self.cluster.faults.faults.is_empty())
                    .then(|| self.cluster.faults.clone()),
                round_timeout_ms: self.cluster.round_timeout_ms,
                quarantine_after: self.cluster.quarantine_after,
                master_threads: self.cluster.master_threads,
                quorum: self.cluster.quorum,
                participation: self.cluster.participation,
                staleness: self.cluster.staleness,
            },
        );
        Ok((problem, runner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "problem": {"kind": "quadratic", "d": 15, "workers": 4, "mu": 1.0, "l": 10.0, "seed": 3},
        "algorithm": {"kind": "rand-diana"},
        "compressor": {"kind": "rand-k", "q": 0.25},
        "run": {"max_rounds": 20000, "tol": 1e-10, "record_every": 10},
        "seed": 3
    }"#;

    #[test]
    fn parses_and_executes_sample() {
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.run.max_rounds, 20_000);
        let trace = cfg.execute().unwrap();
        assert!(trace.converged, "err {:e}", trace.final_relative_error());
    }

    #[test]
    fn rejects_unknown_kinds() {
        assert!(ProblemSpec::parse(&Json::parse(r#"{"kind": "sudoku"}"#).unwrap()).is_err());
        assert!(CompressorSpec::parse(&Json::parse(r#"{"kind": "zip"}"#).unwrap()).is_err());
        assert!(AlgorithmSpec::parse(&Json::parse(r#"{"kind": "adam"}"#).unwrap()).is_err());
    }

    #[test]
    fn missing_required_fields_error() {
        assert!(CompressorSpec::parse(&Json::parse(r#"{"kind": "rand-k"}"#).unwrap()).is_err());
        assert!(ExperimentConfig::parse("{}").is_err());
    }

    #[test]
    fn ridge_defaults_match_paper() {
        let spec =
            ProblemSpec::parse(&Json::parse(r#"{"kind": "ridge", "seed": 1}"#).unwrap()).unwrap();
        match spec {
            ProblemSpec::Ridge {
                m,
                d,
                workers,
                lambda,
                ..
            } => {
                assert_eq!((m, d, workers), (100, 80, 10));
                assert!((lambda - 0.01).abs() < 1e-12);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn cluster_spec_parses_and_defaults() {
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.cluster, ClusterSpec::default());
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "diana"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"prec": "f32", "resync_every": 25}
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.resync_every, 25);
        assert_eq!(cfg.cluster.prec, ValPrec::F32);
        let bad = with.replace("f32", "f16");
        assert!(ExperimentConfig::parse(&bad).is_err());
        // a wrong-typed resync_every must error, not silently become 0
        let bad = with.replace("25", "\"25\"");
        assert!(ExperimentConfig::parse(&bad).is_err());
    }

    #[test]
    fn fault_schedule_parses_and_validates() {
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {
                "round_timeout_ms": 250,
                "quarantine_after": 2,
                "faults": [
                    {"worker": 2, "kind": "crash", "round": 7},
                    {"worker": 1, "kind": "straggle", "round": 3, "rounds": 4},
                    {"worker": 1, "kind": "garbage_uplink", "round": 12},
                    {"worker": 0, "kind": "corrupt_downlink", "round": 5}
                ]
            }
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.round_timeout_ms, 250);
        assert_eq!(cfg.cluster.quarantine_after, 2);
        assert_eq!(
            cfg.cluster.faults,
            FaultPlan::new()
                .crash(2, 7)
                .straggle(1, 3, 4)
                .garbage_uplink(1, 12)
                .corrupt_downlink(0, 5)
        );
        assert!(cfg.build_distributed().is_ok());
        // defaults: no faults, generous deadline, quarantine on first miss
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        assert!(cfg.cluster.faults.faults.is_empty());
        assert_eq!(cfg.cluster.round_timeout_ms, DEFAULT_ROUND_TIMEOUT_MS);
        assert_eq!(cfg.cluster.quarantine_after, 1);
        // parse-time validation: unknown kinds, missing straggle window,
        // non-array faults, zero deadline / quarantine threshold all error
        assert!(
            ExperimentConfig::parse(&with.replace(r#""kind": "crash""#, r#""kind": "reboot""#))
                .is_err()
        );
        assert!(ExperimentConfig::parse(&with.replace(r#", "rounds": 4"#, "")).is_err());
        assert!(ExperimentConfig::parse(
            &with.replace(r#""round_timeout_ms": 250"#, r#""round_timeout_ms": 0"#)
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            &with.replace(r#""quarantine_after": 2"#, r#""quarantine_after": 0"#)
        )
        .is_err());
        let non_array = with.replace(
            r#""faults": ["#,
            r#""faults": {"worker": 0}, "ignored": ["#,
        );
        assert!(ExperimentConfig::parse(&non_array).is_err());
    }

    #[test]
    fn local_steps_and_pipeline_parse_and_validate() {
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"local_steps": 8, "pipeline": true}
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.local_steps, 8);
        assert!(cfg.cluster.pipeline);
        assert!(cfg.build_distributed().is_ok());
        // defaults
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.cluster.local_steps, 1);
        assert!(!cfg.cluster.pipeline);
        // parse-time validation: zero / out-of-range / wrong-typed values
        // error (the wire count field is a u16)
        assert!(
            ExperimentConfig::parse(&with.replace(r#""local_steps": 8"#, r#""local_steps": 0"#))
                .is_err()
        );
        assert!(ExperimentConfig::parse(
            &with.replace(r#""local_steps": 8"#, r#""local_steps": 70000"#)
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            &with.replace(r#""local_steps": 8"#, r#""local_steps": "8""#)
        )
        .is_err());
        assert!(
            ExperimentConfig::parse(&with.replace(r#""pipeline": true"#, r#""pipeline": 1"#))
                .is_err()
        );
        // rand-diana has no per-sub-step batching mapping: build must error
        let cfg =
            ExperimentConfig::parse(&with.replace(r#""kind": "dcgd""#, r#""kind": "rand-diana""#))
                .unwrap();
        assert!(cfg.build_distributed().is_err());
        // plain diana maps fine
        let cfg = ExperimentConfig::parse(&with.replace(r#""kind": "dcgd""#, r#""kind": "diana""#))
            .unwrap();
        assert!(cfg.build_distributed().is_ok());
    }

    #[test]
    fn master_threads_parses_builds_and_rejects() {
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"master_threads": 3}
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.master_threads, Some(3));
        // the knob reaches the runner's fold pool verbatim
        let (_p, runner) = cfg.build_distributed().unwrap();
        assert_eq!(runner.fold_threads(), 3);
        // default: auto-sized (spec stores None; resolution happens at
        // pool construction from env/available_parallelism)
        let dflt = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(dflt.cluster.master_threads, None);
        // the field participates in ClusterSpec equality
        assert_ne!(
            ClusterSpec {
                master_threads: Some(2),
                ..ClusterSpec::default()
            },
            ClusterSpec::default()
        );
        // parse-time validation: zero, over-cap and wrong-typed values all
        // error with a descriptive message instead of tripping the pool's
        // assert at build time
        let zero = with.replace(r#""master_threads": 3"#, r#""master_threads": 0"#);
        let err = ExperimentConfig::parse(&zero).unwrap_err();
        assert!(
            err.to_string().contains("master_threads"),
            "error must name the field: {err}"
        );
        assert!(ExperimentConfig::parse(
            &with.replace(r#""master_threads": 3"#, r#""master_threads": 100000"#)
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            &with.replace(r#""master_threads": 3"#, r#""master_threads": "4""#)
        )
        .is_err());
        // bit-identity across widths, through the config layer: T = 1 and
        // T = 3 clusters from the same spec track each other exactly
        let cfg1 = ExperimentConfig::parse(
            &with.replace(r#""master_threads": 3"#, r#""master_threads": 1"#),
        )
        .unwrap();
        let (p1, mut r1) = cfg1.build_distributed().unwrap();
        let (p3, mut r3) = cfg.build_distributed().unwrap();
        for k in 0..25 {
            r1.step(p1.as_ref());
            r3.step(p3.as_ref());
            assert_eq!(r1.x(), r3.x(), "diverged at round {k}");
        }
    }

    #[test]
    fn semi_async_knobs_parse_build_and_reject() {
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 4, "seed": 1},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"quorum": 2, "participation": 0.5, "staleness": true}
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.quorum, Some(2));
        assert_eq!(cfg.cluster.participation, Some(0.5));
        assert!(cfg.cluster.staleness);
        assert!(cfg.build_distributed().is_ok());
        // defaults: all off
        let dflt = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(dflt.cluster.quorum, None);
        assert_eq!(dflt.cluster.participation, None);
        assert!(!dflt.cluster.staleness);
        // every knob participates in ClusterSpec equality
        assert_ne!(
            ClusterSpec {
                quorum: Some(2),
                ..ClusterSpec::default()
            },
            ClusterSpec::default()
        );
        assert_ne!(
            ClusterSpec {
                participation: Some(0.5),
                ..ClusterSpec::default()
            },
            ClusterSpec::default()
        );
        assert_ne!(
            ClusterSpec {
                staleness: true,
                ..ClusterSpec::default()
            },
            ClusterSpec::default()
        );
        // parse-time range checks, with descriptive field-naming errors
        let err = ExperimentConfig::parse(&with.replace(r#""quorum": 2"#, r#""quorum": 1"#))
            .unwrap_err();
        assert!(err.to_string().contains("quorum"), "{err}");
        let err = ExperimentConfig::parse(&with.replace(r#""quorum": 2"#, r#""quorum": 9"#))
            .unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
        assert!(
            ExperimentConfig::parse(&with.replace(r#""quorum": 2"#, r#""quorum": "2""#)).is_err()
        );
        let err = ExperimentConfig::parse(
            &with.replace(r#""participation": 0.5"#, r#""participation": 0.0"#),
        )
        .unwrap_err();
        assert!(err.to_string().contains("participation"), "{err}");
        assert!(ExperimentConfig::parse(
            &with.replace(r#""participation": 0.5"#, r#""participation": 1.5"#)
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            &with.replace(r#""staleness": true"#, r#""staleness": 1"#)
        )
        .is_err());
        // cross-field gates: shift-learning algorithms and batched rounds
        // are rejected at parse time, not at build
        let err =
            ExperimentConfig::parse(&with.replace(r#""kind": "dcgd""#, r#""kind": "diana""#))
                .unwrap_err();
        assert!(err.to_string().contains("dcgd"), "{err}");
        let err = ExperimentConfig::parse(
            &with.replace(r#""staleness": true"#, r#""staleness": true, "local_steps": 4"#),
        )
        .unwrap_err();
        assert!(err.to_string().contains("local_steps"), "{err}");
        // an m < n quorum with the EF uplink requires staleness
        let ef = with
            .replace(r#""participation": 0.5, "#, "")
            .replace(
                r#""staleness": true"#,
                r#""staleness": false, "uplink": {"error_feedback": true}"#,
            );
        let err = ExperimentConfig::parse(&ef).unwrap_err();
        assert!(err.to_string().contains("staleness"), "{err}");
        assert!(ExperimentConfig::parse(&ef.replace(
            r#""staleness": false"#,
            r#""staleness": true"#
        ))
        .is_ok());
        // quorum = workers is the barrier gather and stays legal for every
        // method
        let barrier = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 4, "seed": 1},
            "algorithm": {"kind": "diana"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"quorum": 4}
        }"#;
        let cfg = ExperimentConfig::parse(barrier).unwrap();
        assert!(cfg.build_distributed().is_ok());
        // degenerate pin through the config layer: quorum = workers plus
        // participation = 1.0 is the barrier round, bit for bit
        let degenerate = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 4, "seed": 1},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"quorum": 4, "participation": 1.0}
        }"#;
        let plain = degenerate.replace(
            r#""cluster": {"quorum": 4, "participation": 1.0}"#,
            r#""cluster": {}"#,
        );
        let (pd, mut rd) = ExperimentConfig::parse(degenerate)
            .unwrap()
            .build_distributed()
            .unwrap();
        let (pp, mut rp) = ExperimentConfig::parse(&plain)
            .unwrap()
            .build_distributed()
            .unwrap();
        for k in 0..25 {
            rd.step(pd.as_ref());
            rp.step(pp.as_ref());
            assert_eq!(rd.x(), rp.x(), "diverged at round {k}");
        }
    }

    #[test]
    fn downlink_spec_parses_builds_and_rejects() {
        let with = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "diana"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"resync_every": 50, "downlink": {"compressor": "top-k", "q": 0.2}}
        }"#;
        let cfg = ExperimentConfig::parse(with).unwrap();
        assert_eq!(cfg.cluster.downlink, DownlinkSpec::TopK { q: 0.2 });
        let comp = cfg.cluster.downlink.build(10).unwrap();
        assert_eq!(comp.name(), "top-k(2/10)");
        // k-form
        let cfg =
            ExperimentConfig::parse(&with.replace(r#""q": 0.2"#, r#""k": 3"#)).unwrap();
        assert_eq!(
            cfg.cluster.downlink.build(10).unwrap().name(),
            "top-k(3/10)"
        );
        // identity + exact fallback
        let cfg = ExperimentConfig::parse(
            &with.replace(r#""compressor": "top-k", "q": 0.2"#, r#""compressor": "identity""#),
        )
        .unwrap();
        assert_eq!(cfg.cluster.downlink, DownlinkSpec::Identity);
        assert!(cfg.cluster.downlink.build(10).is_some());
        let cfg = ExperimentConfig::parse(
            &with.replace(r#""compressor": "top-k", "q": 0.2"#, r#""exact": true"#),
        )
        .unwrap();
        assert_eq!(cfg.cluster.downlink, DownlinkSpec::Exact);
        assert!(cfg.cluster.downlink.build(10).is_none());
        // rejections: unsupported compressor, missing K, both q and k,
        // and out-of-range q/k (validated at parse time, not at build)
        assert!(ExperimentConfig::parse(&with.replace("top-k", "rand-k")).is_err());
        assert!(ExperimentConfig::parse(&with.replace(r#", "q": 0.2"#, "")).is_err());
        assert!(
            ExperimentConfig::parse(&with.replace(r#""q": 0.2"#, r#""q": 0.2, "k": 2"#))
                .is_err()
        );
        assert!(ExperimentConfig::parse(&with.replace(r#""q": 0.2"#, r#""k": 0"#)).is_err());
        assert!(ExperimentConfig::parse(&with.replace(r#""q": 0.2"#, r#""q": 0.0"#)).is_err());
        assert!(ExperimentConfig::parse(&with.replace(r#""q": 0.2"#, r#""q": 1.5"#)).is_err());
    }

    #[test]
    fn lossy_downlink_without_resync_schedule_is_rejected_at_parse() {
        // resync_every = 0 means "never truncate": fine for exact or
        // identity downlinks, but a lossy downlink's overlay patch then
        // has no bound on its support. The pairing must fail at parse
        // time with an actionable hint, not degrade silently at run time.
        let text = r#"{
            "problem": {"kind": "quadratic", "d": 10, "workers": 3, "seed": 1},
            "algorithm": {"kind": "diana"},
            "compressor": {"kind": "rand-k", "q": 0.3},
            "cluster": {"downlink": {"compressor": "top-k", "q": 0.2}}
        }"#;
        let err = ExperimentConfig::parse(text).unwrap_err().to_string();
        assert!(
            err.contains("overlays need a periodic truncation point to stay sparse"),
            "unhelpful error: {err}"
        );
        assert!(err.contains("resync_every"), "no actionable hint: {err}");
        // the k-form is just as lossy; identity and exact are not
        assert!(ExperimentConfig::parse(
            &text.replace(r#""q": 0.2"#, r#""k": 3"#)
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            &text.replace(r#""compressor": "top-k", "q": 0.2"#, r#""compressor": "identity""#)
        )
        .is_ok());
        assert!(ExperimentConfig::parse(
            &text.replace(r#""compressor": "top-k", "q": 0.2"#, r#""exact": true"#)
        )
        .is_ok());
        // an explicit schedule clears the rejection
        assert!(ExperimentConfig::parse(
            &text.replace(r#""cluster": {"#, r#""cluster": {"resync_every": 100, "#)
        )
        .is_ok());
    }

    #[test]
    fn distributed_identity_downlink_matches_exact_config() {
        // the EF path with an identity compressor must reproduce the exact
        // delta path bit for bit, end to end through the config layer
        let exact = ExperimentConfig::parse(SAMPLE).unwrap();
        let mut ident = ExperimentConfig::parse(SAMPLE).unwrap();
        ident.cluster.downlink = DownlinkSpec::Identity;
        let (p_a, mut a) = exact.build_distributed().unwrap();
        let (p_b, mut b) = ident.build_distributed().unwrap();
        for k in 0..30 {
            let sa = a.step(p_a.as_ref());
            let sb = b.step(p_b.as_ref());
            assert_eq!(a.x(), b.x(), "diverged at round {k}");
            assert_eq!(sa.bits_down, sb.bits_down, "downlink bits at round {k}");
        }
    }

    #[test]
    fn build_distributed_matches_single_process() {
        // the config-built coordinator must track the config-built
        // single-process driver bit for bit
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        let problem = cfg.problem.build().unwrap();
        let mut single = cfg
            .algorithm
            .build(problem.as_ref(), &cfg.compressor, cfg.seed, false)
            .unwrap();
        let (p, mut dist) = cfg.build_distributed().unwrap();
        for k in 0..40 {
            single.step(problem.as_ref());
            dist.step(p.as_ref());
            assert_eq!(single.x(), dist.x(), "diverged at round {k}");
        }
    }

    #[test]
    fn build_distributed_rejects_unmapped_algorithms() {
        let text = SAMPLE.replace("rand-diana", "gdci");
        let cfg = ExperimentConfig::parse(&text).unwrap();
        assert!(cfg.build_distributed().is_err());
    }

    #[test]
    fn biased_q_on_exact_uplink_is_a_parse_error_not_a_panic() {
        // the former behaviour was a panic at *build* time deep inside the
        // compressor dispatch; the pairing matrix now rejects the config
        // at parse with a descriptive message
        let text = SAMPLE.replace("rand-k", "top-k");
        let err = ExperimentConfig::parse(&text).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unbiased"), "unhelpful message: {msg}");
        assert!(msg.contains("error_feedback"), "should point at the EF uplink: {msg}");
        // the factory second line of defense errors too (no panic) for
        // programmatic callers that skip parse
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        let problem = cfg.problem.build().unwrap();
        let biased = CompressorSpec::TopK { q: 0.2 };
        assert!(cfg
            .algorithm
            .build(problem.as_ref(), &biased, 1, false)
            .is_err());
    }

    #[test]
    fn uplink_spec_parses_and_rejects() {
        let with = |uplink: &str| {
            format!(
                r#"{{
                    "problem": {{"kind": "quadratic", "d": 10, "workers": 3, "seed": 1}},
                    "algorithm": {{"kind": "dcgd"}},
                    "compressor": {{"kind": "rand-k", "q": 0.3}},
                    "cluster": {{"uplink": {uplink}}}
                }}"#
            )
        };
        let cfg = ExperimentConfig::parse(&with(r#"{"error_feedback": true}"#)).unwrap();
        assert_eq!(cfg.cluster.uplink, UplinkSpec::ErrorFeedback);
        let cfg = ExperimentConfig::parse(&with(r#"{"exact": true}"#)).unwrap();
        assert_eq!(cfg.cluster.uplink, UplinkSpec::Exact);
        let cfg = ExperimentConfig::parse(&with(r#"{"error_feedback": false}"#)).unwrap();
        assert_eq!(cfg.cluster.uplink, UplinkSpec::Exact);
        // defaults to exact when the object is absent
        let cfg = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.cluster.uplink, UplinkSpec::Exact);
        // rejections: empty object, contradictory flags, ambiguous or
        // double negation
        assert!(ExperimentConfig::parse(&with("{}")).is_err());
        assert!(
            ExperimentConfig::parse(&with(r#"{"exact": true, "error_feedback": true}"#)).is_err()
        );
        assert!(ExperimentConfig::parse(&with(r#"{"exact": false}"#)).is_err());
        assert!(
            ExperimentConfig::parse(&with(r#"{"exact": false, "error_feedback": false}"#))
                .is_err()
        );
    }

    #[test]
    fn ef_uplink_pairing_matrix() {
        let cfg_text = |alg: &str, comp: &str| {
            format!(
                r#"{{
                    "problem": {{"kind": "quadratic", "d": 12, "workers": 3, "seed": 2}},
                    "algorithm": {{"kind": "{alg}"}},
                    "compressor": {comp},
                    "cluster": {{"uplink": {{"error_feedback": true}}}}
                }}"#
            )
        };
        let randk = r#"{"kind": "rand-k", "q": 0.3}"#;
        let topk = r#"{"kind": "top-k", "q": 0.3}"#;
        // EF + dcgd: any compressor, including the biased one
        assert!(ExperimentConfig::parse(&cfg_text("dcgd", randk)).is_ok());
        assert!(ExperimentConfig::parse(&cfg_text("dcgd", topk)).is_ok());
        // EF + diana/rand-diana: unbiased only (α and M need ω)
        assert!(ExperimentConfig::parse(&cfg_text("diana", randk)).is_ok());
        assert!(ExperimentConfig::parse(&cfg_text("rand-diana", randk)).is_ok());
        assert!(ExperimentConfig::parse(&cfg_text("diana", topk)).is_err());
        assert!(ExperimentConfig::parse(&cfg_text("rand-diana", topk)).is_err());
        // EF + algorithms without an accumulator mapping
        for alg in ["gdci", "vr-gdci", "star", "dgd"] {
            assert!(
                ExperimentConfig::parse(&cfg_text(alg, randk)).is_err(),
                "{alg} must reject the EF uplink"
            );
        }
    }

    #[test]
    fn ef_uplink_topk_config_builds_and_matches_across_drivers() {
        // the headline unlock: dcgd + top-k, EF uplink armed — parses,
        // executes, and the config-built cluster tracks the config-built
        // single-process mirror bit for bit
        let text = r#"{
            "problem": {"kind": "quadratic", "d": 12, "workers": 3, "mu": 1.0, "l": 10.0, "seed": 5},
            "algorithm": {"kind": "dcgd"},
            "compressor": {"kind": "top-k", "q": 0.25},
            "run": {"max_rounds": 400, "tol": 1e-8},
            "cluster": {"uplink": {"error_feedback": true}},
            "seed": 5
        }"#;
        let cfg = ExperimentConfig::parse(text).unwrap();
        let problem = cfg.problem.build().unwrap();
        let mut single = cfg
            .algorithm
            .build(problem.as_ref(), &cfg.compressor, cfg.seed, true)
            .unwrap();
        let (p, mut dist) = cfg.build_distributed().unwrap();
        for k in 0..40 {
            single.step(problem.as_ref());
            dist.step(p.as_ref());
            assert_eq!(single.x(), dist.x(), "diverged at round {k}");
        }
        // and the whole config executes end to end (EF keeps Top-K stable)
        let trace = cfg.execute().unwrap();
        assert!(
            !trace.diverged,
            "EF-TopK run diverged: err {:e}",
            trace.final_relative_error()
        );
    }

    #[test]
    fn all_compressor_kinds_build() {
        for (text, unbiased) in [
            (r#"{"kind": "identity"}"#, true),
            (r#"{"kind": "rand-k", "q": 0.1}"#, true),
            (r#"{"kind": "top-k", "q": 0.1}"#, false),
            (r#"{"kind": "nd", "s": 4}"#, true),
            (r#"{"kind": "standard-dithering", "s": 8}"#, true),
            (r#"{"kind": "nat-comp"}"#, true),
            (r#"{"kind": "bernoulli", "p": 0.2}"#, true),
            (r#"{"kind": "ternary"}"#, true),
        ] {
            let spec = CompressorSpec::parse(&Json::parse(text).unwrap()).unwrap();
            let c = spec.build(30);
            assert_eq!(c.omega().is_some(), unbiased, "{text}");
            assert_eq!(c.dim(), 30);
        }
    }
}
