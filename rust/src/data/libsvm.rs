//! LibSVM text format: `label idx:val idx:val ...`, 1-based indices.
//!
//! The paper's logistic-regression experiment uses the `w2a` dataset from
//! the LibSVM repository. This module provides a full parser + writer; the
//! synthetic stand-in dataset (see [`crate::data::w2a`]) is emitted through
//! the writer and read back with the parser so the same code path a real
//! `w2a` file would take is exercised end to end.

use crate::data::sparse::{SparseDataset, SparseRow};

#[derive(Debug)]
pub enum LibsvmError {
    Parse { line: usize, msg: String },
    Io(std::io::Error),
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            LibsvmError::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> Self {
        LibsvmError::Io(e)
    }
}

/// Parse LibSVM text. Indices are converted to 0-based. Features indices
/// must be strictly increasing within a row (LibSVM convention).
pub fn parse(text: &str) -> Result<SparseDataset, LibsvmError> {
    let mut rows = Vec::new();
    let mut n_features = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or_else(|| LibsvmError::Parse {
            line: lineno + 1,
            msg: "empty line".into(),
        })?;
        let label: f64 = label_tok.parse().map_err(|e| LibsvmError::Parse {
            line: lineno + 1,
            msg: format!("bad label '{label_tok}': {e}"),
        })?;
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut prev: i64 = -1;
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("expected idx:val, got '{tok}'"),
            })?;
            let idx1: u32 = idx_s.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad index '{idx_s}': {e}"),
            })?;
            if idx1 == 0 {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: "LibSVM indices are 1-based; got 0".into(),
                });
            }
            let idx = idx1 - 1;
            if (idx as i64) <= prev {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: format!("indices not strictly increasing at {idx1}"),
                });
            }
            prev = idx as i64;
            let val: f64 = val_s.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad value '{val_s}': {e}"),
            })?;
            n_features = n_features.max(idx as usize + 1);
            indices.push(idx);
            values.push(val);
        }
        rows.push(SparseRow {
            indices,
            values,
            label,
        });
    }
    Ok(SparseDataset { rows, n_features })
}

/// Serialize to LibSVM text (1-based indices; zero values skipped).
pub fn write(ds: &SparseDataset) -> String {
    let mut out = String::with_capacity(ds.nnz() * 12 + ds.len() * 4);
    for row in &ds.rows {
        if row.label == row.label.trunc() {
            out.push_str(&format!("{}", row.label as i64));
        } else {
            out.push_str(&format!("{}", row.label));
        }
        for (idx, val) in row.indices.iter().zip(row.values.iter()) {
            if *val == 0.0 {
                continue;
            }
            if *val == val.trunc() && val.abs() < 1e15 {
                out.push_str(&format!(" {}:{}", idx + 1, *val as i64));
            } else {
                out.push_str(&format!(" {}:{}", idx + 1, val));
            }
        }
        out.push('\n');
    }
    out
}

pub fn read_file(path: &str) -> Result<SparseDataset, LibsvmError> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

pub fn write_file(path: &str, ds: &SparseDataset) -> Result<(), LibsvmError> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, write(ds))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let ds = parse("+1 1:1 4:0.5\n-1 2:2\n").unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.n_features, 4);
        assert_eq!(ds.rows[0].indices, vec![0, 3]);
        assert_eq!(ds.rows[0].values, vec![1.0, 0.5]);
        assert_eq!(ds.rows[0].label, 1.0);
        assert_eq!(ds.rows[1].label, -1.0);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let ds = parse("# header\n\n+1 1:1\n").unwrap();
        assert_eq!(ds.len(), 1);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse("1 0:5\n").is_err());
    }

    #[test]
    fn rejects_decreasing_indices() {
        assert!(parse("1 3:1 2:1\n").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("1 a:b\n").is_err());
        assert!(parse("x 1:1\n").is_err());
        assert!(parse("1 11\n").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = "1 1:1 3:-2.5 10:0.125\n-1 2:4\n1 1:0.333\n";
        let ds = parse(src).unwrap();
        let text = write(&ds);
        let ds2 = parse(&text).unwrap();
        assert_eq!(ds.rows, ds2.rows);
        assert_eq!(ds.n_features, ds2.n_features);
    }

    #[test]
    fn file_roundtrip() {
        let ds = parse("1 1:1 2:2\n-1 3:3\n").unwrap();
        let path = std::env::temp_dir().join("shiftcomp_libsvm_test.txt");
        let path = path.to_str().unwrap();
        write_file(path, &ds).unwrap();
        let ds2 = read_file(path).unwrap();
        assert_eq!(ds.rows, ds2.rows);
        let _ = std::fs::remove_file(path);
    }
}
