//! Partitioning examples across workers.
//!
//! The paper: data is "uniformly, evenly, and randomly distributed among 10
//! workers". We shuffle indices with the experiment's seeded RNG and cut
//! into `n` near-equal contiguous chunks (sizes differ by at most one).

use crate::util::rng::Pcg64;

/// Return `n_workers` disjoint index sets covering `0..n_samples`,
/// random and even (|size difference| ≤ 1).
pub fn partition_evenly(n_samples: usize, n_workers: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(n_workers > 0, "need at least one worker");
    assert!(
        n_samples >= n_workers,
        "cannot give every worker data: {n_samples} samples, {n_workers} workers"
    );
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let base = n_samples / n_workers;
    let extra = n_samples % n_workers;
    let mut out = Vec::with_capacity(n_workers);
    let mut cursor = 0;
    for w in 0..n_workers {
        let size = base + usize::from(w < extra);
        out.push(idx[cursor..cursor + size].to_vec());
        cursor += size;
    }
    debug_assert_eq!(cursor, n_samples);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_indices_exactly_once() {
        let mut rng = Pcg64::new(1);
        let parts = partition_evenly(100, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let mut seen = vec![false; 100];
        for p in &parts {
            assert_eq!(p.len(), 10);
            for &i in p {
                assert!(!seen[i], "duplicate {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn uneven_split_differs_by_at_most_one() {
        let mut rng = Pcg64::new(2);
        let parts = partition_evenly(103, 10, &mut rng);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn randomized_not_contiguous() {
        let mut rng = Pcg64::new(3);
        let parts = partition_evenly(1000, 4, &mut rng);
        // The first chunk of a shuffled partition should not be 0..250.
        let sorted_first: Vec<usize> = {
            let mut p = parts[0].clone();
            p.sort_unstable();
            p
        };
        assert_ne!(sorted_first, (0..250).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        assert_eq!(partition_evenly(50, 5, &mut a), partition_evenly(50, 5, &mut b));
    }

    #[test]
    #[should_panic]
    fn too_few_samples_panics() {
        let mut rng = Pcg64::new(1);
        partition_evenly(3, 10, &mut rng);
    }
}
