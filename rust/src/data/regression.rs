//! Port of `sklearn.datasets.make_regression`.
//!
//! The paper's ridge experiment (Section 4): `make_regression` with default
//! parameters for `m = 100, d = 80`, data then "uniformly, evenly, and
//! randomly distributed among 10 workers".
//!
//! sklearn semantics reproduced here (defaults in parentheses):
//! * `X` is `m × d` i.i.d. standard normal;
//! * `n_informative` (10) coordinates of the ground truth are drawn as
//!   `100 * U[0, 1)`, the rest are zero;
//! * `y = X @ coef + bias (0) + noise (0) * N(0,1)`;
//! * columns and rows are shuffled (`shuffle=True`).
//!
//! RNG streams obviously differ from NumPy's MT19937, but every compared
//! algorithm consumes the *same* generated dataset, which is what the
//! paper's comparisons rely on.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct RegressionOpts {
    pub n_samples: usize,
    pub n_features: usize,
    pub n_informative: usize,
    pub bias: f64,
    pub noise: f64,
    pub shuffle: bool,
    pub seed: u64,
}

impl Default for RegressionOpts {
    fn default() -> Self {
        Self {
            n_samples: 100,
            n_features: 80,
            n_informative: 10,
            bias: 0.0,
            noise: 0.0,
            shuffle: true,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RegressionDataset {
    pub a: Mat,           // design matrix, m × d
    pub y: Vec<f64>,      // targets, m
    pub coef: Vec<f64>,   // ground-truth coefficients, d
}

/// Generate a regression problem following sklearn's `make_regression`.
pub fn make_regression(opts: &RegressionOpts) -> RegressionDataset {
    let RegressionOpts {
        n_samples: m,
        n_features: d,
        n_informative,
        bias,
        noise,
        shuffle,
        seed,
    } = *opts;
    let n_informative = n_informative.min(d);
    let mut rng = Pcg64::with_stream(seed, 0x8e6);

    let mut a = Mat::zeros(m, d);
    rng.fill_normal(&mut a.data);

    // Ground truth: informative prefix, then zeros.
    let mut coef = vec![0.0; d];
    for c in coef.iter_mut().take(n_informative) {
        *c = 100.0 * rng.f64();
    }

    let mut y = a.matvec(&coef);
    for v in y.iter_mut() {
        *v += bias;
        if noise > 0.0 {
            *v += rng.normal() * noise;
        }
    }

    if shuffle {
        // Shuffle rows (keeping X/y aligned) …
        let row_perm = rng.permutation(m);
        let mut a2 = Mat::zeros(m, d);
        let mut y2 = vec![0.0; m];
        for (new_i, &old_i) in row_perm.iter().enumerate() {
            a2.row_mut(new_i).copy_from_slice(a.row(old_i as usize));
            y2[new_i] = y[old_i as usize];
        }
        // … and features (keeping coef aligned).
        let col_perm = rng.permutation(d);
        let mut a3 = Mat::zeros(m, d);
        let mut coef2 = vec![0.0; d];
        for (new_j, &old_j) in col_perm.iter().enumerate() {
            for i in 0..m {
                a3.data[i * d + new_j] = a2.data[i * d + old_j as usize];
            }
            coef2[new_j] = coef[old_j as usize];
        }
        a = a3;
        y = y2;
        coef = coef2;
    }

    RegressionDataset { a, y, coef }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_opts() {
        let ds = make_regression(&RegressionOpts::default());
        assert_eq!(ds.a.rows, 100);
        assert_eq!(ds.a.cols, 80);
        assert_eq!(ds.y.len(), 100);
        assert_eq!(ds.coef.len(), 80);
    }

    #[test]
    fn noiseless_targets_are_exact() {
        let ds = make_regression(&RegressionOpts {
            noise: 0.0,
            ..Default::default()
        });
        let pred = ds.a.matvec(&ds.coef);
        for (p, t) in pred.iter().zip(ds.y.iter()) {
            assert!((p - t).abs() < 1e-9, "{p} vs {t}");
        }
    }

    #[test]
    fn informative_count_respected() {
        let ds = make_regression(&RegressionOpts {
            shuffle: false,
            ..Default::default()
        });
        let nonzero = ds.coef.iter().filter(|&&c| c != 0.0).count();
        assert_eq!(nonzero, 10);
        // informative coefficients live in [0, 100)
        for &c in ds.coef.iter().filter(|&&c| c != 0.0) {
            assert!((0.0..100.0).contains(&c));
        }
    }

    #[test]
    fn shuffle_preserves_model() {
        let ds = make_regression(&RegressionOpts {
            shuffle: true,
            seed: 5,
            ..Default::default()
        });
        let pred = ds.a.matvec(&ds.coef);
        for (p, t) in pred.iter().zip(ds.y.iter()) {
            assert!((p - t).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = make_regression(&RegressionOpts {
            seed: 9,
            ..Default::default()
        });
        let b = make_regression(&RegressionOpts {
            seed: 9,
            ..Default::default()
        });
        assert_eq!(a.a.data, b.a.data);
        assert_eq!(a.y, b.y);
        let c = make_regression(&RegressionOpts {
            seed: 10,
            ..Default::default()
        });
        assert_ne!(a.a.data, c.a.data);
    }

    #[test]
    fn noise_perturbs_targets() {
        let clean = make_regression(&RegressionOpts {
            seed: 1,
            noise: 0.0,
            shuffle: false,
            ..Default::default()
        });
        let noisy = make_regression(&RegressionOpts {
            seed: 1,
            noise: 1.0,
            shuffle: false,
            ..Default::default()
        });
        assert_eq!(clean.a.data, noisy.a.data);
        let diffs = clean
            .y
            .iter()
            .zip(noisy.y.iter())
            .filter(|(a, b)| (*a - *b).abs() > 1e-12)
            .count();
        assert!(diffs > 90);
    }

    #[test]
    fn entries_look_standard_normal() {
        let ds = make_regression(&RegressionOpts {
            n_samples: 200,
            n_features: 100,
            ..Default::default()
        });
        let n = ds.a.data.len() as f64;
        let mean: f64 = ds.a.data.iter().sum::<f64>() / n;
        let var: f64 = ds.a.data.iter().map(|v| v * v).sum::<f64>() / n - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
