//! Synthetic stand-in for the LibSVM `w2a` dataset.
//!
//! The paper's supplementary logistic-regression experiment (Figure 4) uses
//! `w2a` from the LibSVM repository. This environment has no network
//! access, so we generate a synthetic dataset that matches the properties
//! the experiment actually depends on (see DESIGN.md §Substitutions):
//!
//! * shape: 3,470 examples, 300 binary features (the real w2a is
//!   3,470 × 300 with {0,1} features);
//! * sparsity: ≈ 3.9 % density (avg ≈ 11.7 nnz/row);
//! * label imbalance: ≈ 3 % positives;
//! * labels correlated with features through a sparse ground-truth
//!   hyperplane + flip noise, so the logistic loss is non-degenerate and
//!   *not* interpolating — exactly the regime the shifted-compression
//!   framework targets (`∇f_i(x*) ≠ 0`).
//!
//! The generator emits through the LibSVM **writer** and experiments read it
//! back with the **parser**, exercising the identical path a downloaded
//! `w2a` file would take (running against a real `w2a` file also works:
//! pass `--data path/to/w2a` to the CLI).

use crate::data::libsvm;
use crate::data::sparse::{SparseDataset, SparseRow};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct W2aOpts {
    pub n_samples: usize,
    pub n_features: usize,
    pub avg_nnz_per_row: f64,
    /// Target fraction of +1 labels (before flips).
    pub positive_rate: f64,
    /// Probability of flipping each label (keeps the problem from being
    /// linearly separable / interpolating).
    pub label_flip: f64,
    pub seed: u64,
}

impl Default for W2aOpts {
    fn default() -> Self {
        Self {
            n_samples: 3470,
            n_features: 300,
            avg_nnz_per_row: 11.7,
            positive_rate: 0.03,
            label_flip: 0.02,
            seed: 0x77326_1, // "w2a" tag
        }
    }
}

/// Generate the synthetic w2a-like dataset directly (in memory).
pub fn synthetic_w2a(opts: &W2aOpts) -> SparseDataset {
    let W2aOpts {
        n_samples,
        n_features,
        avg_nnz_per_row,
        positive_rate,
        label_flip,
        seed,
    } = *opts;
    let mut rng = Pcg64::with_stream(seed, 0x773261);

    // Sparse ground-truth hyperplane over ~20% of features.
    let n_active = (n_features / 5).max(1);
    let active = rng.subset(n_features, n_active);
    let mut w_star = vec![0.0; n_features];
    for &j in &active {
        w_star[j as usize] = rng.normal() * 2.0;
    }

    // Per-feature inclusion probabilities follow a Zipf-ish profile like
    // real text-derived binary features (a few common, many rare), scaled so
    // the expected row nnz matches `avg_nnz_per_row`.
    let mut probs: Vec<f64> = (0..n_features)
        .map(|j| 1.0 / (1.0 + j as f64).powf(0.7))
        .collect();
    let sum: f64 = probs.iter().sum();
    let scale = avg_nnz_per_row / sum;
    for p in probs.iter_mut() {
        *p = (*p * scale).min(0.95);
    }
    // Shuffle so "common" features are not the low indices of the
    // hyperplane support.
    rng.shuffle(&mut probs);

    // Bias chosen so that P(+1) ≈ positive_rate under a logistic link:
    // sigma(bias + w·a). Calibrate empirically on a pilot sample.
    let mut bias = 0.0f64;
    for _ in 0..30 {
        let mut pos = 0usize;
        let pilot = 400;
        let mut prng = rng.stream(0xb1a5);
        for _ in 0..pilot {
            let mut score = bias;
            for (j, &p) in probs.iter().enumerate() {
                if prng.bernoulli(p) {
                    score += w_star[j];
                }
            }
            if prng.bernoulli(sigmoid(score)) {
                pos += 1;
            }
        }
        let rate = pos as f64 / pilot as f64;
        bias += (positive_rate.max(1e-4).ln() - rate.max(1e-4).ln()) * 0.5;
        if (rate - positive_rate).abs() < 0.005 {
            break;
        }
    }

    let mut rows = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let mut indices = Vec::new();
        let mut score = bias;
        for (j, &p) in probs.iter().enumerate() {
            if rng.bernoulli(p) {
                indices.push(j as u32);
                score += w_star[j];
            }
        }
        let mut label = if rng.bernoulli(sigmoid(score)) { 1.0 } else { -1.0 };
        if rng.bernoulli(label_flip) {
            label = -label;
        }
        let values = vec![1.0; indices.len()];
        rows.push(SparseRow {
            indices,
            values,
            label,
        });
    }
    SparseDataset {
        rows,
        n_features,
    }
}

#[inline]
fn sigmoid(t: f64) -> f64 {
    1.0 / (1.0 + (-t).exp())
}

/// Generate, write as LibSVM text to `path`, and read back through the
/// parser — the canonical way experiments obtain the dataset.
pub fn synthetic_w2a_via_file(
    opts: &W2aOpts,
    path: &str,
) -> Result<SparseDataset, libsvm::LibsvmError> {
    let ds = synthetic_w2a(opts);
    libsvm::write_file(path, &ds)?;
    libsvm::read_file(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_sparsity_match_profile() {
        let ds = synthetic_w2a(&W2aOpts::default());
        assert_eq!(ds.len(), 3470);
        assert_eq!(ds.n_features, 300);
        let avg_nnz = ds.nnz() as f64 / ds.len() as f64;
        assert!(
            (avg_nnz - 11.7).abs() < 2.0,
            "avg nnz/row {avg_nnz} should be ≈ 11.7"
        );
        let pos = ds.positive_fraction();
        assert!(pos > 0.005 && pos < 0.15, "positive rate {pos}");
    }

    #[test]
    fn features_are_binary() {
        let ds = synthetic_w2a(&W2aOpts {
            n_samples: 50,
            ..Default::default()
        });
        for row in &ds.rows {
            for &v in &row.values {
                assert_eq!(v, 1.0);
            }
            assert!(row.label == 1.0 || row.label == -1.0);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = synthetic_w2a(&W2aOpts {
            n_samples: 100,
            ..Default::default()
        });
        let b = synthetic_w2a(&W2aOpts {
            n_samples: 100,
            ..Default::default()
        });
        assert_eq!(a.rows, b.rows);
    }

    #[test]
    fn file_roundtrip_identical() {
        let opts = W2aOpts {
            n_samples: 60,
            ..Default::default()
        };
        let direct = synthetic_w2a(&opts);
        let path = std::env::temp_dir().join("shiftcomp_w2a_test.libsvm");
        let via_file = synthetic_w2a_via_file(&opts, path.to_str().unwrap()).unwrap();
        // Rows with no features survive the roundtrip (label-only lines).
        assert_eq!(direct.rows, via_file.rows);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn labels_are_learnable_not_random() {
        // A dataset whose labels correlate with features: the ground-truth
        // margin direction should classify better than chance.
        let ds = synthetic_w2a(&W2aOpts {
            n_samples: 800,
            positive_rate: 0.3,
            label_flip: 0.0,
            ..Default::default()
        });
        // crude check: positives should have systematically different mean
        // nnz-weighted score; verify via label/feature mutual correlation on
        // a handful of features
        let mut best_corr: f64 = 0.0;
        for j in 0..ds.n_features {
            let mut with = 0.0;
            let mut with_pos = 0.0;
            for row in &ds.rows {
                if row.indices.binary_search(&(j as u32)).is_ok() {
                    with += 1.0;
                    if row.label > 0.0 {
                        with_pos += 1.0;
                    }
                }
            }
            if with >= 30.0 {
                let base = ds.positive_fraction();
                best_corr = best_corr.max((with_pos / with - base).abs());
            }
        }
        assert!(best_corr > 0.05, "labels look uncorrelated: {best_corr}");
    }
}
