//! Sparse row storage for classification datasets (LibSVM-style).

/// One example: sorted feature indices + values, and a ±1 label.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseRow {
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
    pub label: f64, // ±1 for binary classification
}

impl SparseRow {
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Sparse dot with a dense vector.
    #[inline]
    pub fn dot(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for (idx, v) in self.indices.iter().zip(self.values.iter()) {
            s += x[*idx as usize] * v;
        }
        s
    }

    /// `out += a * row` scatter-add.
    #[inline]
    pub fn axpy_into(&self, a: f64, out: &mut [f64]) {
        for (idx, v) in self.indices.iter().zip(self.values.iter()) {
            out[*idx as usize] += a * v;
        }
    }

    /// Squared Euclidean norm of the feature vector.
    pub fn nrm2_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }
}

/// A sparse binary-classification dataset.
#[derive(Clone, Debug, Default)]
pub struct SparseDataset {
    pub rows: Vec<SparseRow>,
    pub n_features: usize,
}

impl SparseDataset {
    pub fn len(&self) -> usize {
        self.rows.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.nnz()).sum()
    }
    pub fn density(&self) -> f64 {
        if self.rows.is_empty() || self.n_features == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.len() * self.n_features) as f64
    }
    pub fn positive_fraction(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().filter(|r| r.label > 0.0).count() as f64 / self.len() as f64
    }
    /// Upper bound on per-example smoothness of the logistic loss:
    /// L_row = ‖a‖²/4 (curvature of log(1+exp(-t)) is ≤ 1/4).
    pub fn max_row_norm_sq(&self) -> f64 {
        self.rows.iter().map(|r| r.nrm2_sq()).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> SparseRow {
        SparseRow {
            indices: vec![0, 3, 7],
            values: vec![1.0, -2.0, 0.5],
            label: 1.0,
        }
    }

    #[test]
    fn sparse_dot() {
        let r = row();
        let x = vec![1.0; 8];
        assert_eq!(r.dot(&x), -0.5);
    }

    #[test]
    fn axpy_scatter() {
        let r = row();
        let mut out = vec![0.0; 8];
        r.axpy_into(2.0, &mut out);
        assert_eq!(out[0], 2.0);
        assert_eq!(out[3], -4.0);
        assert_eq!(out[7], 1.0);
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn dataset_stats() {
        let ds = SparseDataset {
            rows: vec![
                row(),
                SparseRow {
                    indices: vec![1],
                    values: vec![3.0],
                    label: -1.0,
                },
            ],
            n_features: 8,
        };
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.nnz(), 4);
        assert!((ds.density() - 4.0 / 16.0).abs() < 1e-12);
        assert_eq!(ds.positive_fraction(), 0.5);
        assert_eq!(ds.max_row_norm_sq(), 9.0);
    }
}
