//! Datasets: generators, parsers, and partitioning.
//!
//! * [`regression`] — a faithful port of
//!   `sklearn.datasets.make_regression` (the paper's ridge experiment uses
//!   it with `m=100, d=80` and default parameters).
//! * [`sparse`] — CSR-style sparse rows used by the LibSVM path.
//! * [`libsvm`] — LibSVM text format parser/writer.
//! * [`w2a`] — synthetic stand-in for the LibSVM `w2a` dataset (no network
//!   access in this environment); same shape/sparsity/imbalance profile,
//!   emitted through the LibSVM writer and read back through the parser so
//!   the full file path is exercised. See DESIGN.md §Substitutions.
//! * [`partition`] — uniform, even, random assignment of examples to the
//!   `n` workers, as in the paper's Section 4.

pub mod libsvm;
pub mod partition;
pub mod regression;
pub mod sparse;
pub mod w2a;

pub use partition::partition_evenly;
pub use regression::{make_regression, RegressionDataset, RegressionOpts};
pub use sparse::{SparseDataset, SparseRow};
pub use w2a::{synthetic_w2a, W2aOpts};
