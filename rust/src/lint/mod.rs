//! In-tree static lint (`shiftcomp-lint`): repo-specific invariants as code.
//!
//! The crate carries correctness obligations that `rustc` cannot see — the
//! `// SAFETY:` discipline around the fold pool's aliasing surface, the
//! panic-freedom contract of the master's round path (PR 5's `try_step`),
//! the wire-format frame table, the ROADMAP `cluster.*` documentation, and
//! the "no deadline-free blocking recv on the master" rule the
//! fault-tolerance layer depends on. This module enforces them textually,
//! with zero dependencies (same offline discipline as the rest of the
//! crate), so CI fails instead of a reviewer having to notice.
//!
//! ## Rules
//!
//! | rule id          | scope                                   | requirement |
//! |------------------|-----------------------------------------|-------------|
//! | `safety-comment` | all of `rust/src/**`                    | every `unsafe` token is adjacent to a `// SAFETY:` (or `/// # Safety`) comment |
//! | `no-panic`       | `coordinator/`, `wire.rs`, `net/`, `downlink.rs`, `ef.rs` | no `.unwrap()`, `.expect(`, or `panic!` outside `#[cfg(test)]` |
//! | `wire-tags`      | `wire.rs`                               | frame tag bytes (`TAG_*`, `DOWN_*`) unique per namespace and each listed in the module-doc frame table |
//! | `cluster-keys`   | `config/mod.rs`                         | every key `ClusterSpec::parse` reads appears in ROADMAP's cluster table |
//! | `blocking-recv`  | `coordinator/`                          | no deadline-free `.recv()` (use `recv_timeout`/`try_recv`; `try_send` on the send side) |
//!
//! ## Escape hatch
//!
//! A violation is suppressed by a `// LINT-ALLOW(rule): reason` comment on
//! the same line or on the contiguous comment block directly above it. The
//! reason is mandatory — an allow without one is itself a violation, so
//! every exemption in the tree is forced to say *why* it is sound.
//!
//! The scanner is a line-oriented token classifier (string/char literals
//! and comments are masked out before pattern matching), not a parser; it
//! is deliberately conservative, and `LINT-ALLOW` exists precisely so a
//! human can overrule it with a recorded justification.

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding, pointing at a repo-relative file and 1-based line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Result of a whole-tree run: findings plus how many files were scanned.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
}

// ---------------------------------------------------------------------------
// Byte classification: code vs comment vs string/char literal
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum Kind {
    Code,
    Comment,
    Str,
}

/// Classify every byte of `src` as code, comment, or string/char literal.
///
/// Newlines are always classified as code so line splitting stays trivial.
/// Handles line comments, nested block comments, string escapes, raw
/// strings (`r"…"`, `r#"…"#`, byte variants), and the `'x'` char-literal
/// vs `'lifetime` ambiguity via one-char lookahead.
fn classify(src: &str) -> Vec<Kind> {
    let b = src.as_bytes();
    let n = b.len();
    let mut kinds = vec![Kind::Code; n];
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            // Line comment (also `///` and `//!`): to end of line.
            while i < n && b[i] != b'\n' {
                kinds[i] = Kind::Comment;
                i += 1;
            }
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            // Block comment; Rust block comments nest.
            let mut depth = 0usize;
            while i < n {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    kinds[i] = Kind::Comment;
                    kinds[i + 1] = Kind::Comment;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    kinds[i] = Kind::Comment;
                    kinds[i + 1] = Kind::Comment;
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if b[i] != b'\n' {
                        kinds[i] = Kind::Comment;
                    }
                    i += 1;
                }
            }
        } else if c == b'"' {
            // String literal; check for a raw-string prefix `(b?)r#*` just
            // before the quote (the byte before the prefix must not be an
            // identifier byte, so `var_r"` can't false-positive).
            let mut hashes = 0usize;
            let mut j = i;
            while j > 0 && b[j - 1] == b'#' {
                hashes += 1;
                j -= 1;
            }
            let raw = j > 0
                && b[j - 1] == b'r'
                && (j < 2 || !is_ident_byte(b[j - 2]) || b[j - 2] == b'b');
            // Mark the prefix bytes as part of the literal too.
            if raw {
                let start = if j >= 2 && b[j - 2] == b'b' { j - 2 } else { j - 1 };
                for k in start..i {
                    kinds[k] = Kind::Str;
                }
            }
            kinds[i] = Kind::Str;
            i += 1;
            if raw {
                // Ends at `"` followed by `hashes` hash marks.
                'raw: while i < n {
                    if b[i] == b'"' {
                        let mut ok = true;
                        for k in 0..hashes {
                            if i + 1 + k >= n || b[i + 1 + k] != b'#' {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            for k in 0..=hashes {
                                kinds[i + k] = Kind::Str;
                            }
                            i += hashes + 1;
                            break 'raw;
                        }
                    }
                    if b[i] != b'\n' {
                        kinds[i] = Kind::Str;
                    }
                    i += 1;
                }
            } else {
                while i < n {
                    if b[i] == b'\\' && i + 1 < n {
                        kinds[i] = Kind::Str;
                        if b[i + 1] != b'\n' {
                            kinds[i + 1] = Kind::Str;
                        }
                        i += 2;
                        continue;
                    }
                    if b[i] != b'\n' {
                        kinds[i] = Kind::Str;
                    }
                    if b[i] == b'"' {
                        i += 1;
                        break;
                    }
                    i += 1;
                }
            }
        } else if c == b'\'' {
            // Char literal vs lifetime. `'\…'` is always a char literal;
            // `'X'` (one UTF-8 char then a quote) is a char literal;
            // anything else (`'a>`, `'static`) is a lifetime → code.
            let is_escape = i + 1 < n && b[i + 1] == b'\\';
            let mut char_len = 0usize;
            if !is_escape && i + 1 < n {
                let rest = &src[i + 1..];
                if let Some(ch) = rest.chars().next() {
                    char_len = ch.len_utf8();
                }
            }
            let is_char = is_escape
                || (char_len > 0 && i + 1 + char_len < n && b[i + 1 + char_len] == b'\'');
            if is_char {
                kinds[i] = Kind::Str;
                i += 1;
                let mut prev_backslash = false;
                while i < n {
                    if b[i] != b'\n' {
                        kinds[i] = Kind::Str;
                    }
                    if b[i] == b'\'' && !prev_backslash {
                        i += 1;
                        break;
                    }
                    prev_backslash = b[i] == b'\\' && !prev_backslash;
                    i += 1;
                }
            } else {
                i += 1; // lifetime quote stays code
            }
        } else {
            i += 1;
        }
    }
    kinds
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

// ---------------------------------------------------------------------------
// Per-file scan structure
// ---------------------------------------------------------------------------

/// A scanned file: per-line code text (non-code bytes blanked to spaces)
/// and comment text, plus which lines sit inside `#[cfg(test)]` items.
struct Scan {
    /// Per line: source bytes with comment/string bytes replaced by spaces.
    code: Vec<String>,
    /// Per line: the comment bytes of the line (code/string blanked).
    comment: Vec<String>,
    /// Per line: true if the line is inside a `#[cfg(test)]` item.
    in_test: Vec<bool>,
}

impl Scan {
    fn new(src: &str) -> Scan {
        let kinds = classify(src);
        let bytes = src.as_bytes();
        let mut code_lines = Vec::new();
        let mut comment_lines = Vec::new();
        let mut code_buf = Vec::new();
        let mut comment_buf = Vec::new();
        for (i, &c) in bytes.iter().enumerate() {
            if c == b'\n' {
                code_lines.push(String::from_utf8_lossy(&code_buf).into_owned());
                comment_lines.push(String::from_utf8_lossy(&comment_buf).into_owned());
                code_buf.clear();
                comment_buf.clear();
                continue;
            }
            match kinds[i] {
                Kind::Code => {
                    code_buf.push(c);
                    comment_buf.push(b' ');
                }
                Kind::Comment => {
                    code_buf.push(b' ');
                    comment_buf.push(c);
                }
                Kind::Str => {
                    // Keep the quotes themselves as structure-free spaces;
                    // string contents never participate in rules.
                    code_buf.push(b' ');
                    comment_buf.push(b' ');
                }
            }
        }
        if !code_buf.is_empty() || !comment_buf.is_empty() {
            code_lines.push(String::from_utf8_lossy(&code_buf).into_owned());
            comment_lines.push(String::from_utf8_lossy(&comment_buf).into_owned());
        }
        let in_test = mark_test_lines(&code_lines);
        Scan {
            code: code_lines,
            comment: comment_lines,
            in_test,
        }
    }

    /// True if the violation at `line` (0-based) carries a reasoned
    /// `LINT-ALLOW(rule): …` on the same line or the contiguous comment
    /// block directly above.
    fn allowed(&self, rule: &str, line: usize) -> Option<bool> {
        let needle = format!("LINT-ALLOW({rule})");
        let check = |text: &str| -> Option<bool> {
            let at = text.find(&needle)?;
            let rest = &text[at + needle.len()..];
            // Reason is mandatory: `LINT-ALLOW(rule): non-empty reason`.
            let ok = rest
                .strip_prefix(':')
                .is_some_and(|r| !r.trim().is_empty());
            Some(ok)
        };
        if let Some(v) = check(&self.comment[line]) {
            return Some(v);
        }
        let mut j = line;
        while j > 0 {
            j -= 1;
            match self.adjacent_kind(j) {
                Adjacent::Comment => {
                    if let Some(v) = check(&self.comment[j]) {
                        return Some(v);
                    }
                }
                Adjacent::Attribute => {}
                Adjacent::Other => break,
            }
        }
        None
    }

    /// How line `j` participates in an upward adjacency scan: a comment
    /// line is checked, an attribute line (`#[...]`) is skipped over (doc
    /// comments legitimately sit above attributes), anything else ends the
    /// scan.
    fn adjacent_kind(&self, j: usize) -> Adjacent {
        let code = self.code[j].trim();
        if code.is_empty() {
            if self.comment[j].trim().is_empty() {
                Adjacent::Other // blank line breaks adjacency
            } else {
                Adjacent::Comment
            }
        } else if code.starts_with("#[") && code.ends_with(']') {
            Adjacent::Attribute
        } else {
            Adjacent::Other
        }
    }

    /// True if the `unsafe` at `line` is covered by an adjacent
    /// `SAFETY:` comment (same line, or the contiguous comment block
    /// directly above — doc-comment `# Safety` sections count).
    fn has_safety_comment(&self, line: usize) -> bool {
        let hit = |text: &str| text.contains("SAFETY:") || text.contains("# Safety");
        if hit(&self.comment[line]) {
            return true;
        }
        let mut j = line;
        while j > 0 {
            j -= 1;
            match self.adjacent_kind(j) {
                Adjacent::Comment => {
                    if hit(&self.comment[j]) {
                        return true;
                    }
                }
                Adjacent::Attribute => {}
                Adjacent::Other => return false,
            }
        }
        false
    }
}

/// Classification of a line during an upward adjacency scan.
enum Adjacent {
    Comment,
    Attribute,
    Other,
}

/// Mark the lines belonging to `#[cfg(test)]` items (attribute through the
/// end of the following brace-balanced item, or through the next `;` for
/// brace-less items).
fn mark_test_lines(code_lines: &[String]) -> Vec<bool> {
    let mut marked = vec![false; code_lines.len()];
    let mut i = 0usize;
    while i < code_lines.len() {
        if !code_lines[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // From the end of the attribute, find the first `{` or `;`; on
        // `{`, brace-count to the matching `}`.
        let attr_end = code_lines[i].find("#[cfg(test)]").map(|p| p + 12).unwrap_or(0);
        let mut depth = 0i64;
        let mut opened = false;
        let mut line = i;
        let mut col = attr_end;
        'outer: while line < code_lines.len() {
            let chars: Vec<char> = code_lines[line].chars().collect();
            while col < chars.len() {
                match chars[col] {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'outer;
                        }
                    }
                    ';' if !opened => break 'outer,
                    _ => {}
                }
                col += 1;
            }
            marked[line] = true;
            line += 1;
            col = 0;
        }
        if line < code_lines.len() {
            marked[line] = true;
        }
        i = line + 1;
    }
    marked
}

// ---------------------------------------------------------------------------
// Path-scoped rules: safety-comment, no-panic, blocking-recv
// ---------------------------------------------------------------------------

fn path_in_no_panic_scope(file: &str) -> bool {
    file.contains("coordinator/")
        || file.contains("net/")
        || file.ends_with("wire.rs")
        || file.ends_with("downlink.rs")
        || file.ends_with("ef.rs")
}

fn path_in_recv_scope(file: &str) -> bool {
    file.contains("coordinator/")
}

/// Run the path-scoped textual rules over one file's source.
///
/// `file` is a repo-relative path with `/` separators; it selects which
/// rules apply (`safety-comment` is crate-wide, `no-panic` and
/// `blocking-recv` are scoped — see the module docs).
pub fn lint_source(file: &str, content: &str) -> Vec<Violation> {
    let scan = Scan::new(content);
    let mut out = Vec::new();
    let mut push = |rule: &'static str, line: usize, message: String| {
        match scan.allowed(rule, line) {
            Some(true) => {}
            Some(false) => out.push(Violation {
                file: file.to_string(),
                line: line + 1,
                rule,
                message: format!("LINT-ALLOW({rule}) without a reason (use `: why`)"),
            }),
            None => out.push(Violation {
                file: file.to_string(),
                line: line + 1,
                rule,
                message,
            }),
        }
    };

    let no_panic = path_in_no_panic_scope(file);
    let recv_scope = path_in_recv_scope(file);

    for (i, code) in scan.code.iter().enumerate() {
        // safety-comment: crate-wide, including test code (an aliasing
        // argument is just as load-bearing inside a test). One finding per
        // line is enough.
        if !find_word(code, "unsafe").is_empty() && !scan.has_safety_comment(i) {
            push(
                "safety-comment",
                i,
                "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
            );
        }

        if scan.in_test[i] {
            continue;
        }

        if no_panic {
            if code.contains(".unwrap()") {
                push(
                    "no-panic",
                    i,
                    "`.unwrap()` in production path (return an error or LINT-ALLOW)"
                        .to_string(),
                );
            }
            if code.contains(".expect(") {
                push(
                    "no-panic",
                    i,
                    "`.expect(` in production path (return an error or LINT-ALLOW)"
                        .to_string(),
                );
            }
            for at in code.match_indices("panic!").map(|(p, _)| p) {
                let before_ok =
                    at == 0 || !is_ident_byte(code.as_bytes()[at - 1]);
                if before_ok {
                    push(
                        "no-panic",
                        i,
                        "`panic!` in production path (return an error or LINT-ALLOW)"
                            .to_string(),
                    );
                    break;
                }
            }
        }

        if recv_scope && code.contains(".recv()") {
            push(
                "blocking-recv",
                i,
                "deadline-free blocking `.recv()` (use `recv_timeout` or LINT-ALLOW)"
                    .to_string(),
            );
        }
    }
    out
}

/// Find occurrences of `word` in `hay` with identifier boundaries.
fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = hay.as_bytes();
    for (at, _) in hay.match_indices(word) {
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after = at + word.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// wire-tags rule
// ---------------------------------------------------------------------------

/// Check `wire.rs`: frame tag constants (`TAG_*: u8`, `DOWN_*: u8`) must be
/// unique within their namespace and each value must appear in the
/// module-doc frame table as `tag N` (uplink) / `kind N` (downlink).
pub fn check_wire_tags(file: &str, content: &str) -> Vec<Violation> {
    let scan = Scan::new(content);
    let mut out = Vec::new();
    let mut tags: Vec<(String, u64, usize)> = Vec::new(); // (name, value, line)
    let mut downs: Vec<(String, u64, usize)> = Vec::new();
    for (i, code) in scan.code.iter().enumerate() {
        if let Some((name, value)) = parse_u8_const(code) {
            if name.starts_with("TAG_") {
                tags.push((name, value, i));
            } else if name.starts_with("DOWN_") {
                downs.push((name, value, i));
            }
        }
    }
    // Module-doc text: every `//!` comment line joined.
    let doc: String = scan
        .comment
        .iter()
        .filter(|c| c.trim_start().starts_with("//!"))
        .map(|c| c.as_str())
        .collect::<Vec<_>>()
        .join("\n");

    for (word, list) in [("tag", &tags), ("kind", &downs)] {
        for (idx, (name, value, line)) in list.iter().enumerate() {
            for (prev_name, prev_value, _) in &list[..idx] {
                if prev_value == value {
                    out.push(Violation {
                        file: file.to_string(),
                        line: line + 1,
                        rule: "wire-tags",
                        message: format!(
                            "{name} reuses frame byte {value} already taken by {prev_name}"
                        ),
                    });
                }
            }
            if !doc_mentions(&doc, word, *value) {
                out.push(Violation {
                    file: file.to_string(),
                    line: line + 1,
                    rule: "wire-tags",
                    message: format!(
                        "{name} = {value} missing from the module-doc frame table \
                         (expected `{word} {value}` in a `//!` row)"
                    ),
                });
            }
        }
    }
    out
}

/// Parse `pub const NAME: u8 = N;` from a code line.
fn parse_u8_const(code: &str) -> Option<(String, u64)> {
    let t = code.trim();
    let t = t.strip_prefix("pub ").unwrap_or(t);
    let rest = t.strip_prefix("const ")?;
    let colon = rest.find(':')?;
    let name = rest[..colon].trim().to_string();
    let after = rest[colon + 1..].trim();
    let after = after.strip_prefix("u8")?.trim_start();
    let after = after.strip_prefix('=')?.trim_start();
    let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() {
        return None;
    }
    Some((name, digits.parse().ok()?))
}

/// `doc` mentions `word N` with the number not running into more digits.
fn doc_mentions(doc: &str, word: &str, value: u64) -> bool {
    let needle = format!("{word} {value}");
    for (at, _) in doc.match_indices(&needle) {
        let after = at + needle.len();
        let bytes = doc.as_bytes();
        if after >= bytes.len() || !bytes[after].is_ascii_digit() {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// cluster-keys rule
// ---------------------------------------------------------------------------

/// Check `config/mod.rs`: every `cluster.*` key read inside
/// `ClusterSpec::parse` (via `.get("key")`) must appear backticked in the
/// ROADMAP cluster table (`roadmap` is the full ROADMAP.md text).
pub fn check_cluster_keys(file: &str, content: &str, roadmap: &str) -> Vec<Violation> {
    let scan = Scan::new(content);
    let mut out = Vec::new();
    let Some((start_line, end_line)) = cluster_parse_body(&scan) else {
        return out; // no ClusterSpec::parse in this file — nothing to check
    };
    let raw_lines: Vec<&str> = content.lines().collect();
    for (i, raw) in raw_lines
        .iter()
        .enumerate()
        .take(end_line + 1)
        .skip(start_line)
    {
        // Only look where the *code* has a `.get(` call; the key itself
        // lives in the raw text (string literals are masked in code text).
        if !scan.code[i].contains(".get(") {
            continue;
        }
        let mut rest = *raw;
        while let Some(p) = rest.find(".get(\"") {
            let key_start = p + 6;
            let Some(len) = rest[key_start..].find('"') else { break };
            let key = &rest[key_start..key_start + len];
            if !roadmap.contains(&format!("`{key}`")) {
                out.push(Violation {
                    file: file.to_string(),
                    line: i + 1,
                    rule: "cluster-keys",
                    message: format!(
                        "cluster key \"{key}\" is parsed here but missing from \
                         ROADMAP.md's cluster table"
                    ),
                });
            }
            rest = &rest[key_start + len..];
        }
    }
    out
}

/// Locate the line range (0-based, inclusive) of the `fn parse` body inside
/// `impl ClusterSpec`.
fn cluster_parse_body(scan: &Scan) -> Option<(usize, usize)> {
    let mut impl_line = None;
    for (i, code) in scan.code.iter().enumerate() {
        if code.contains("impl ClusterSpec") {
            impl_line = Some(i);
            break;
        }
    }
    let impl_line = impl_line?;
    let mut fn_line = None;
    for (i, code) in scan.code.iter().enumerate().skip(impl_line) {
        if code.contains("fn parse(") {
            fn_line = Some(i);
            break;
        }
    }
    let fn_line = fn_line?;
    // Brace-count from the function signature to the end of its body.
    let mut depth = 0i64;
    let mut opened = false;
    for (i, code) in scan.code.iter().enumerate().skip(fn_line) {
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some((fn_line, i));
                    }
                }
                _ => {}
            }
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Whole-tree driver
// ---------------------------------------------------------------------------

/// Lint the repository rooted at `repo_root` (the directory containing
/// `rust/` and `ROADMAP.md`). Walks `rust/src/**`, applies every rule, and
/// returns all findings sorted by file/line.
pub fn run_repo(repo_root: &Path) -> Result<Report, String> {
    let src_root = repo_root.join("rust").join("src");
    if !src_root.is_dir() {
        return Err(format!("{} is not a directory", src_root.display()));
    }
    let roadmap = std::fs::read_to_string(repo_root.join("ROADMAP.md"))
        .map_err(|e| format!("read ROADMAP.md: {e}"))?;
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    files.sort();

    let mut report = Report::default();
    for path in &files {
        let content = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(repo_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        report.files_scanned += 1;
        report.violations.extend(lint_source(&rel, &content));
        if rel.ends_with("src/wire.rs") {
            report.violations.extend(check_wire_tags(&rel, &content));
        }
        if rel.ends_with("config/mod.rs") {
            report
                .violations
                .extend(check_cluster_keys(&rel, &content, &roadmap));
        }
    }
    report
        .violations
        .sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_of(src: &str) -> String {
        classify(src)
            .iter()
            .map(|k| match k {
                Kind::Code => 'c',
                Kind::Comment => '/',
                Kind::Str => 's',
            })
            .collect()
    }

    #[test]
    fn classifier_masks_comments_and_strings() {
        assert_eq!(kinds_of("a // b"), "cc////");
        assert_eq!(kinds_of("\"x\" y"), "ssscc");
        assert_eq!(kinds_of("/*a*/b"), "/////c");
        // Nested block comment.
        assert_eq!(kinds_of("/*/*x*/*/y"), "/////////c");
    }

    #[test]
    fn classifier_handles_char_literals_and_lifetimes() {
        // Char literal masked; lifetime kept as code.
        assert_eq!(kinds_of("'a' x"), "ssscc");
        assert_eq!(kinds_of("&'a str"), "ccccccc");
        assert_eq!(kinds_of(r"'\n' x"), "sssscc");
    }

    #[test]
    fn classifier_handles_raw_strings() {
        let src = "r#\"// not a comment\"# x";
        let k = kinds_of(src);
        assert!(k.starts_with("sss"));
        assert!(k.ends_with("cc"));
        assert!(!lint_source("coordinator/f.rs", "let s = r#\".unwrap()\"#;")
            .iter()
            .any(|v| v.rule == "no-panic"));
    }

    #[test]
    fn cfg_test_blocks_are_excluded() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g() { x.unwrap(); }\n}\n";
        assert!(lint_source("coordinator/f.rs", src).is_empty());
    }

    #[test]
    fn allow_requires_reason() {
        let with_reason = "// LINT-ALLOW(no-panic): construction-time only\nx.unwrap();\n";
        assert!(lint_source("coordinator/f.rs", with_reason).is_empty());
        let without = "// LINT-ALLOW(no-panic)\nx.unwrap();\n";
        let v = lint_source("coordinator/f.rs", without);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("without a reason"));
    }

    #[test]
    fn expect_err_is_not_flagged() {
        assert!(lint_source("coordinator/f.rs", "let e = r.expect_err(\"msg\");")
            .iter()
            .all(|v| v.rule != "no-panic"));
    }

    #[test]
    fn recv_timeout_is_not_flagged() {
        let src = "let r = rx.recv_timeout(deadline);\nlet t = rx.try_recv();\n";
        assert!(lint_source("coordinator/f.rs", src).is_empty());
    }
}
