//! Dense linear algebra substrate.
//!
//! Everything the optimization stack needs, built from scratch for the
//! offline environment: vector kernels, a row-major dense matrix with
//! matvec/gemm, Cholesky solves (used for the closed-form ridge optimum),
//! and spectral estimation (power iteration and Rayleigh bounds) used to
//! derive the smoothness constants `L_i`, `L` and strong-convexity `μ` that
//! the paper's step-size rules (Theorems 1–6) consume.

pub mod matrix;
pub mod solve;
pub mod spectral;
pub mod vector;

pub use matrix::Mat;
pub use solve::{cholesky_solve, Cholesky};
pub use spectral::{lambda_max, lambda_min_psd, SpectralOpts};
pub use vector::*;
