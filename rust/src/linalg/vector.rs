//! Dense vector kernels on `&[f64]` / `&mut [f64]`.
//!
//! These are the innermost loops of the whole stack — every compressor,
//! every algorithm step, and the coordinator's aggregation path run through
//! them — so they are written to autovectorize. The fold kernels ([`axpy`],
//! [`ax_into`], [`scatter_axpy`]) process fixed-width chunks via
//! `chunks_exact`, which hands the vectorizer a bounds-check-free inner loop
//! of known trip count; the remainder runs the same scalar expression.
//! Chunking never reorders or reassociates the per-element arithmetic, so
//! results stay bit-identical to the plain loop (each `y[i]` sees exactly
//! one `+= a * x[i]`).

/// Chunk width for the vectorizable kernels: 8 doubles = one cache line,
/// and a multiple of every SIMD width in practice (2/4/8 lanes).
const LANES: usize = 8;

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for (yv, xv) in ys.iter_mut().zip(xs.iter()) {
            *yv += a * xv;
        }
    }
    for (xv, yv) in xc.remainder().iter().zip(yc.into_remainder().iter_mut()) {
        *yv += a * xv;
    }
}

/// `y = a * x + b * y` (general scaled update).
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = a * x[i] + b * y[i];
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Squared Euclidean norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    nrm2_sq(x).sqrt()
}

/// ‖x − y‖².
#[inline]
pub fn dist_sq(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

/// `x *= a` in place.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// `y[indices[j]] += a * values[j]` — the sparse aggregation kernel behind
/// [`crate::compressors::Packet::add_scaled_into`]: consuming a K-sparse
/// message costs O(K) instead of the O(d) of a dense decode + [`axpy`].
/// Indices must be in-bounds for `y` (compressor packets guarantee this).
/// The scatter writes cannot vectorize (indices are data-dependent), but a
/// 4-wide unrolled body amortizes loop overhead; the sequential `+=` order
/// is preserved, so duplicate indices (and bit-identity) are handled
/// exactly as in the plain loop.
#[inline]
pub fn scatter_axpy(a: f64, indices: &[u32], values: &[f64], y: &mut [f64]) {
    assert_eq!(indices.len(), values.len());
    let mut ic = indices.chunks_exact(4);
    let mut vc = values.chunks_exact(4);
    for (i4, v4) in (&mut ic).zip(&mut vc) {
        y[i4[0] as usize] += a * v4[0];
        y[i4[1] as usize] += a * v4[1];
        y[i4[2] as usize] += a * v4[2];
        y[i4[3] as usize] += a * v4[3];
    }
    for (&i, &v) in ic.remainder().iter().zip(vc.remainder().iter()) {
        y[i as usize] += a * v;
    }
}

/// `out = a * x` (elementwise), overwriting `out`. Used by the round
/// pipeline to seed the gradient estimator from the aggregate shift in one
/// pass instead of `zero` + `axpy`.
#[inline]
pub fn ax_into(a: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for (xs, os) in (&mut xc).zip(&mut oc) {
        for (ov, xv) in os.iter_mut().zip(xs.iter()) {
            *ov = a * xv;
        }
    }
    for (xv, ov) in xc.remainder().iter().zip(oc.into_remainder().iter_mut()) {
        *ov = a * xv;
    }
}

/// `out = x - y` into a preallocated buffer.
#[inline]
pub fn sub_into(x: &[f64], y: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// `out = x + y` into a preallocated buffer.
#[inline]
pub fn add_into(x: &[f64], y: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] + y[i];
    }
}

/// Copy `src` into `dst`.
#[inline]
pub fn copy(src: &[f64], dst: &mut [f64]) {
    dst.copy_from_slice(src);
}

/// Set all entries to zero.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// ℓ∞ norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// ℓ1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓp norm for p ≥ 1 (used by Natural Dithering's p-norm variant).
#[inline]
pub fn nrmp(x: &[f64], p: f64) -> f64 {
    debug_assert!(p >= 1.0);
    if p == 1.0 {
        return nrm1(x);
    }
    if p == 2.0 {
        return nrm2(x);
    }
    if p.is_infinite() {
        return nrm_inf(x);
    }
    x.iter().map(|v| v.abs().powf(p)).sum::<f64>().powf(1.0 / p)
}

/// Mean of n vectors accumulated into `out` (used by the master aggregate).
pub fn mean_into(vectors: &[&[f64]], out: &mut [f64]) {
    assert!(!vectors.is_empty());
    zero(out);
    for v in vectors {
        axpy(1.0, v, out);
    }
    scale(1.0 / vectors.len() as f64, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_manual() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_matches_manual() {
        let x = [1.0, 2.0];
        let mut y = [3.0, 4.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, [3.5, 6.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert_eq!(nrm2(&x), 5.0);
        assert_eq!(nrm2_sq(&x), 25.0);
        assert_eq!(nrm1(&x), 7.0);
        assert_eq!(nrm_inf(&x), 4.0);
        assert!((nrmp(&x, 2.0) - 5.0).abs() < 1e-12);
        assert!((nrmp(&x, 3.0) - (27.0f64 + 64.0).powf(1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(nrmp(&x, f64::INFINITY), 4.0);
    }

    #[test]
    fn dist_and_dot() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 2.0, 1.0];
        assert_eq!(dot(&x, &y), 9.0);
        assert_eq!(dist_sq(&x, &y), 1.0 + 0.0 + 4.0);
    }

    #[test]
    fn mean_of_vectors() {
        let a = [1.0, 2.0];
        let b = [3.0, 6.0];
        let mut out = [0.0, 0.0];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn scatter_axpy_touches_only_listed_indices() {
        let mut y = [1.0, 2.0, 3.0, 4.0, 5.0];
        scatter_axpy(2.0, &[1, 4], &[10.0, -1.0], &mut y);
        assert_eq!(y, [1.0, 22.0, 3.0, 4.0, 3.0]);
        // empty index set is a no-op
        scatter_axpy(3.0, &[], &[], &mut y);
        assert_eq!(y, [1.0, 22.0, 3.0, 4.0, 3.0]);
    }

    #[test]
    fn ax_into_overwrites() {
        let x = [1.0, -2.0, 0.5];
        let mut out = [9.0, 9.0, 9.0];
        ax_into(0.5, &x, &mut out);
        assert_eq!(out, [0.5, -1.0, 0.25]);
    }

    #[test]
    fn chunked_kernels_match_plain_loops_at_awkward_lengths() {
        // Lengths straddling the chunk width (8 for axpy/ax_into, 4 for
        // scatter_axpy) including the empty and remainder-only cases: the
        // chunked kernels must be bit-identical to the naive loop.
        for d in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 33] {
            let x: Vec<f64> = (0..d).map(|i| (i as f64).sin() * 3.0).collect();
            let y0: Vec<f64> = (0..d).map(|i| (i as f64).cos() - 0.5).collect();
            let a = -1.37;

            let mut want = y0.clone();
            for (w, xv) in want.iter_mut().zip(x.iter()) {
                *w += a * xv;
            }
            let mut got = y0.clone();
            axpy(a, &x, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy d={d}"
            );

            let mut want = vec![0.0; d];
            for (w, xv) in want.iter_mut().zip(x.iter()) {
                *w = a * xv;
            }
            let mut got = y0.clone();
            ax_into(a, &x, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "ax_into d={d}"
            );

            // sparse scatter over every 2nd coordinate (odd nnz counts too)
            let idx: Vec<u32> = (0..d as u32).step_by(2).collect();
            let vals: Vec<f64> = idx.iter().map(|&i| x[i as usize] * 0.7).collect();
            let mut want = y0.clone();
            for (&i, &v) in idx.iter().zip(vals.iter()) {
                want[i as usize] += a * v;
            }
            let mut got = y0.clone();
            scatter_axpy(a, &idx, &vals, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "scatter_axpy d={d}"
            );
        }
    }

    #[test]
    fn sub_add_roundtrip() {
        let x = [5.0, 7.0];
        let y = [2.0, 3.0];
        let mut d = [0.0; 2];
        let mut s = [0.0; 2];
        sub_into(&x, &y, &mut d);
        add_into(&d, &y, &mut s);
        assert_eq!(s, x);
    }
}
