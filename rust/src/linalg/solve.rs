//! Cholesky factorization and SPD solves.
//!
//! Used to compute the *exact* ridge-regression optimum
//! `x* = (AᵀA/m + λI)⁻¹ Aᵀy/m` that the paper's error curves
//! `log(‖x^k − x*‖²/‖x⁰ − x*‖²)` are measured against.

use crate::linalg::matrix::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix: `A = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Mat,
}

#[derive(Debug)]
pub enum SolveError {
    NotPositiveDefinite { index: usize, pivot: f64 },
    Dim(String),
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::NotPositiveDefinite { index, pivot } => write!(
                f,
                "matrix is not positive definite (pivot {pivot} at index {index})"
            ),
            SolveError::Dim(msg) => write!(f, "dimension mismatch: {msg}"),
        }
    }
}

impl std::error::Error for SolveError {}

impl Cholesky {
    /// Factor an SPD matrix. O(n³/3).
    pub fn factor(a: &Mat) -> Result<Self, SolveError> {
        if a.rows != a.cols {
            return Err(SolveError::Dim(format!("{}x{} not square", a.rows, a.cols)));
        }
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(SolveError::NotPositiveDefinite { index: i, pivot: s });
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Ok(Self { l })
    }

    /// Solve `A x = b` given the factorization.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.get(i, k) * y[k];
            }
            y[i] = s / self.l.get(i, i);
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l.get(k, i) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
        x
    }

    /// log-determinant of A (= 2 Σ log L_ii); handy for tests.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// One-shot SPD solve.
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>, SolveError> {
    Ok(Cholesky::factor(a)?.solve(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_identity() {
        let a = Mat::eye(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(cholesky_solve(&a, &b).unwrap(), b);
    }

    #[test]
    fn solves_known_spd() {
        // A = [[4,2],[2,3]], b = [2, 5] -> x = [-0.5, 2]
        let a = Mat::from_rows(vec![vec![4.0, 2.0], vec![2.0, 3.0]]);
        let x = cholesky_solve(&a, &[2.0, 5.0]).unwrap();
        assert!((x[0] + 0.5).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn random_spd_residual_small() {
        let mut g = Pcg64::new(99);
        let n = 30;
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = g.normal();
        }
        let mut a = b.transpose().matmul(&b); // PSD
        a.add_diag(1.0); // PD
        let rhs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let x = cholesky_solve(&a, &rhs).unwrap();
        let ax = a.matvec(&x);
        let resid: f64 = ax
            .iter()
            .zip(rhs.iter())
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        assert!(resid < 1e-8, "residual {resid}");
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn logdet_of_diagonal() {
        let mut a = Mat::eye(3);
        a.set(0, 0, 2.0);
        a.set(1, 1, 4.0);
        a.set(2, 2, 8.0);
        let c = Cholesky::factor(&a).unwrap();
        assert!((c.logdet() - (64.0f64).ln()).abs() < 1e-12);
    }
}
