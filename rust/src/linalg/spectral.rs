//! Spectral estimation for smoothness / strong-convexity constants.
//!
//! The paper's step-size rules need `L_i = λ_max(∇²f_i)`, `L = λ_max(∇²f)`
//! and `μ = λ_min(∇²f)`. For ridge regression the Hessian is constant
//! (`AᵀA/m + λI`), so we estimate extreme eigenvalues of SPD matrices with:
//!
//! * **power iteration** with Rayleigh-quotient convergence test → λ_max,
//! * **spectral-shift power iteration** on `λ_max·I − H` → λ_min (avoids a
//!   full inverse; for PSD H this is robust and allocation-light).

use crate::linalg::matrix::Mat;
use crate::linalg::vector::{dot, nrm2, scale};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct SpectralOpts {
    pub max_iters: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Default for SpectralOpts {
    fn default() -> Self {
        Self {
            max_iters: 5_000,
            tol: 1e-12,
            seed: 0x5eed,
        }
    }
}

/// Largest eigenvalue of a symmetric PSD matrix via power iteration.
pub fn lambda_max(h: &Mat, opts: SpectralOpts) -> f64 {
    assert_eq!(h.rows, h.cols, "symmetric matrix required");
    let n = h.rows;
    if n == 0 {
        return 0.0;
    }
    let mut g = Pcg64::new(opts.seed);
    let mut v: Vec<f64> = (0..n).map(|_| g.normal()).collect();
    let norm = nrm2(&v);
    scale(1.0 / norm, &mut v);
    let mut hv = vec![0.0; n];
    let mut prev = 0.0f64;
    for _ in 0..opts.max_iters {
        h.matvec_into(&v, &mut hv);
        let lam = dot(&v, &hv); // Rayleigh quotient
        let hv_norm = nrm2(&hv);
        if hv_norm == 0.0 {
            return 0.0; // zero matrix
        }
        for i in 0..n {
            v[i] = hv[i] / hv_norm;
        }
        if (lam - prev).abs() <= opts.tol * lam.abs().max(1.0) {
            return lam.max(hv_norm); // hv_norm ≥ Rayleigh for the final iterate
        }
        prev = lam;
    }
    prev
}

/// Smallest eigenvalue of a symmetric PSD matrix via shifted power
/// iteration: λ_min(H) = s − λ_max(sI − H) with s ≥ λ_max(H).
pub fn lambda_min_psd(h: &Mat, opts: SpectralOpts) -> f64 {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    if n == 0 {
        return 0.0;
    }
    let lmax = lambda_max(h, opts);
    // shift slightly above λ_max so the target eigenvalue is the largest of
    // the shifted matrix with a margin
    let s = lmax * (1.0 + 1e-6) + 1e-12;
    let mut shifted = h.clone();
    shifted.scale(-1.0);
    shifted.add_diag(s);
    let lam_shift = lambda_max(&shifted, opts);
    (s - lam_shift).max(0.0)
}

/// Gershgorin upper bound on λ_max — cheap sanity check / fallback.
pub fn gershgorin_upper(h: &Mat) -> f64 {
    assert_eq!(h.rows, h.cols);
    let mut best = 0.0f64;
    for i in 0..h.rows {
        let row = h.row(i);
        let radius: f64 = row
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(_, v)| v.abs())
            .sum();
        best = best.max(h.get(i, i) + radius);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(vals: &[f64]) -> Mat {
        let n = vals.len();
        let mut m = Mat::zeros(n, n);
        for (i, &v) in vals.iter().enumerate() {
            m.set(i, i, v);
        }
        m
    }

    #[test]
    fn diagonal_extremes() {
        let h = diag(&[0.5, 3.0, 7.0, 1.0]);
        let opts = SpectralOpts::default();
        assert!((lambda_max(&h, opts) - 7.0).abs() < 1e-6);
        assert!((lambda_min_psd(&h, opts) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rank_one_plus_ridge() {
        // H = u uᵀ + λ I has λ_max = ‖u‖² + λ, λ_min = λ.
        let u = [1.0, 2.0, 2.0]; // ‖u‖² = 9
        let lam = 0.25;
        let mut h = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                h.set(i, j, u[i] * u[j]);
            }
        }
        h.add_diag(lam);
        let opts = SpectralOpts::default();
        assert!((lambda_max(&h, opts) - 9.25).abs() < 1e-6);
        assert!((lambda_min_psd(&h, opts) - 0.25).abs() < 1e-5);
    }

    #[test]
    fn gershgorin_upper_bounds_lambda_max() {
        let h = diag(&[1.0, 2.0, 5.0]);
        assert!(gershgorin_upper(&h) >= lambda_max(&h, SpectralOpts::default()) - 1e-9);
    }

    #[test]
    fn random_gram_consistency() {
        use crate::util::rng::Pcg64;
        let mut g = Pcg64::new(7);
        let mut a = Mat::zeros(40, 12);
        for v in a.data.iter_mut() {
            *v = g.normal();
        }
        let mut h = a.gram();
        h.scale(1.0 / 40.0);
        h.add_diag(0.01);
        let opts = SpectralOpts::default();
        let lmax = lambda_max(&h, opts);
        let lmin = lambda_min_psd(&h, opts);
        assert!(lmax >= lmin && lmin >= 0.0099, "lmax {lmax} lmin {lmin}");
        assert!(gershgorin_upper(&h) >= lmax - 1e-9);
        // trace bounds: lmin*n <= tr <= lmax*n
        let tr: f64 = (0..12).map(|i| h.get(i, i)).sum();
        assert!(lmin * 12.0 <= tr + 1e-9 && tr <= lmax * 12.0 + 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let h = Mat::zeros(5, 5);
        assert_eq!(lambda_max(&h, SpectralOpts::default()), 0.0);
    }
}
