//! Row-major dense matrix with the operations the problem layer needs:
//! matvec, transposed matvec, gram matrix, and a blocked GEMM used by the
//! spectral estimator and the data generator's low-rank construction.

use crate::linalg::vector::{axpy, dot};

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>, // row-major, len = rows * cols
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self {
            rows: r,
            cols: c,
            data,
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// `out = A x`.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// `out = Aᵀ y` without materializing the transpose.
    pub fn t_matvec_into(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            axpy(y[i], self.row(i), out);
        }
    }

    pub fn t_matvec(&self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.t_matvec_into(y, &mut out);
        out
    }

    /// Gram matrix `AᵀA` (cols × cols), the Hessian core of least squares.
    pub fn gram(&self) -> Mat {
        let d = self.cols;
        let mut g = Mat::zeros(d, d);
        // Rank-1 accumulation over rows: G += a_i a_iᵀ. Row-major friendly.
        for i in 0..self.rows {
            let a = self.row(i).to_vec();
            for j in 0..d {
                let aj = a[j];
                if aj != 0.0 {
                    let grow = g.row_mut(j);
                    for k in 0..d {
                        grow[k] += aj * a[k];
                    }
                }
            }
        }
        g
    }

    /// Blocked `A * B` (ikj loop order — streaming, autovectorizable).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            // split borrows: write into c.row_mut(i) while reading b rows
            for p in 0..k {
                let a_ip = arow[p];
                if a_ip == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a_ip * brow[j];
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, a: f64) {
        for v in self.data.iter_mut() {
            *v *= a;
        }
    }

    /// `self += a * I` (ridge term on a square matrix).
    pub fn add_diag(&mut self, a: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Mat {
        Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]])
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let m = a();
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.t_matvec(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn gram_is_at_a() {
        let m = a();
        let g = m.gram();
        let expected = m.transpose().matmul(&m);
        assert_eq!(g, expected);
        // symmetric
        for i in 0..g.rows {
            for j in 0..g.cols {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let m = a();
        let i2 = Mat::eye(2);
        assert_eq!(m.matmul(&i2), m);
    }

    #[test]
    fn matmul_known() {
        let x = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let y = Mat::from_rows(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        let z = x.matmul(&y);
        assert_eq!(z.data, vec![2.0, 1.0, 4.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = a();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn add_diag_and_fro() {
        let mut m = Mat::eye(3);
        m.add_diag(2.0);
        assert_eq!(m.get(0, 0), 3.0);
        assert!((m.fro() - (27.0f64).sqrt()).abs() < 1e-12);
    }
}
