//! Error-fed-back compressed broadcast downlink.
//!
//! The exact delta downlink ([`crate::wire`]'s `Delta` frames) is lossless
//! but only as sparse as the aggregate: once DIANA-family shifts densify,
//! `x^{k+1} − x^k` goes dense and the broadcast collapses back to O(d)
//! bytes per round. This module adds the missing half of the bidirectional
//! compression story: a **contractive compressor with server-side error
//! feedback** on the broadcast, in the spirit of EF21 ("A Better
//! Alternative to Error Feedback", Horváth & Richtárik, 2020) and EF-BV
//! (Condat et al., 2022) — the shifted-compression framework applies to
//! the downlink too.
//!
//! # Protocol
//!
//! The master keeps a per-cluster error accumulator `e^k` (zero after any
//! resync). Each round, after taking its exact gradient step
//! `x^{k+1} = x^k + Δ^k` (with `Δ^k = −γ g^k`), it
//!
//! 1. folds the step into the pending error: `u^k = e^k + Δ^k`,
//! 2. compresses it with a contractive compressor: `c^k = C(u^k)`
//!    (quantized to the wire precision so the encode → decode round-trip
//!    is lossless),
//! 3. broadcasts `c^k` as a [`crate::wire::DownKind::EfDelta`] frame (the
//!    measured wire cost of the round; workers validate it with
//!    [`wire::validate_down`]), and
//! 4. keeps the residual for the next round: `e^{k+1} = u^k − c^k`.
//!
//! # Replicas: shared snapshot + sparse overlay
//!
//! Workers do **not** replay the frame stream into private dense
//! replicas. The logical replica is represented as the fleet-shared
//! iterate snapshot plus a sparse overlay patch
//! ([`crate::coordinator::replica`]): after each fold this state rebuilds
//! the patch as `−e` on the error accumulator's nonzero support, so
//! `snapshot + patch` *is* the replica `x_master − e` — one O(d) snapshot
//! and O(nnz e) of patch for the whole fleet, instead of n dense copies.
//!
//! The **EF invariant** is `x_replica + e = x_master`: everything the
//! compressor has dropped so far is exactly what the replicas are still
//! missing. Under the overlay representation it holds by construction on
//! the accumulator's support (to one fp rounding per coordinate) and
//! bit-exactly off it; a resync [`EfDownlink::flush`]es `e` to zero and
//! empties the patch, collapsing the replica onto the snapshot exactly.
//! For a contractive `C ∈ B(δ)` the residual contracts —
//! `‖e^{k+1}‖² ≤ (1 − δ)‖e^k + Δ^k‖²` — so the replica drift stays
//! proportional to the recent step sizes and vanishes as the method
//! converges; the overlay's nnz is bounded by the compressor's residual
//! support (Top-K zeroes the k kept coordinates exactly).
//!
//! With `C = Identity` the compressor drops nothing: `c^k = Δ^k`, `e`
//! stays exactly zero, and the broadcast — re-packed through
//! [`wire::build_update_packet`]'s sparse/dense choice — is bit-identical
//! in effect to the exact `Delta` path (pinned by
//! `tests/coordinator.rs`), which is why `Identity` doubles as the "exact
//! fallback" configuration.
//!
//! Used by [`crate::coordinator::DistributedRunner`] and mirrored op for
//! op by the single-process drivers ([`crate::algorithms::DcgdShift`],
//! [`crate::algorithms::Gdci`], [`crate::algorithms::VrGdci`]) so
//! trajectories stay bit-identical across drivers. The driver-side glue —
//! replica bootstrap, resync flush, next-frame accounting — lives in one
//! place, [`DownlinkState`], shared by every driver: one copy to keep
//! bit-identical. The fold/compress/flush cycle itself is the
//! direction-agnostic [`crate::ef::EfCore`], shared with the worker-side
//! [`crate::ef::EfUplink`] that applies the same construction to the
//! uplink.

use std::sync::Arc;

use crate::compressors::{Compressor, Packet, ValPrec};
use crate::coordinator::replica::{materialize_into, OverlayPatch};
use crate::ef::EfCore;
use crate::util::rng::Pcg64;
use crate::wire;

/// Master-side state of the error-fed-back downlink: the compressor, its
/// RNG stream, and the shared error-feedback core ([`crate::ef::EfCore`] —
/// accumulator `e` plus the recycled compress/re-pack scratch; the
/// identical fold/flush cycle drives the worker-side
/// [`crate::ef::EfUplink`], so the two directions can never drift apart).
/// Steady-state rounds never touch the allocator once the compressed
/// support has reached its working size.
pub struct EfDownlink {
    comp: Box<dyn Compressor>,
    rng: Pcg64,
    core: EfCore,
}

impl EfDownlink {
    /// `comp` must be built for dimension `d`; `rng` is the master's
    /// dedicated downlink stream (deterministic compressors like Top-K and
    /// Identity never draw from it, but the stream keeps randomized
    /// compressors reproducible and bit-identical across drivers).
    pub fn new(comp: Box<dyn Compressor>, d: usize, rng: Pcg64) -> Self {
        assert_eq!(comp.dim(), d, "downlink compressor dimension mismatch");
        Self {
            comp,
            rng,
            core: EfCore::new(d),
        }
    }

    /// One round of error feedback: fold the exact step `delta` (the
    /// packet the master applied to its own iterate) into `e`, compress
    /// `e + Δ`, keep the residual, and return the quantized broadcast
    /// packet. The compressor output is re-packed through
    /// [`wire::build_update_packet`]'s exact bit accounting (see
    /// [`EfCore::compress_pending`]), so the frame takes the cheaper of
    /// the Sparse/Dense representations — Identity reproduces the exact
    /// delta path frame for frame, and a near-dense Top-K never ships a
    /// sparse encoding that costs more than the dense one.
    pub fn fold_and_compress(&mut self, delta: &Packet, prec: ValPrec) -> &Packet {
        self.core.fold_packet(delta);
        self.core.compress_pending(self.comp.as_ref(), &mut self.rng, prec)
    }

    /// Like [`fold_and_compress`](Self::fold_and_compress) but folding a
    /// raw dense step `x^{k+1} − x^k`. Drivers whose master iterate does
    /// *not* advance through a pre-quantized packet (the GDCI mixing
    /// update) must fold the raw difference: folding a quantized delta
    /// would silently drop the quantization residual from the accumulator
    /// and let the replica drift unboundedly under f32 wire precision.
    pub fn fold_slice_and_compress(&mut self, delta: &[f64], prec: ValPrec) -> &Packet {
        self.core.fold_slice(delta);
        self.core.compress_pending(self.comp.as_ref(), &mut self.rng, prec)
    }

    /// The packet returned by the last compress call.
    pub fn packet(&self) -> &Packet {
        self.core.packet()
    }

    /// Zero the error accumulator. Must be called whenever a dense resync
    /// frame is broadcast: the replicas then hold `x_master` exactly, so
    /// nothing is pending.
    pub fn flush(&mut self) {
        self.core.flush();
    }

    /// The error accumulator `x_master − x_replica` (tests, diagnostics).
    pub fn error(&self) -> &[f64] {
        self.core.error()
    }

    /// Contraction parameter δ of the configured compressor, if known.
    pub fn delta_contraction(&self) -> Option<f64> {
        self.comp.delta()
    }

    /// Human-readable compressor identifier (logs, bench labels).
    pub fn comp_name(&self) -> String {
        self.comp.name()
    }
}

// ------------------------------------------------------ driver-side glue

/// Broadcast-side state shared by every driver: measured delta-frame
/// accounting (round-0 dense resync, then one update frame per round) and
/// the optional error-fed-back compressed downlink with its sparse
/// replica overlay and materialized mirror view. This is the single copy
/// of the glue the threaded coordinator
/// and the single-process drivers ([`crate::algorithms::DcgdShift`],
/// [`crate::algorithms::Gdci`], [`crate::algorithms::VrGdci`]) all reuse,
/// so `bits_down` means the same thing across the library and the EF fold
/// stays bit-identical across drivers by construction.
///
/// Two finishing flavors cover the two ways a master iterate advances:
///
/// * [`finish_round_packet`](Self::finish_round_packet) — the DCGD-SHIFT
///   family, whose step goes through a pre-quantized delta packet (the
///   same packet is folded, so the accumulator sees exactly what the
///   master applied);
/// * [`finish_round`](Self::finish_round) — the GDCI family, whose mixing
///   update touches every coordinate without a packet; the *raw*
///   difference `x^{k+1} − x^k` is folded so the quantization residual
///   stays in the accumulator.
pub struct DownlinkState {
    ef: Option<EfDownlink>,
    /// sparse overlay `−e` on the error accumulator's support: what the
    /// logical replicas differ from the snapshot by (empty when exact)
    overlay: OverlayPatch,
    /// materialized logical replica `snapshot + overlay` (EF path only;
    /// empty when exact) — the mirror view [`Self::x_eval`] hands the
    /// single-process drivers, rebuilt through the *same*
    /// [`materialize_into`] kernel the worker threads use so both sides
    /// see identical bits
    x_hat: Vec<f64>,
    /// recycled dense resync frame for `Rejoin` arms: built once per
    /// rejoin round and shared (via `Arc`) by every rejoining worker
    /// instead of a fresh O(d) frame per arm
    rejoin_buf: Arc<Vec<u8>>,
    /// dedicated RNG stream for the downlink compressor
    dl_rng: Pcg64,
    /// x^k snapshot the broadcast delta is built against — allocated only
    /// by [`Self::track_deltas`] (the GDCI flavor); packet-driven drivers
    /// hand their delta packet in directly and never pay for this scratch
    x_prev: Vec<f64>,
    /// x^{k+1} − x^k scratch ([`Self::track_deltas`] only)
    diff: Vec<f64>,
    /// delta builder scratch ([`Self::track_deltas`] only; both
    /// representations pre-sized to d)
    delta: wire::DeltaScratch,
    /// per-worker bits of the frame the *next* round broadcasts
    next_down_bits: u64,
}

impl DownlinkState {
    /// `dl_rng` is the master's dedicated downlink compressor stream
    /// (worker streams are 1..=n, this is n+1 — every driver derives it
    /// identically so randomized downlink compressors stay bit-identical
    /// across drivers). `x0` fixes the dimension; drivers that account
    /// the broadcast from raw iterate differences must also call
    /// [`Self::track_deltas`].
    pub fn new(x0: &[f64], dl_rng: Pcg64) -> Self {
        Self {
            ef: None,
            overlay: OverlayPatch::new(),
            x_hat: Vec::new(),
            rejoin_buf: Arc::new(Vec::new()),
            dl_rng,
            x_prev: Vec::new(),
            diff: Vec::new(),
            delta: wire::DeltaScratch::with_capacity(0),
            // round 0 broadcasts the dense bootstrap resync
            next_down_bits: wire::resync_frame_bits(x0.len()),
        }
    }

    /// Allocate the iterate-difference tracking scratch (~4·d f64) and
    /// snapshot `x0` as the baseline the first broadcast delta is built
    /// against. Required before [`Self::finish_round`]; drivers on the
    /// packet flavor ([`Self::finish_round_packet`]) skip it and stay
    /// scratch-free.
    pub fn track_deltas(&mut self, x0: &[f64]) {
        let d = x0.len();
        self.x_prev = x0.to_vec();
        self.diff = vec![0.0; d];
        self.delta = wire::DeltaScratch::with_capacity(d);
    }

    /// Arm the error-fed-back compressed broadcast; the overlay starts
    /// empty and the mirror view boots from the current iterate (what the
    /// next dense resync would carry).
    pub fn arm(&mut self, comp: Box<dyn Compressor>, x: &[f64]) {
        self.overlay.clear();
        materialize_into(x, &self.overlay, &mut self.x_hat);
        self.ef = Some(EfDownlink::new(comp, x.len(), self.dl_rng.clone()));
        self.next_down_bits = wire::resync_frame_bits(x.len());
    }

    /// Is the lossy EF broadcast armed (vs exact delta frames)?
    pub fn is_armed(&self) -> bool {
        self.ef.is_some()
    }

    /// The iterate the workers actually hold this round: the materialized
    /// `snapshot + overlay` view when the EF broadcast is armed, the
    /// master iterate itself when exact (replicas are then bit-equal to
    /// it by construction).
    pub fn x_eval<'a>(&'a self, x: &'a [f64]) -> &'a [f64] {
        if self.ef.is_some() {
            &self.x_hat
        } else {
            x
        }
    }

    /// The sparse overlay patch the logical replicas carry on top of the
    /// published snapshot (empty on the exact path). The threaded runner
    /// publishes exactly this patch alongside each snapshot.
    pub fn overlay(&self) -> &OverlayPatch {
        &self.overlay
    }

    /// The logical worker replica x̂ = snapshot + overlay, materialized
    /// (`None` on the exact path, where the replicas are bit-equal to the
    /// master iterate by construction).
    pub fn replica(&self) -> Option<&[f64]> {
        self.ef.as_ref().map(|_| self.x_hat.as_slice())
    }

    /// Resident bytes of the mirror-side replica state: the materialized
    /// view plus the overlay payload (0 when exact — the mirror borrows
    /// the master iterate).
    pub fn replica_footprint(&self) -> u64 {
        (self.x_hat.len() * 8) as u64 + self.overlay.bytes()
    }

    /// The EF error accumulator `x_master − x_replica` (`None` when exact).
    pub fn ef_error(&self) -> Option<&[f64]> {
        self.ef.as_ref().map(|ef| ef.error())
    }

    /// EF-fold a pre-quantized delta packet (the exact step the master
    /// just applied to its own iterate), rebuild the overlay from the new
    /// residual, and re-materialize the mirror view `x_new + overlay`
    /// with the same kernel the workers use; returns the packet to
    /// broadcast (`delta` itself on the exact path). `x_new` is the
    /// master iterate *after* the step `delta` was applied.
    pub fn fold_packet<'a>(
        &'a mut self,
        delta: &'a Packet,
        x_new: &[f64],
        prec: ValPrec,
    ) -> &'a Packet {
        match &mut self.ef {
            Some(ef) => {
                ef.fold_and_compress(delta, prec);
                self.overlay.rebuild_from_error(ef.error());
                materialize_into(x_new, &self.overlay, &mut self.x_hat);
                ef.packet()
            }
            None => delta,
        }
    }

    /// [`Self::fold_packet`] with the O(d) mirror re-materialization
    /// sharded across a caller-supplied parallel runner (the threaded
    /// runner hands in its fold pool; `cuts` are the pool's coordinate
    /// shard cuts). Per shard the kernel is the same
    /// `copy_from_slice` + [`OverlayPatch::apply_range`] `+=` sequence
    /// the serial [`materialize_into`] performs on those coordinates, so
    /// the mirror is bit-identical for any shard count — including the
    /// single-process drivers that keep calling the serial form. The EF
    /// fold-and-compress itself stays serial: compressor tie-breaking
    /// (Top-K ordering, randomized draws) is sequence-sensitive, and the
    /// downstream bit-packed frame encode is a single bit stream either
    /// way. Exact-path calls (`ef = None`) do no materialization at all
    /// and never invoke the runner.
    pub fn fold_packet_pooled<'a>(
        &'a mut self,
        delta: &'a Packet,
        x_new: &[f64],
        prec: ValPrec,
        par: &dyn Fn(&(dyn Fn(usize) + Sync)),
        cuts: &[usize],
    ) -> &'a Packet {
        match &mut self.ef {
            Some(ef) => {
                ef.fold_and_compress(delta, prec);
                self.overlay.rebuild_from_error(ef.error());
                if self.x_hat.len() != x_new.len() {
                    self.x_hat.resize(x_new.len(), 0.0);
                }
                {
                    let overlay = &self.overlay;
                    let x_hat = crate::coordinator::pool::ShardView::new(&mut self.x_hat);
                    par(&|s| {
                        let (lo, hi) = (cuts[s], cuts[s + 1]);
                        if lo < hi {
                            // SAFETY: shard ranges are disjoint.
                            let sub = unsafe { x_hat.slice(lo, hi) };
                            sub.copy_from_slice(&x_new[lo..hi]);
                            overlay.apply_range(lo, hi, sub);
                        }
                    });
                }
                ef.packet()
            }
            None => delta,
        }
    }

    /// Account this round's broadcast for a driver whose iterate advances
    /// through a pre-quantized delta packet (the DCGD-SHIFT family):
    /// returns this round's `bits_down` across `n` workers and builds the
    /// next frame from `delta` via [`fold_packet`](Self::fold_packet).
    pub fn finish_round_packet(
        &mut self,
        delta: &Packet,
        x_new: &[f64],
        n: usize,
        prec: ValPrec,
    ) -> u64 {
        let bits_down = n as u64 * self.next_down_bits;
        let next = wire::down_frame_bits(self.fold_packet(delta, x_new, prec), prec);
        self.next_down_bits = next;
        bits_down
    }

    /// Account this round's broadcast and build the next frame from
    /// `x_new − x_prev`, EF-compressed when armed (replica updated with
    /// the same packet the workers apply). Returns this round's
    /// `bits_down` across `n` workers. The GDCI flavor: the raw difference
    /// is folded so the quantization residual stays in the accumulator.
    /// Requires [`Self::track_deltas`] at construction.
    pub fn finish_round(&mut self, x_new: &[f64], n: usize, prec: ValPrec) -> u64 {
        assert_eq!(
            self.x_prev.len(),
            x_new.len(),
            "finish_round needs track_deltas(x0) at construction"
        );
        let bits_down = n as u64 * self.next_down_bits;
        for j in 0..x_new.len() {
            self.diff[j] = x_new[j] - self.x_prev[j];
        }
        self.next_down_bits = match &mut self.ef {
            Some(ef) => {
                ef.fold_slice_and_compress(&self.diff, prec);
                self.overlay.rebuild_from_error(ef.error());
                materialize_into(x_new, &self.overlay, &mut self.x_hat);
                wire::down_frame_bits(ef.packet(), prec)
            }
            None => {
                let delta = wire::build_update_packet(&self.diff, 1.0, prec, &mut self.delta);
                wire::down_frame_bits(delta, prec)
            }
        };
        self.x_prev.copy_from_slice(x_new);
        bits_down
    }

    /// Out-of-band iterate change (or a scheduled dense broadcast): the
    /// next frame is a dense resync, which flushes the EF accumulator,
    /// truncates the overlay to empty, and collapses the replica mirror
    /// onto `x` exactly (and resets the delta-tracking baseline, when
    /// armed).
    pub fn resync(&mut self, x: &[f64]) {
        self.next_down_bits = wire::resync_frame_bits(x.len());
        if !self.x_prev.is_empty() {
            self.x_prev.copy_from_slice(x);
        }
        if let Some(ef) = &mut self.ef {
            ef.flush();
            self.overlay.clear();
            materialize_into(x, &self.overlay, &mut self.x_hat);
        }
    }

    /// The dense resync frame a `Rejoin` command carries, built once into
    /// a recycled buffer and shared by every rejoin arm of the round (the
    /// old protocol materialized a fresh O(d) frame *per arm* — the
    /// resync-frame memory spike). The buffer is reused in place via
    /// [`Arc::get_mut`] whenever no worker still pins the previous rejoin
    /// frame; a pinned buffer costs one fallback allocation.
    pub fn rejoin_frame(&mut self, x: &[f64]) -> Arc<Vec<u8>> {
        match Arc::get_mut(&mut self.rejoin_buf) {
            Some(buf) => {
                wire::encode_down_dense(wire::DownKind::Resync, x, ValPrec::F64, buf);
            }
            None => {
                let mut buf = Vec::with_capacity(x.len() * 8 + 32);
                wire::encode_down_dense(wire::DownKind::Resync, x, ValPrec::F64, &mut buf);
                self.rejoin_buf = Arc::new(buf);
            }
        }
        self.rejoin_buf.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{Identity, TopK};
    use crate::linalg::{nrm2_sq, scatter_axpy};

    fn rng() -> Pcg64 {
        Pcg64::with_stream(7, 0xef)
    }

    fn sparse_delta(d: usize, touched: &[(u32, f64)]) -> Packet {
        Packet::Sparse {
            dim: d as u32,
            indices: touched.iter().map(|&(i, _)| i).collect(),
            values: touched.iter().map(|&(_, v)| v).collect(),
            scale: 1.0,
        }
    }

    #[test]
    fn identity_leaves_zero_error_and_matches_delta() {
        let d = 32;
        let mut ef = EfDownlink::new(Box::new(Identity::new(d)), d, rng());
        let delta = sparse_delta(d, &[(3, 0.5), (17, -1.25)]);
        let mut from_delta = vec![0.0; d];
        delta.add_scaled_into(1.0, &mut from_delta);
        let c = ef.fold_and_compress(&delta, ValPrec::F64);
        // identity broadcast applies exactly the delta
        let mut from_ef = vec![0.0; d];
        c.add_scaled_into(1.0, &mut from_ef);
        for j in 0..d {
            assert_eq!(from_ef[j].to_bits(), from_delta[j].to_bits(), "coord {j}");
        }
        // and the re-pack picked the sparse representation
        assert!(matches!(ef.packet(), Packet::Sparse { .. }));
        assert!(ef.error().iter().all(|&v| v == 0.0), "identity must keep e = 0");
    }

    #[test]
    fn topk_contracts_the_residual_and_feeds_it_back() {
        let d = 64;
        let k = 8;
        let mut ef = EfDownlink::new(Box::new(TopK::new(d, k)), d, rng());
        let mut x_master = vec![0.0; d];
        let mut x_rep = vec![0.0; d];
        let mut g = Pcg64::new(5);
        for round in 0..50 {
            // a dense-ish step: every coordinate moves a little
            let step: Vec<f64> = (0..d).map(|_| 0.1 * g.normal()).collect();
            let delta = Packet::Dense(step.clone());
            delta.add_scaled_into(1.0, &mut x_master);
            let u_norm_sq = {
                let mut u = ef.error().to_vec();
                crate::linalg::axpy(1.0, &step, &mut u);
                nrm2_sq(&u)
            };
            let c = ef.fold_and_compress(&delta, ValPrec::F64);
            assert!(matches!(c, Packet::Sparse { .. }), "top-k ships a sparse frame");
            assert_eq!(c.nnz(), k, "top-k keeps exactly k coordinates");
            c.add_scaled_into(1.0, &mut x_rep);
            // contraction: ‖e_new‖² ≤ (1 − k/d)·‖e_old + Δ‖²
            let bound = (1.0 - k as f64 / d as f64) * u_norm_sq;
            let e_sq = nrm2_sq(ef.error());
            assert!(e_sq <= bound + 1e-12, "round {round}: {e_sq} > {bound}");
            // EF invariant: x_rep + e = x_master (to fp rounding)
            for j in 0..d {
                let lhs = x_rep[j] + ef.error()[j];
                assert!(
                    (lhs - x_master[j]).abs() <= 1e-12 * x_master[j].abs().max(1.0),
                    "round {round} coord {j}: {lhs} vs {}",
                    x_master[j]
                );
            }
        }
        // flush models a resync: replicas are overwritten, nothing pending
        ef.flush();
        assert!(ef.error().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn f32_broadcast_survives_wire_roundtrip() {
        let d = 16;
        let mut ef = EfDownlink::new(Box::new(TopK::new(d, 3)), d, rng());
        let delta = sparse_delta(d, &[(0, 0.1), (5, -7.3), (9, 1e-3), (12, 2.5)]);
        let c = ef.fold_and_compress(&delta, ValPrec::F32);
        let mut buf = Vec::new();
        wire::encode_down_into(wire::DownKind::EfDelta, c, ValPrec::F32, &mut buf);
        let mut back = Packet::Zero { dim: 0 };
        assert_eq!(
            wire::decode_down_into(&buf, &mut back).unwrap(),
            wire::DownKind::EfDelta
        );
        assert_eq!(&back, c, "quantized EF frame must round-trip losslessly");
    }

    #[test]
    fn scatter_reference_sanity() {
        // the apply path used by workers is scatter_axpy for scale-1 sparse
        // packets; pin the equivalence the EF tests above rely on
        let mut out = vec![1.0; 8];
        let pkt = sparse_delta(8, &[(2, 0.5)]);
        pkt.add_scaled_into(1.0, &mut out);
        let mut want = vec![1.0; 8];
        scatter_axpy(1.0, &[2], &[0.5], &mut want);
        assert_eq!(out, want);
    }
}
