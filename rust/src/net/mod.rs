//! Simulated network model.
//!
//! The coordinator moves *real encoded bytes* between threads; this module
//! prices those bytes. Each worker↔master link has a bandwidth and latency;
//! a synchronous round costs the slowest worker's uplink plus the broadcast
//! ("the straggler defines the round"). This is what turns bit-accounting
//! into the simulated wall-clock series reported alongside the figures, and
//! what makes heterogeneous-compressor experiments (slow links get more
//! aggressive compressors — §3.2.1's remark) meaningful.
//!
//! # Staged rounds and pipelined overlap
//!
//! [`NetworkAccountant::round`] prices communication only (the historical
//! model). Batched local-step rounds also account the compute stage, with
//! per-worker measured compute seconds:
//!
//! * [`NetworkAccountant::round_staged`] — the three stages run back to
//!   back: broadcast, then compute, then uplink; the slowest worker's
//!   `down_i + compute_i + up_i` defines the round.
//! * [`NetworkAccountant::round_pipelined`] — within a batched round the
//!   worker streams each of its `stages` sub-step packets as soon as it is
//!   produced, so sub-step compute overlaps the uplink *transfer* (the
//!   broadcast and the uplink latency cannot overlap — the first packet
//!   must exist before anything is sent). Per worker the round costs
//!   `down + L_up + max(C_i + x_i/τ, C_i/τ + x_i)` where `C_i` is the
//!   worker's total compute, `x_i` its uplink transfer time and τ the
//!   stage count — the exact finish time of a homogeneous τ-stage
//!   two-phase pipeline. With τ = 1 this degenerates to the staged cost
//!   (nothing can overlap), and it is always ≥ max of the stage costs and
//!   ≤ the staged cost, so the simulated wall clock honestly reflects the
//!   overlap instead of charging `compute + comm`.
//!
//! # Quorum pricing and per-round participation masks
//!
//! Semi-async rounds close as soon as `m` of the active workers have
//! arrived, so the round's wall clock is the **m-th fastest** worker's
//! finish time, not the fleet max ([`NetworkAccountant::set_quorum`]).
//! Partial participation samples a subset S_k per round; a worker
//! sampled out for one round is masked with the one-shot
//! [`NetworkAccountant::set_round_mask`] (the sticky
//! [`NetworkAccountant::set_worker_active`] expresses quarantine, which
//! persists across rounds — the mask composes with it and clears itself
//! after the next priced round). A masked-out worker contributes neither
//! link time nor traffic, so a masked round prices exactly like the
//! smaller fleet (unit-pinned below).
//!
//! Trajectories never depend on which pricing is used — only `sim_time`
//! does.

/// One worker's link to the master.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// uplink bandwidth, bits/second
    pub up_bps: f64,
    /// downlink bandwidth, bits/second
    pub down_bps: f64,
    /// one-way latency, seconds
    pub latency: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 100 Mbit/s symmetric, 1 ms — a commodity datacenter link.
        Self {
            up_bps: 100e6,
            down_bps: 100e6,
            latency: 1e-3,
        }
    }
}

impl LinkModel {
    /// Panics unless the link is physically meaningful: bandwidths must be
    /// positive and finite, latency non-negative and finite. Called by
    /// every constructor-like entry point ([`NetworkAccountant::new`],
    /// [`Self::heterogeneous_fleet`]) so a bad link fails loudly at
    /// construction instead of producing NaN/∞ wall clocks mid-run.
    pub fn validate(&self) {
        assert!(
            self.up_bps > 0.0 && self.up_bps.is_finite(),
            "LinkModel.up_bps must be positive and finite, got {}",
            self.up_bps
        );
        assert!(
            self.down_bps > 0.0 && self.down_bps.is_finite(),
            "LinkModel.down_bps must be positive and finite, got {}",
            self.down_bps
        );
        assert!(
            self.latency >= 0.0 && self.latency.is_finite(),
            "LinkModel.latency must be non-negative and finite, got {}",
            self.latency
        );
    }

    pub fn uplink_time(&self, bits: u64) -> f64 {
        self.latency + bits as f64 / self.up_bps
    }
    pub fn downlink_time(&self, bits: u64) -> f64 {
        self.latency + bits as f64 / self.down_bps
    }

    /// A heterogeneous fleet: worker i's bandwidths shrink by
    /// `1/(1 + i·bw_spread)` and its latency grows by
    /// `(1 + i·lat_spread)` — the two degradations are independently
    /// configurable (a far-away worker has high latency but not
    /// necessarily a thin pipe, and vice versa). Both spreads must be
    /// ≥ 0 and the base link valid.
    pub fn heterogeneous_fleet(
        n: usize,
        base: LinkModel,
        bw_spread: f64,
        lat_spread: f64,
    ) -> Vec<LinkModel> {
        base.validate();
        assert!(
            bw_spread >= 0.0 && bw_spread.is_finite(),
            "bw_spread must be non-negative and finite, got {bw_spread}"
        );
        assert!(
            lat_spread >= 0.0 && lat_spread.is_finite(),
            "lat_spread must be non-negative and finite, got {lat_spread}"
        );
        (0..n)
            .map(|i| LinkModel {
                up_bps: base.up_bps / (1.0 + i as f64 * bw_spread),
                down_bps: base.down_bps / (1.0 + i as f64 * bw_spread),
                latency: base.latency * (1.0 + i as f64 * lat_spread),
            })
            .collect()
    }
}

/// Accumulates the simulated time and traffic of a run.
#[derive(Clone, Debug, Default)]
pub struct NetworkAccountant {
    pub links: Vec<LinkModel>,
    pub total_up_bits: u64,
    pub total_down_bits: u64,
    pub sim_time: f64,
    pub rounds: usize,
    /// degraded-round mask: an inactive (quarantined) worker contributes
    /// neither link time nor traffic — a round with f workers masked out
    /// costs exactly what an (n−f)-fleet round costs (unit-pinned below)
    pub active: Vec<bool>,
    /// quorum size: when `Some(m)`, a round's wall clock is the m-th
    /// fastest participant's finish time instead of the max (the
    /// semi-async close rule); `m ≥ participants` degenerates to the max
    quorum: Option<usize>,
    /// one-shot per-round participation mask (see the module doc);
    /// consumed and cleared by the next priced round
    round_mask: Vec<bool>,
    round_mask_on: bool,
    /// reused sort scratch for the quorum order statistic
    times_scratch: Vec<f64>,
}

impl NetworkAccountant {
    pub fn new(links: Vec<LinkModel>) -> Self {
        for link in &links {
            link.validate();
        }
        Self {
            active: vec![true; links.len()],
            links,
            ..Default::default()
        }
    }

    pub fn uniform(n: usize, link: LinkModel) -> Self {
        Self::new(vec![link; n])
    }

    /// Mask worker `wi` in (`true`) or out (`false`) of round pricing —
    /// the coordinator flips this on quarantine and rejoin.
    pub fn set_worker_active(&mut self, wi: usize, on: bool) {
        self.active[wi] = on;
    }

    /// Price rounds under an `m`-quorum close: the round's wall clock is
    /// the m-th smallest participant finish time (ties broken by
    /// `total_cmp`, so the statistic is deterministic). `None` (or
    /// `m ≥ participants`) restores the barrier max. Sticky, unlike the
    /// per-round mask — the close rule is a property of the run.
    pub fn set_quorum(&mut self, m: Option<usize>) {
        if let Some(m) = m {
            assert!(m >= 1, "quorum must be at least 1");
        }
        self.quorum = m;
    }

    /// Mask the **next priced round only**: workers with `mask[wi] ==
    /// false` are sampled out of that round — no link time, no traffic —
    /// and the mask clears itself once the round is priced. Composes with
    /// the sticky [`Self::set_worker_active`] (a quarantined worker stays
    /// out either way). Reuses an internal buffer, so steady-state rounds
    /// stay allocation-free.
    pub fn set_round_mask(&mut self, mask: &[bool]) {
        assert_eq!(mask.len(), self.links.len());
        self.round_mask.clear();
        self.round_mask.extend_from_slice(mask);
        self.round_mask_on = true;
    }

    /// Price one synchronous round: `up_bits[i]` is worker i's uplink
    /// payload, `down_bits` the per-worker broadcast size. Returns the
    /// round's wall-clock contribution. Communication-only (the
    /// historical pricing; compute-aware rounds use
    /// [`Self::round_staged`] / [`Self::round_pipelined`]).
    pub fn round(&mut self, up_bits: &[u64], down_bits: u64) -> f64 {
        self.finish_round(up_bits, down_bits, |link, bits, _wi| {
            link.uplink_time(bits) + link.downlink_time(down_bits)
        })
    }

    /// Price one staged round: broadcast, then `compute_secs[i]` of
    /// worker i's compute, then the uplink — the slowest worker's
    /// `down_i + compute_i + up_i` defines the round.
    pub fn round_staged(&mut self, up_bits: &[u64], down_bits: u64, compute_secs: &[f64]) -> f64 {
        assert_eq!(compute_secs.len(), self.links.len());
        self.finish_round(up_bits, down_bits, |link, bits, wi| {
            link.downlink_time(down_bits) + compute_secs[wi] + link.uplink_time(bits)
        })
    }

    /// Price one pipelined batched round (see the module doc): each worker
    /// streams its `stages` sub-step packets as they are produced, so its
    /// compute overlaps its uplink transfer. Never less than the max of a
    /// worker's stage costs; equal to [`Self::round_staged`] when
    /// `stages == 1`.
    pub fn round_pipelined(
        &mut self,
        up_bits: &[u64],
        down_bits: u64,
        compute_secs: &[f64],
        stages: usize,
    ) -> f64 {
        assert_eq!(compute_secs.len(), self.links.len());
        let s = stages.max(1) as f64;
        self.finish_round(up_bits, down_bits, |link, bits, wi| {
            let x = bits as f64 / link.up_bps;
            let c = compute_secs[wi];
            let overlapped = (c + x / s).max(c / s + x);
            link.downlink_time(down_bits) + link.latency + overlapped
        })
    }

    /// Shared straggler fold: `worker_time(link, up_bits, worker)` prices
    /// one worker's round; the slowest *participating* worker defines the
    /// round's wall-clock contribution — or the m-th fastest under an
    /// [`Self::set_quorum`] close — and the traffic totals accumulate over
    /// the participants only (a quarantined or sampled-out worker neither
    /// receives the broadcast nor ships an uplink). A one-shot
    /// [`Self::set_round_mask`] is consumed here.
    fn finish_round(
        &mut self,
        up_bits: &[u64],
        down_bits: u64,
        worker_time: impl Fn(&LinkModel, u64, usize) -> f64,
    ) -> f64 {
        assert_eq!(up_bits.len(), self.links.len());
        self.times_scratch.clear();
        let mut active_count: u64 = 0;
        for (wi, (bits, link)) in up_bits.iter().zip(self.links.iter()).enumerate() {
            if !self.active[wi] || (self.round_mask_on && !self.round_mask[wi]) {
                continue;
            }
            active_count += 1;
            self.times_scratch.push(worker_time(link, *bits, wi));
            self.total_up_bits += bits;
        }
        self.round_mask_on = false;
        let round_time = match self.quorum {
            Some(m) if m < self.times_scratch.len() => {
                // m-th order statistic of the participant finish times:
                // the round closed once m arrivals were in, so the tail
                // beyond the m-th fastest costs nothing.
                self.times_scratch.sort_unstable_by(|a, b| a.total_cmp(b));
                self.times_scratch[m - 1]
            }
            _ => self
                .times_scratch
                .iter()
                .fold(0.0_f64, |acc, t| acc.max(*t)),
        };
        self.total_down_bits += down_bits * active_count;
        self.sim_time += round_time;
        self.rounds += 1;
        round_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times() {
        let l = LinkModel {
            up_bps: 1e6,
            down_bps: 2e6,
            latency: 0.01,
        };
        assert!((l.uplink_time(1_000_000) - 1.01).abs() < 1e-12);
        assert!((l.downlink_time(1_000_000) - 0.51).abs() < 1e-12);
    }

    #[test]
    fn straggler_defines_round() {
        let fast = LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        let slow = LinkModel {
            up_bps: 1e3,
            down_bps: 1e9,
            latency: 0.0,
        };
        let mut acc = NetworkAccountant::new(vec![fast, slow]);
        let t = acc.round(&[1_000, 1_000], 0);
        assert!((t - 1.0).abs() < 1e-6, "slow link dominates: {t}");
        assert_eq!(acc.total_up_bits, 2_000);
    }

    #[test]
    fn accumulates_over_rounds() {
        let mut acc = NetworkAccountant::uniform(3, LinkModel::default());
        acc.round(&[100, 200, 300], 640);
        acc.round(&[100, 200, 300], 640);
        assert_eq!(acc.rounds, 2);
        assert_eq!(acc.total_up_bits, 1200);
        assert_eq!(acc.total_down_bits, 2 * 640 * 3);
        assert!(acc.sim_time > 0.0);
    }

    #[test]
    fn heterogeneous_fleet_degrades() {
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 1.0, 1.0);
        assert!(fleet[0].up_bps > fleet[3].up_bps * 3.0);
        assert!(fleet[3].latency > fleet[0].latency * 3.0);
    }

    #[test]
    fn heterogeneous_fleet_spreads_are_independent() {
        // latency-only spread: bandwidths stay flat, latency degrades
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 0.0, 2.0);
        assert_eq!(fleet[0].up_bps, fleet[3].up_bps);
        assert_eq!(fleet[0].down_bps, fleet[3].down_bps);
        assert!(fleet[3].latency > fleet[0].latency * 6.0);
        // bandwidth-only spread: latency stays flat
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 2.0, 0.0);
        assert_eq!(fleet[0].latency, fleet[3].latency);
        assert!(fleet[0].up_bps > fleet[3].up_bps * 6.0);
    }

    #[test]
    #[should_panic(expected = "up_bps must be positive")]
    fn rejects_non_positive_bandwidth() {
        NetworkAccountant::uniform(
            2,
            LinkModel {
                up_bps: 0.0,
                down_bps: 1e6,
                latency: 0.01,
            },
        );
    }

    #[test]
    #[should_panic(expected = "latency must be non-negative")]
    fn rejects_negative_latency() {
        LinkModel::heterogeneous_fleet(
            2,
            LinkModel {
                up_bps: 1e6,
                down_bps: 1e6,
                latency: -0.5,
            },
            1.0,
            1.0,
        );
    }

    #[test]
    fn staged_round_adds_compute_to_the_straggler() {
        let link = LinkModel {
            up_bps: 1e6,
            down_bps: 1e6,
            latency: 0.01,
        };
        let mut comm_only = NetworkAccountant::uniform(2, link);
        let t0 = comm_only.round(&[1_000_000, 500_000], 100_000);
        let mut staged = NetworkAccountant::uniform(2, link);
        let t1 = staged.round_staged(&[1_000_000, 500_000], 100_000, &[0.25, 0.25]);
        assert!((t1 - (t0 + 0.25)).abs() < 1e-12, "{t1} vs {t0} + 0.25");
        // per-worker compute: the straggler is whoever's *sum* is worst,
        // not comm-straggler + fleet-max compute. Worker 0: 1.01 up +
        // 0.11 down + 0.0 = 1.12; worker 1: 0.51 + 0.11 + 1.0 = 1.62.
        let mut hetero = NetworkAccountant::uniform(2, link);
        let t2 = hetero.round_staged(&[1_000_000, 500_000], 100_000, &[0.0, 1.0]);
        assert!((t2 - 1.62).abs() < 1e-12, "hetero staged round {t2}");
    }

    #[test]
    fn masked_round_costs_the_same_as_the_smaller_fleet() {
        // a 4-fleet round with workers 1 and 3 quarantined must price
        // exactly like the 2-fleet round over the surviving links — for
        // every pricing model (comm-only, staged, pipelined)
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 1.0, 1.0);
        let survivors = vec![fleet[0], fleet[2]];
        let up4 = [1_000_000u64, 77, 500_000, 77];
        let up2 = [1_000_000u64, 500_000];
        let comp4 = [0.25, 9.0, 1.0, 9.0];
        let comp2 = [0.25, 1.0];
        let down = 640_000u64;

        let mask = |mut acc: NetworkAccountant| {
            acc.set_worker_active(1, false);
            acc.set_worker_active(3, false);
            acc
        };

        let mut a4 = mask(NetworkAccountant::new(fleet.clone()));
        let mut a2 = NetworkAccountant::new(survivors.clone());
        assert_eq!(a4.round(&up4, down), a2.round(&up2, down));
        assert_eq!(a4.total_up_bits, a2.total_up_bits);
        assert_eq!(a4.total_down_bits, a2.total_down_bits);
        assert_eq!(a4.sim_time, a2.sim_time);

        let mut s4 = mask(NetworkAccountant::new(fleet.clone()));
        let mut s2 = NetworkAccountant::new(survivors.clone());
        assert_eq!(
            s4.round_staged(&up4, down, &comp4),
            s2.round_staged(&up2, down, &comp2)
        );

        let mut p4 = mask(NetworkAccountant::new(fleet));
        let mut p2 = NetworkAccountant::new(survivors);
        assert_eq!(
            p4.round_pipelined(&up4, down, &comp4, 4),
            p2.round_pipelined(&up2, down, &comp2, 4)
        );
    }

    #[test]
    fn quorum_prices_the_mth_fastest_arrival() {
        // latency-only spread so worker i's round time is exactly
        // (1 + i) * base_latency * 2 (up + down, no transfer time)
        let fleet = LinkModel::heterogeneous_fleet(
            4,
            LinkModel {
                up_bps: 1e9,
                down_bps: 1e9,
                latency: 0.01,
            },
            0.0,
            1.0,
        );
        let up = [0u64; 4];
        let mut barrier = NetworkAccountant::new(fleet.clone());
        let t_max = barrier.round(&up, 0);
        assert!((t_max - 0.08).abs() < 1e-12, "barrier round {t_max}");

        let mut q2 = NetworkAccountant::new(fleet.clone());
        q2.set_quorum(Some(2));
        let t2 = q2.round(&up, 0);
        // 2nd fastest of {0.02, 0.04, 0.06, 0.08}
        assert!((t2 - 0.04).abs() < 1e-12, "quorum-2 round {t2}");
        // traffic still accumulates over every participant: the tail
        // workers' frames were in flight (and are folded stale later)
        barrier.round(&[1_000, 2_000, 3_000, 4_000], 640);
        q2.round(&[1_000, 2_000, 3_000, 4_000], 640);
        assert_eq!(q2.total_down_bits, barrier.total_down_bits);
        assert_eq!(q2.total_up_bits, barrier.total_up_bits);

        // m = n degenerates to the barrier max
        let mut qn = NetworkAccountant::new(fleet);
        qn.set_quorum(Some(4));
        assert_eq!(qn.round(&up, 0), t_max);
    }

    #[test]
    fn quorum_order_statistic_ignores_masked_workers() {
        // quarantine the slowest worker: quorum 2 is now the 2nd fastest
        // of the three survivors
        let fleet = LinkModel::heterogeneous_fleet(
            4,
            LinkModel {
                up_bps: 1e9,
                down_bps: 1e9,
                latency: 0.01,
            },
            0.0,
            1.0,
        );
        let mut acc = NetworkAccountant::new(fleet);
        acc.set_quorum(Some(3));
        acc.set_worker_active(3, false);
        let t = acc.round(&[0; 4], 0);
        // participants {0.02, 0.04, 0.06}; 3rd fastest = 0.06
        assert!((t - 0.06).abs() < 1e-12, "masked quorum round {t}");
    }

    #[test]
    fn one_shot_round_mask_prices_like_the_smaller_fleet_then_clears() {
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 1.0, 1.0);
        let survivors = vec![fleet[0], fleet[2]];
        let up4 = [1_000_000u64, 77, 500_000, 77];
        let up2 = [1_000_000u64, 500_000];
        let comp4 = [0.25, 9.0, 1.0, 9.0];
        let comp2 = [0.25, 1.0];
        let down = 640_000u64;

        let mut m4 = NetworkAccountant::new(fleet.clone());
        let mut m2 = NetworkAccountant::new(survivors);
        m4.set_round_mask(&[true, false, true, false]);
        assert_eq!(
            m4.round_staged(&up4, down, &comp4),
            m2.round_staged(&up2, down, &comp2)
        );
        assert_eq!(m4.total_up_bits, m2.total_up_bits);
        assert_eq!(m4.total_down_bits, m2.total_down_bits);

        // the mask is one-shot: the next round prices the full fleet again
        let mut full = NetworkAccountant::new(fleet);
        let t_full = full.round_staged(&up4, down, &comp4);
        assert_eq!(m4.round_staged(&up4, down, &comp4), t_full);
    }

    #[test]
    fn round_mask_composes_with_sticky_quarantine() {
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 1.0, 1.0);
        let survivor = vec![fleet[2]];
        let mut acc = NetworkAccountant::new(fleet);
        acc.set_worker_active(0, false); // quarantined (sticky)
        acc.set_round_mask(&[true, false, true, false]); // sampled out (one round)
        let mut one = NetworkAccountant::new(survivor);
        assert_eq!(
            acc.round(&[9, 9, 500_000, 9], 640),
            one.round(&[500_000], 640)
        );
        assert_eq!(acc.total_up_bits, one.total_up_bits);
        assert_eq!(acc.total_down_bits, one.total_down_bits);
    }

    #[test]
    fn pipelined_round_overlaps_compute_with_uplink_transfer() {
        // latency-free link so the numbers are exact: down = 0.1 s,
        // up transfer x = 1.0 s, compute C = 1.0 s, τ = 4.
        let link = LinkModel {
            up_bps: 1e6,
            down_bps: 1e7,
            latency: 0.0,
        };
        let mut acc = NetworkAccountant::uniform(1, link);
        let t = acc.round_pipelined(&[1_000_000], 1_000_000, &[1.0], 4);
        // down + max(C + x/4, C/4 + x) = 0.1 + 1.25
        assert!((t - 1.35).abs() < 1e-12, "pipelined round {t}");
        // the staged (no-overlap) cost of the same round
        let mut seq = NetworkAccountant::uniform(1, link);
        let ts = seq.round_staged(&[1_000_000], 1_000_000, &[1.0]);
        assert!((ts - 2.1).abs() < 1e-12, "staged round {ts}");
        // one stage ⇒ nothing can overlap: pipelined == staged
        let mut one = NetworkAccountant::uniform(1, link);
        let t1 = one.round_pipelined(&[1_000_000], 1_000_000, &[1.0], 1);
        assert!((t1 - ts).abs() < 1e-12, "{t1} vs staged {ts}");
    }
}
