//! Simulated network model.
//!
//! The coordinator moves *real encoded bytes* between threads; this module
//! prices those bytes. Each worker↔master link has a bandwidth and latency;
//! a synchronous round costs the slowest worker's uplink plus the broadcast
//! ("the straggler defines the round"). This is what turns bit-accounting
//! into the simulated wall-clock series reported alongside the figures, and
//! what makes heterogeneous-compressor experiments (slow links get more
//! aggressive compressors — §3.2.1's remark) meaningful.

/// One worker's link to the master.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// uplink bandwidth, bits/second
    pub up_bps: f64,
    /// downlink bandwidth, bits/second
    pub down_bps: f64,
    /// one-way latency, seconds
    pub latency: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 100 Mbit/s symmetric, 1 ms — a commodity datacenter link.
        Self {
            up_bps: 100e6,
            down_bps: 100e6,
            latency: 1e-3,
        }
    }
}

impl LinkModel {
    pub fn uplink_time(&self, bits: u64) -> f64 {
        self.latency + bits as f64 / self.up_bps
    }
    pub fn downlink_time(&self, bits: u64) -> f64 {
        self.latency + bits as f64 / self.down_bps
    }

    /// A heterogeneous fleet: worker i gets bandwidth scaled by
    /// `1/(1 + i·spread)` — used by the heterogeneous example.
    pub fn heterogeneous_fleet(n: usize, base: LinkModel, spread: f64) -> Vec<LinkModel> {
        (0..n)
            .map(|i| LinkModel {
                up_bps: base.up_bps / (1.0 + i as f64 * spread),
                down_bps: base.down_bps / (1.0 + i as f64 * spread),
                latency: base.latency * (1.0 + i as f64 * spread),
            })
            .collect()
    }
}

/// Accumulates the simulated time and traffic of a run.
#[derive(Clone, Debug, Default)]
pub struct NetworkAccountant {
    pub links: Vec<LinkModel>,
    pub total_up_bits: u64,
    pub total_down_bits: u64,
    pub sim_time: f64,
    pub rounds: usize,
}

impl NetworkAccountant {
    pub fn new(links: Vec<LinkModel>) -> Self {
        Self {
            links,
            ..Default::default()
        }
    }

    pub fn uniform(n: usize, link: LinkModel) -> Self {
        Self::new(vec![link; n])
    }

    /// Price one synchronous round: `up_bits[i]` is worker i's uplink
    /// payload, `down_bits` the per-worker broadcast size. Returns the
    /// round's wall-clock contribution.
    pub fn round(&mut self, up_bits: &[u64], down_bits: u64) -> f64 {
        assert_eq!(up_bits.len(), self.links.len());
        let mut slowest: f64 = 0.0;
        for (bits, link) in up_bits.iter().zip(self.links.iter()) {
            let t = link.uplink_time(*bits) + link.downlink_time(down_bits);
            slowest = slowest.max(t);
            self.total_up_bits += bits;
        }
        self.total_down_bits += down_bits * self.links.len() as u64;
        self.sim_time += slowest;
        self.rounds += 1;
        slowest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times() {
        let l = LinkModel {
            up_bps: 1e6,
            down_bps: 2e6,
            latency: 0.01,
        };
        assert!((l.uplink_time(1_000_000) - 1.01).abs() < 1e-12);
        assert!((l.downlink_time(1_000_000) - 0.51).abs() < 1e-12);
    }

    #[test]
    fn straggler_defines_round() {
        let fast = LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        let slow = LinkModel {
            up_bps: 1e3,
            down_bps: 1e9,
            latency: 0.0,
        };
        let mut acc = NetworkAccountant::new(vec![fast, slow]);
        let t = acc.round(&[1_000, 1_000], 0);
        assert!((t - 1.0).abs() < 1e-6, "slow link dominates: {t}");
        assert_eq!(acc.total_up_bits, 2_000);
    }

    #[test]
    fn accumulates_over_rounds() {
        let mut acc = NetworkAccountant::uniform(3, LinkModel::default());
        acc.round(&[100, 200, 300], 640);
        acc.round(&[100, 200, 300], 640);
        assert_eq!(acc.rounds, 2);
        assert_eq!(acc.total_up_bits, 1200);
        assert_eq!(acc.total_down_bits, 2 * 640 * 3);
        assert!(acc.sim_time > 0.0);
    }

    #[test]
    fn heterogeneous_fleet_degrades() {
        let fleet = LinkModel::heterogeneous_fleet(4, LinkModel::default(), 1.0);
        assert!(fleet[0].up_bps > fleet[3].up_bps * 3.0);
        assert!(fleet[3].latency > fleet[0].latency * 3.0);
    }
}
