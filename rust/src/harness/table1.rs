//! Table 1 regeneration: theoretical iteration complexities (paper formulas)
//! side by side with *measured* rounds-to-ε for every method, on the
//! paper's ridge problem.

use crate::algorithms::{Algorithm, DcgdShift, Gdci, RunOpts, VrGdci};
use crate::compressors::{Compressor, RandK};
use crate::problems::{Problem, Ridge};
use crate::theory;

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    /// Õ-complexity from our theorems (paper Table 1, "Our result")
    pub theory_ours: f64,
    /// best previously known (NaN for new methods)
    pub theory_prev: f64,
    /// measured rounds to reach ε (None: hit the neighborhood floor first)
    pub measured_rounds: Option<usize>,
    /// the error floor actually reached
    pub floor: f64,
}

/// Regenerate Table 1 on ridge (m=100, d=80, n=10) with Rand-K(q).
pub fn table1(seed: u64, q: f64, eps: f64, max_rounds: usize) -> Vec<Table1Row> {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let n = p.n_workers();
    let omega = RandK::with_q(d, q).omega().unwrap();
    let kappa = p.kappa();
    let delta = 0.0; // C_i = 0 in the measured configuration
    let p_refresh = theory::rand_diana_default_p(omega);
    let formulas = theory::table1_complexities(kappa, omega, delta, p_refresh, n);
    let theory_of = |name: &str| {
        formulas
            .iter()
            .find(|(f_name, _)| *f_name == name)
            .map(|(_, c)| *c)
            .unwrap()
    };

    let opts = RunOpts {
        max_rounds,
        tol: eps,
        record_every: 5,
        ..Default::default()
    };

    let mut rows = Vec::new();
    let mut push = |method: &str, theory_name: &str, trace: crate::metrics::Trace| {
        let c = theory_of(theory_name);
        rows.push(Table1Row {
            method: method.to_string(),
            theory_ours: c.ours,
            theory_prev: c.previous,
            measured_rounds: trace.rounds_to_tol(eps),
            floor: trace.error_floor(),
        });
    };

    push(
        "DCGD (zero fixed shift)",
        "DCGD-FIXED",
        DcgdShift::dcgd(&p, RandK::with_q(d, q), seed).run(&p, &opts),
    );
    push(
        "DCGD-STAR",
        "DCGD-STAR",
        DcgdShift::star(&p, RandK::with_q(d, q), None, seed).run(&p, &opts),
    );
    push(
        "DIANA",
        "DIANA",
        DcgdShift::diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts),
    );
    push(
        "RAND-DIANA",
        "RAND-DIANA",
        DcgdShift::rand_diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts),
    );
    push(
        "GDCI",
        "GDCI",
        Gdci::new(&p, RandK::with_q(d, q), seed).run(&p, &opts),
    );
    push(
        "VR-GDCI",
        "GDCI",
        VrGdci::new(&p, RandK::with_q(d, q), seed).run(&p, &opts),
    );
    rows
}

pub fn render(rows: &[Table1Row], eps: f64) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Table 1 — iteration complexities (theory, Õ) and measured rounds to ε = {eps:.0e}\n"
    ));
    s.push_str(&format!(
        "{:<26} {:>14} {:>14} {:>12} {:>12}\n",
        "method", "theory (ours)", "theory (prev)", "measured", "floor"
    ));
    for r in rows {
        let prev = if r.theory_prev.is_nan() {
            "—".to_string()
        } else {
            format!("{:.0}", r.theory_prev)
        };
        let measured = r
            .measured_rounds
            .map(|m| m.to_string())
            .unwrap_or_else(|| "neighborhood".into());
        s.push_str(&format!(
            "{:<26} {:>14.0} {:>14} {:>12} {:>12.2e}\n",
            r.method, r.theory_ours, prev, measured, r.floor
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_reflect_paper_shape() {
        // moderate budget: checks ordering, not deep convergence
        let rows = table1(1, 0.5, 1e-8, 60_000);
        assert_eq!(rows.len(), 6);
        let get = |m: &str| rows.iter().find(|r| r.method.starts_with(m)).unwrap();
        // DCGD stalls in a neighborhood above ε or converges slower than
        // the VR methods; VR methods must actually reach ε.
        assert!(get("DIANA").measured_rounds.is_some(), "{rows:?}");
        assert!(get("RAND-DIANA").measured_rounds.is_some());
        assert!(get("DCGD-STAR").measured_rounds.is_some());
        assert!(get("VR-GDCI").measured_rounds.is_some());
        // our GDCI theory improves on the previous by ~κ
        let g = get("GDCI");
        assert!(g.theory_prev / g.theory_ours > 10.0);
    }
}
