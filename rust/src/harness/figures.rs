//! Figure regeneration drivers (paper Figures 1–4 + the GDCI ablation).
//!
//! Axes follow the paper: y = log10 of the relative squared argument error
//! `‖x^k − x*‖²/‖x⁰ − x*‖²`, x = cumulative communicated bits (worker →
//! master payload).

use crate::algorithms::{Algorithm, DcgdShift, Gdci, RunOpts, VrGdci};
use crate::compressors::{Compressor, NaturalDithering, RandK};
use crate::metrics::{AsciiPlot, Trace};
use crate::problems::{Logistic, Problem, Ridge};
use crate::theory;

/// Summary of one curve, for shape assertions.
#[derive(Clone, Debug)]
pub struct CurveSummary {
    pub label: String,
    /// total uplink (gradient messages + shift refreshes)
    pub bits_to_tol: Option<u64>,
    /// gradient messages only (the paper's Figure-1 convention)
    pub bits_msg_to_tol: Option<u64>,
    pub rounds_to_tol: Option<usize>,
    pub error_floor: f64,
    pub diverged: bool,
}

#[derive(Clone, Debug, Default)]
pub struct FigureResult {
    pub name: String,
    pub curves: Vec<CurveSummary>,
}

impl FigureResult {
    pub fn curve(&self, label: &str) -> &CurveSummary {
        self.curves
            .iter()
            .find(|c| c.label == label)
            .unwrap_or_else(|| panic!("no curve '{label}' in {}", self.name))
    }
}

fn record(
    name: &str,
    out_dir: &str,
    plot: &mut AsciiPlot,
    curves: &mut Vec<CurveSummary>,
    label: &str,
    trace: &Trace,
    tol: f64,
) {
    let path = format!("{out_dir}/{name}_{}.csv", label.replace(['/', ' '], "_"));
    trace.save_csv(&path).expect("writing results CSV");
    plot.add_series(label, trace.bits_log_err());
    curves.push(CurveSummary {
        label: label.to_string(),
        bits_to_tol: trace.bits_to_tol(tol),
        bits_msg_to_tol: trace.bits_to_tol_messages_only(tol),
        rounds_to_tol: trace.rounds_to_tol(tol),
        error_floor: trace.error_floor(),
        diverged: trace.diverged,
    });
}

fn finish(name: &str, plot: AsciiPlot, curves: Vec<CurveSummary>) -> FigureResult {
    println!("{}", plot.render());
    FigureResult {
        name: name.to_string(),
        curves,
    }
}

// ---------------------------------------------------------------- Figure 1L

/// Figure 1 (left): DIANA vs Rand-DIANA with Rand-K, q ∈ {0.1, 0.5, 0.9},
/// on the paper's ridge problem. `p = 1/(ω+1)` for every Rand-DIANA run.
pub fn fig1_left(out_dir: &str, seed: u64, max_rounds: usize) -> FigureResult {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let tol = 1e-10;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 10,
        ..Default::default()
    };
    let mut plot = AsciiPlot::new(
        "Figure 1 (left): DIANA vs Rand-DIANA, Rand-K",
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();
    for &q in &[0.1, 0.5, 0.9] {
        let trace = DcgdShift::diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts);
        record("fig1_left", out_dir, &mut plot, &mut curves, &format!("diana q={q}"), &trace, tol);
        let trace = DcgdShift::rand_diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts);
        record(
            "fig1_left",
            out_dir,
            &mut plot,
            &mut curves,
            &format!("rand-diana q={q}"),
            &trace,
            tol,
        );
    }
    finish("fig1_left", plot, curves)
}

// ---------------------------------------------------------------- Figure 1R

/// Figure 1 (right): Natural Dithering — grid search s ∈ {2..20} for each
/// method, plot each method's best-s curve plus the aggressive s=2 curves.
pub fn fig1_right(out_dir: &str, seed: u64, max_rounds: usize) -> FigureResult {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let tol = 1e-10;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 10,
        ..Default::default()
    };
    let mut plot = AsciiPlot::new(
        "Figure 1 (right): DIANA vs Rand-DIANA, Natural Dithering (s grid search)",
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();

    // grid search: bits to reach a coarser tolerance decides the winner
    let search_tol = 1e-8;
    let mut best: [(u8, u64); 2] = [(0, u64::MAX), (0, u64::MAX)];
    let mut traces: Vec<(usize, u8, Trace)> = Vec::new();
    for s in 2..=20u8 {
        let nd = NaturalDithering::l2(d, s);
        let t0 = DcgdShift::diana(&p, nd.clone(), None, seed).run(&p, &opts);
        let t1 = DcgdShift::rand_diana(&p, nd, None, seed).run(&p, &opts);
        for (mi, t) in [(0usize, t0), (1usize, t1)] {
            let score = t.bits_to_tol(search_tol).unwrap_or(u64::MAX);
            if score < best[mi].1 {
                best[mi] = (s, score);
            }
            traces.push((mi, s, t));
        }
    }
    let names = ["diana", "rand-diana"];
    for (mi, s, t) in &traces {
        let is_best = best[*mi].0 == *s;
        if is_best || *s == 2 {
            let tag = if is_best { "s*" } else { "s" };
            record(
                "fig1_right",
                out_dir,
                &mut plot,
                &mut curves,
                &format!("{} {tag}={s}", names[*mi]),
                t,
                tol,
            );
        }
    }
    finish("fig1_right", plot, curves)
}

// ---------------------------------------------------------------- Figure 2L

/// Figure 2 (left): Rand-DIANA stability in the Lyapunov constant
/// `M = b·M'`, `M' = 2ω/(np)` — b < 1 destabilizes/diverges, b = 1.5 is
/// stable but slower (the paper's exact claim).
///
/// M enters the *algorithm* only through the step size `γ(M)` of Theorem 4,
/// so the study runs the γ(b·M') family at a fixed practical
/// aggressiveness factor `c = 12` (the largest multiple at which the
/// recommended `M = 2M'` configuration retains a comfortable margin on
/// this problem; at `c = 1` the theorem's sufficient condition keeps every
/// b stable — see EXPERIMENTS.md §Fig2).
pub fn fig2_left(out_dir: &str, seed: u64, max_rounds: usize) -> FigureResult {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let q = 0.1; // high compression (ω = 9): where the M-condition bites
    let aggressiveness = 12.0;
    let tol = 1e-10;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 10,
        blowup: 1e6,
        ..Default::default()
    };
    let mut plot = AsciiPlot::new(
        "Figure 2 (left): Rand-DIANA, M = b·M' stability (Rand-K q=0.1, γ = 12·γ_thm(M))",
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();
    let omega = RandK::with_q(d, q).omega().unwrap();
    let pr = theory::rand_diana_default_p(omega);
    let n = p.n_workers();
    let m_prime = 2.0 * omega / (n as f64 * pr);
    for &b in &[0.1, 0.5, 1.0, 1.5] {
        let m = b * m_prime;
        let ss = theory::rand_diana(&p, omega, &vec![pr; n], Some(m));
        let mut alg =
            DcgdShift::rand_diana_with_m(&p, RandK::with_q(d, q), Some(pr), Some(m), seed);
        alg.set_gamma(ss.gamma * aggressiveness);
        let trace = alg.run(&p, &opts);
        record("fig2_left", out_dir, &mut plot, &mut curves, &format!("b={b}"), &trace, tol);
    }
    finish("fig2_left", plot, curves)
}

// ---------------------------------------------------------------- Figure 2R

/// Figure 2 (right): Rand-DIANA p sweep at high compression (q = 0.1),
/// with (γ, M) *fixed at the reference p* = 1/(ω+1)* — smaller p converges
/// in fewer bits; pushing p far above the reference destabilizes.
pub fn fig2_right(out_dir: &str, seed: u64, max_rounds: usize) -> FigureResult {
    fig_p_sweep("fig2_right", out_dir, seed, max_rounds, 0.1)
}

fn fig_p_sweep(
    name: &str,
    out_dir: &str,
    seed: u64,
    max_rounds: usize,
    q: f64,
) -> FigureResult {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let tol = 1e-10;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 10,
        blowup: 1e6,
        ..Default::default()
    };
    let mut plot = AsciiPlot::new(
        &format!("{name}: Rand-DIANA p sweep (Rand-K q={q}, steps fixed at p*)"),
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();
    let omega = RandK::with_q(d, q).omega().unwrap();
    let p_star = theory::rand_diana_default_p(omega);
    let n = p.n_workers() as f64;
    // step sizes frozen at the reference p*
    let ss_ref = theory::rand_diana(&p, omega, &vec![p_star; p.n_workers()], None);
    for &mult in &[0.25, 0.5, 1.0, 2.0, 6.0] {
        let pr = (p_star * mult).min(1.0);
        let mut alg = DcgdShift::rand_diana_with_m(
            &p,
            RandK::with_q(d, q),
            Some(pr),
            Some(4.0 * omega / (n * p_star)), // M from p*, not pr
            seed,
        );
        alg.set_gamma(ss_ref.gamma);
        let trace = alg.run(&p, &opts);
        record(name, out_dir, &mut plot, &mut curves, &format!("p={pr:.4}"), &trace, tol);
    }
    finish(name, plot, curves)
}

// ------------------------------------------------------------------ Figure 3

/// Figure 3 (supplementary): the p sweep across several Rand-K q values.
pub fn fig3(out_dir: &str, seed: u64, max_rounds: usize) -> Vec<FigureResult> {
    [0.2, 0.5, 0.8]
        .iter()
        .map(|&q| fig_p_sweep(&format!("fig3_q{q}"), out_dir, seed, max_rounds, q))
        .collect()
}

// ------------------------------------------------------------------ Figure 4

/// Figure 4 (supplementary): DIANA vs Rand-DIANA on ℓ2-logistic regression
/// (w2a-like dataset, κ = 100). Left: Rand-K q sweep; right: ND s ∈ {2, s*}.
pub fn fig4(out_dir: &str, seed: u64, max_rounds: usize) -> (FigureResult, FigureResult) {
    let p = Logistic::w2a_default(10, seed);
    let d = p.dim();
    let tol = 1e-10;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 10,
        ..Default::default()
    };

    // left: Rand-K
    let mut plot = AsciiPlot::new(
        "Figure 4 (left): logistic w2a — DIANA vs Rand-DIANA, Rand-K",
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();
    for &q in &[0.1, 0.5, 0.9] {
        let trace = DcgdShift::diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts);
        record("fig4_left", out_dir, &mut plot, &mut curves, &format!("diana q={q}"), &trace, tol);
        let trace = DcgdShift::rand_diana(&p, RandK::with_q(d, q), None, seed).run(&p, &opts);
        record(
            "fig4_left",
            out_dir,
            &mut plot,
            &mut curves,
            &format!("rand-diana q={q}"),
            &trace,
            tol,
        );
    }
    let left = finish("fig4_left", plot, curves);

    // right: ND grid search (coarser grid than fig1 to bound runtime)
    let mut plot = AsciiPlot::new(
        "Figure 4 (right): logistic w2a — Natural Dithering",
        "communicated bits",
        "log10 rel err",
    );
    let mut curves = Vec::new();
    let search_tol = 1e-8;
    let mut best: [(u8, u64); 2] = [(0, u64::MAX), (0, u64::MAX)];
    let mut traces: Vec<(usize, u8, Trace)> = Vec::new();
    for s in [2u8, 4, 6, 8, 12, 16, 20] {
        let nd = NaturalDithering::l2(d, s);
        let t0 = DcgdShift::diana(&p, nd.clone(), None, seed).run(&p, &opts);
        let t1 = DcgdShift::rand_diana(&p, nd, None, seed).run(&p, &opts);
        for (mi, t) in [(0usize, t0), (1usize, t1)] {
            let score = t.bits_to_tol(search_tol).unwrap_or(u64::MAX);
            if score < best[mi].1 {
                best[mi] = (s, score);
            }
            traces.push((mi, s, t));
        }
    }
    let names = ["diana", "rand-diana"];
    for (mi, s, t) in &traces {
        let is_best = best[*mi].0 == *s;
        if is_best || *s == 2 {
            let tag = if is_best { "s*" } else { "s" };
            record(
                "fig4_right",
                out_dir,
                &mut plot,
                &mut curves,
                &format!("{} {tag}={s}", names[*mi]),
                t,
                tol,
            );
        }
    }
    let right = finish("fig4_right", plot, curves);
    (left, right)
}

// ------------------------------------------------------------ GDCI ablation

/// Compressed iterates: GDCI converges to a neighborhood; VR-GDCI to the
/// exact optimum; our Theorem-5 step sizes vs the original Chraibi-et-al
/// rate (κ² → κ improvement).
pub fn gdci_ablation(out_dir: &str, seed: u64, max_rounds: usize) -> FigureResult {
    let p = Ridge::paper_default(seed);
    let d = p.dim();
    let q = 0.5;
    let tol = 1e-16;
    let opts = RunOpts {
        max_rounds,
        tol,
        record_every: 20,
        ..Default::default()
    };
    let mut plot = AsciiPlot::new(
        "GDCI ablation: ours vs Chraibi-et-al steps vs VR-GDCI (Rand-K q=0.5)",
        "rounds",
        "log10 rel err",
    );
    let mut curves = Vec::new();

    let mut runs: Vec<(&str, Trace)> = vec![
        ("gdci (thm 5)", Gdci::new(&p, RandK::with_q(d, q), seed).run(&p, &opts)),
        (
            "gdci (chraibi)",
            Gdci::new_chraibi(&p, RandK::with_q(d, q), seed).run(&p, &opts),
        ),
        ("vr-gdci (thm 6)", VrGdci::new(&p, RandK::with_q(d, q), seed).run(&p, &opts)),
    ];
    for (label, trace) in runs.drain(..) {
        let path = format!("{out_dir}/gdci_{}.csv", label.replace([' ', '(', ')'], ""));
        trace.save_csv(&path).expect("writing results CSV");
        plot.add_series(
            label,
            trace
                .records
                .iter()
                .map(|r| (r.round as f64, r.rel_err.max(1e-300).log10()))
                .collect(),
        );
        curves.push(CurveSummary {
            label: label.to_string(),
            bits_to_tol: trace.bits_to_tol(1e-8),
            bits_msg_to_tol: trace.bits_to_tol_messages_only(1e-8),
            rounds_to_tol: trace.rounds_to_tol(1e-8),
            error_floor: trace.error_floor(),
            diverged: trace.diverged,
        });
    }
    finish("gdci", plot, curves)
}
