//! Experiment harness: one driver per paper table/figure, shared by the
//! `cargo bench` targets and the `shiftcomp` CLI.
//!
//! Every driver writes CSVs under `results/` and renders an ASCII plot, and
//! returns a structured summary so benches/tests can assert the *shape* of
//! the result (who wins, by roughly what factor) — see DESIGN.md §5.

pub mod cli;
pub mod figures;
pub mod table1;

pub use cli::cli_main;
pub use figures::{
    fig1_left, fig1_right, fig2_left, fig2_right, fig3, fig4, gdci_ablation, CurveSummary,
    FigureResult,
};
pub use table1::{table1, Table1Row};
