//! `shiftcomp` CLI dispatch.

use crate::config::ExperimentConfig;
use crate::util::cli::Command;

const TOP_USAGE: &str = "\
shiftcomp — Shifted Compression Framework (Shulgin & Richtárik, UAI 2022)

USAGE:
  shiftcomp <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  run       run one experiment from a JSON config
  figure    regenerate a paper figure (1, 2, 3, 4, gdci) into results/
  table     regenerate Table 1 (theory vs measured)
  train-lm  distributed compressed training of the transformer LM
  list      list algorithms / compressors / shift rules (paper Table 2)
  help      show this message
";

pub fn cli_main(argv: &[String]) -> i32 {
    match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("figure") => cmd_figure(&argv[1..]),
        Some("table") => cmd_table(&argv[1..]),
        Some("train-lm") => cmd_train_lm(&argv[1..]),
        Some("list") => cmd_list(),
        Some("help") | Some("--help") | Some("-h") | None => {
            println!("{TOP_USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n\n{TOP_USAGE}");
            2
        }
    }
}

fn cmd_run(argv: &[String]) -> i32 {
    let cmd = Command::new("run", "run one experiment from a JSON config")
        .required("config", "path to the experiment JSON")
        .opt("out", "", "write the trace CSV here");
    let parsed = match cmd.parse(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let cfg_path = parsed.get("config").unwrap();
    let cfg = match ExperimentConfig::load(cfg_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    match cfg.execute() {
        Ok(trace) => {
            println!(
                "{} [{}]: {} rounds, final rel err {:.3e}, uplink {} bits{}{}",
                trace.algorithm,
                trace.compressor,
                trace.rounds(),
                trace.final_relative_error(),
                trace.total_bits_up(),
                if trace.converged { ", converged" } else { "" },
                if trace.diverged { ", DIVERGED" } else { "" },
            );
            if let Some(out) = parsed.get("out") {
                if !out.is_empty() {
                    if let Err(e) = trace.save_csv(out) {
                        eprintln!("writing {out}: {e}");
                        return 1;
                    }
                    println!("trace written to {out}");
                }
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_figure(argv: &[String]) -> i32 {
    let cmd = Command::new("figure", "regenerate a paper figure")
        .positional("which", "1 | 2 | 3 | 4 | gdci")
        .opt("out-dir", "results", "output directory for CSVs")
        .opt("seed", "42", "experiment seed")
        .opt("rounds", "40000", "max rounds per curve");
    let parsed = match cmd.parse(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let out = parsed.get("out-dir").unwrap().to_string();
    let seed = parsed.get_u64("seed").unwrap_or(42);
    let rounds = parsed.get_usize("rounds").unwrap_or(40_000);
    match parsed.positional("which") {
        Some("1") => {
            crate::harness::fig1_left(&out, seed, rounds);
            crate::harness::fig1_right(&out, seed, rounds);
        }
        Some("2") => {
            crate::harness::fig2_left(&out, seed, rounds);
            crate::harness::fig2_right(&out, seed, rounds);
        }
        Some("3") => {
            crate::harness::fig3(&out, seed, rounds);
        }
        Some("4") => {
            crate::harness::fig4(&out, seed, rounds);
        }
        Some("gdci") => {
            crate::harness::gdci_ablation(&out, seed, rounds);
        }
        other => {
            eprintln!("figure must be 1|2|3|4|gdci, got {other:?}");
            return 2;
        }
    }
    0
}

fn cmd_table(argv: &[String]) -> i32 {
    let cmd = Command::new("table", "regenerate Table 1")
        .opt("seed", "42", "experiment seed")
        .opt("q", "0.5", "Rand-K share q = K/d")
        .opt("eps", "1e-6", "target relative error")
        .opt("rounds", "60000", "max rounds per method");
    let parsed = match cmd.parse(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let rows = crate::harness::table1(
        parsed.get_u64("seed").unwrap_or(42),
        parsed.get_f64("q").unwrap_or(0.5),
        parsed.get_f64("eps").unwrap_or(1e-6),
        parsed.get_usize("rounds").unwrap_or(60_000),
    );
    print!("{}", crate::harness::table1::render(&rows, 1e-6));
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_lm(_argv: &[String]) -> i32 {
    eprintln!("train-lm requires the PJRT runtime: rebuild with `--features pjrt`");
    2
}

#[cfg(feature = "pjrt")]
fn cmd_train_lm(argv: &[String]) -> i32 {
    let cmd = Command::new("train-lm", "distributed compressed LM training")
        .opt("artifacts", "artifacts", "AOT artifacts directory")
        .opt("workers", "4", "number of workers")
        .opt("rounds", "300", "training rounds")
        .opt("q", "0.05", "Rand-K share for gradient compression")
        .opt("lr", "0.25", "learning rate")
        .opt("seed", "0", "seed");
    let parsed = match cmd.parse(argv) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let artifacts = parsed.get("artifacts").unwrap();
    let engine = match crate::runtime::Engine::cpu(artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e}\n(run `make artifacts` first)");
            return 1;
        }
    };
    let opts = crate::lm::LmTrainOpts {
        n_workers: parsed.get_usize("workers").unwrap_or(4),
        rounds: parsed.get_usize("rounds").unwrap_or(300),
        lr: parsed.get_f64("lr").unwrap_or(0.1),
        seed: parsed.get_u64("seed").unwrap_or(0),
        ..Default::default()
    };
    let q = parsed.get_f64("q").unwrap_or(0.05);
    let corpus = crate::lm::MarkovCorpus::new(512, 4, 0.9, opts.seed);
    let mut trainer = match crate::lm::LmTrainer::new(
        &engine,
        corpus,
        |p| Box::new(crate::compressors::RandK::with_q(p, q)),
        opts,
    ) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "training {}-param LM, corpus entropy floor ≈ {:.3}",
        trainer.param_count(),
        trainer.entropy_floor()
    );
    match trainer.train() {
        Ok(history) => {
            let first = history.first().map(|l| l.mean_loss).unwrap_or(f64::NAN);
            let last = history.last().map(|l| l.mean_loss).unwrap_or(f64::NAN);
            println!("loss: {first:.4} → {last:.4}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_list() -> i32 {
    println!(
        "\
Algorithms (paper Table 2 — shift h_i^{{k+1}} = s_i^k + C_i(∇f_i(x^k) − s_i^k)):
  dgd         s=0,  C=I    VR  (folklore baseline, no compression)
  dcgd        s=0,  C=O    —   (Khirirat et al. 2018; Theorem 1 w/ h=0)
  dcgd-shift  s=h⁰, C=O    —   (this work, Theorem 1)
  dcgd-star   s=∇f_i(x*)   VR  (this work, Theorem 2)
  diana       s=h_i^k, C_i VR  (Mishchenko et al. 2019; Theorem 3 generalized)
  rand-diana  s=h_i^k, B_p VR  (this work, Theorem 4)
  gdci        iterate compression  (Theorem 5, improved κ²→κ)
  vr-gdci     iterate compression + learned shift (Theorem 6)

Compressors:
  unbiased U(ω): identity(0), rand-k(d/K−1), natural-dithering, standard-
                 dithering, natural-compression(1/8), bernoulli(1/p−1),
                 ternary(√d−1)
  biased B(δ):   top-k(K/d), sign-l1(1/d), zero(0)
  combinators:   induced C+Q(x−C(x)) ∈ U(ω(1−δ)), shifted h+Q(x−h), scaled αQ
"
    );
    0
}
