//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! `serde`/`serde_json` are unavailable in the offline build environment, so
//! the repository carries its own small JSON layer. It is used for three
//! things only — experiment configs, the AOT `artifacts/manifest.json`
//! emitted by `python/compile/aot.py`, and metrics dumps — and therefore
//! implements exactly the JSON subset those need: objects, arrays, strings
//! (with escapes), f64 numbers, booleans, null. Numbers are always parsed as
//! f64, which is lossless for every integer the manifest can contain
//! (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ writing
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                    } else {
                        let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                    }
                } else {
                    // JSON has no inf/nan; encode as null (metrics only).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs unsupported (never
                            // produced by our writers).
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").is_null());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"fig1","params":{"d":80,"q":[0.1,0.5,0.9]},"ok":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        let v2 = Json::parse(&compact).unwrap();
        assert_eq!(v, v2);
        let pretty = v.to_pretty();
        let v3 = Json::parse(&pretty).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{0001}".into());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(80.0).to_string(), "80");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn errors_carry_position() {
        let err = Json::parse("{\"a\": }").unwrap_err();
        assert!(err.pos >= 6, "{err}");
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("01x").is_err() || Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"αβγ δ\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "αβγ δ");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(Json::Num(1.0).get("x").is_null());
    }
}
