//! Micro-benchmark harness (criterion substitute).
//!
//! `cargo bench` targets in this repo are plain binaries (`harness = false`)
//! built on this module: warmup, multiple timed samples, robust statistics
//! (median + MAD), and human-readable + CSV reporting. Black-boxing is done
//! with `std::hint::black_box`.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub iters_per_sample: u64,
}

impl BenchStats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples.iter().map(|&x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = dev.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            dev[n / 2]
        } else {
            0.5 * (dev[n / 2 - 1] + dev[n / 2])
        }
    }

    pub fn report(&self) -> String {
        let med = self.median();
        format!(
            "{:<44} {:>12}/iter  (± {} MAD, {} samples × {} iters)",
            self.name,
            fmt_duration(med),
            fmt_duration(self.mad()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".into();
    }
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`sample_time` per sample, `n_samples` samples after `warmup` time.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_config(name, Duration::from_millis(150), 12, Duration::from_millis(200), &mut f)
}

/// Like [`bench`] but for slower bodies: fewer samples, shorter targets.
pub fn bench_slow<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_config(name, Duration::from_millis(300), 5, Duration::from_millis(100), &mut f)
}

pub fn bench_config<F: FnMut()>(
    name: &str,
    sample_time: Duration,
    n_samples: usize,
    warmup: Duration,
    f: &mut F,
) -> BenchStats {
    // Warmup
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < warmup {
        f();
        warm_iters += 1;
    }
    let per_iter = warmup.as_secs_f64() / warm_iters.max(1) as f64;
    let iters = ((sample_time.as_secs_f64() / per_iter.max(1e-9)).ceil() as u64).max(1);

    let mut samples = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let stats = BenchStats {
        name: name.to_string(),
        samples,
        iters_per_sample: iters,
    };
    println!("{}", stats.report());
    stats
}

/// Time a single run of a long-ish workload (used by figure benches, which
/// care about produced CSVs rather than ns-level timings).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = black_box(f());
    let dt = t0.elapsed().as_secs_f64();
    println!("{:<44} completed in {}", name, fmt_duration(dt));
    (out, dt)
}

/// Write bench results as a CSV file under `results/`.
pub fn write_csv(path: &str, header: &str, rows: &[String]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut body = String::with_capacity(rows.len() * 32 + header.len() + 1);
    body.push_str(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    std::fs::write(path, body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let s = BenchStats {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 10.0],
            iters_per_sample: 1,
        };
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.mad(), 1.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let stats = bench_config(
            "noop",
            Duration::from_millis(5),
            3,
            Duration::from_millis(5),
            &mut || {
                acc = acc.wrapping_add(bb(1));
            },
        );
        assert_eq!(stats.samples.len(), 3);
        assert!(stats.median() >= 0.0);
    }
}
