//! Micro-benchmark harness (criterion substitute).
//!
//! `cargo bench` targets in this repo are plain binaries (`harness = false`)
//! built on this module: warmup, multiple timed samples, robust statistics
//! (median + MAD), and human-readable + CSV reporting. Black-boxing is done
//! with `std::hint::black_box`.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub iters_per_sample: u64,
}

impl BenchStats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples.iter().map(|&x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = dev.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            dev[n / 2]
        } else {
            0.5 * (dev[n / 2 - 1] + dev[n / 2])
        }
    }

    pub fn report(&self) -> String {
        let med = self.median();
        format!(
            "{:<44} {:>12}/iter  (± {} MAD, {} samples × {} iters)",
            self.name,
            fmt_duration(med),
            fmt_duration(self.mad()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".into();
    }
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`sample_time` per sample, `n_samples` samples after `warmup` time.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_config(name, Duration::from_millis(150), 12, Duration::from_millis(200), &mut f)
}

/// Like [`bench`] but for slower bodies: fewer samples, shorter targets.
pub fn bench_slow<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_config(name, Duration::from_millis(300), 5, Duration::from_millis(100), &mut f)
}

pub fn bench_config<F: FnMut()>(
    name: &str,
    sample_time: Duration,
    n_samples: usize,
    warmup: Duration,
    f: &mut F,
) -> BenchStats {
    // Warmup
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < warmup {
        f();
        warm_iters += 1;
    }
    let per_iter = warmup.as_secs_f64() / warm_iters.max(1) as f64;
    let iters = ((sample_time.as_secs_f64() / per_iter.max(1e-9)).ceil() as u64).max(1);

    let mut samples = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let stats = BenchStats {
        name: name.to_string(),
        samples,
        iters_per_sample: iters,
    };
    println!("{}", stats.report());
    stats
}

/// Time a single run of a long-ish workload (used by figure benches, which
/// care about produced CSVs rather than ns-level timings).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = black_box(f());
    let dt = t0.elapsed().as_secs_f64();
    println!("{:<44} completed in {}", name, fmt_duration(dt));
    (out, dt)
}

/// Was the bench binary invoked with `--smoke`? Perf benches use this to
/// shrink dimensions and sample counts so they fit tier-1 time budgets
/// while still exercising every scenario end to end.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Like [`bench_slow`], but drops to a few milliseconds per sample when
/// `smoke` is set.
pub fn bench_maybe_smoke<F: FnMut()>(name: &str, smoke: bool, mut f: F) -> BenchStats {
    if smoke {
        bench_config(
            name,
            Duration::from_millis(10),
            3,
            Duration::from_millis(10),
            &mut f,
        )
    } else {
        bench_config(
            name,
            Duration::from_millis(300),
            5,
            Duration::from_millis(100),
            &mut f,
        )
    }
}

/// One scenario row of the machine-readable perf report.
#[derive(Clone, Debug)]
pub struct JsonScenario {
    pub scenario: String,
    pub median_sec: f64,
    /// aggregate throughput, when the scenario has a natural coordinate
    /// count (used to track the sparse-aggregation win across PRs)
    pub coords_per_s: Option<f64>,
    /// measured broadcast cost, when the scenario drives the coordinator
    /// (tracks the delta-downlink win across PRs)
    pub down_bytes_per_round: Option<f64>,
    /// measured per-worker uplink payload bytes/round (tracks the EF
    /// uplink's O(K) guarantee across PRs)
    pub up_bytes_per_round: Option<f64>,
    /// simulated wall clock of the scenario's run, when it prices a
    /// `LinkModel` fleet (tracks the latency-amortization win across PRs —
    /// scenarios record it with and without pipelining as separate rows)
    pub sim_time_sec: Option<f64>,
    /// measured master-CPU seconds per round
    /// (`DistributedRunner::master_seconds`), when the scenario breaks the
    /// master's decode + fold out of the round wall-clock (tracks the
    /// parallel-fold win across PRs — scenarios record one row per
    /// fold-pool width T)
    pub master_secs: Option<f64>,
    /// resident fleet replica memory in bytes (`StepStats::replica_bytes`:
    /// the shared snapshot slots + published overlay + any per-worker
    /// private iterates), when the scenario tracks the shared
    /// copy-on-write replica's O(d) guarantee across fleet sizes
    pub replica_bytes: Option<f64>,
}

impl JsonScenario {
    pub fn new(scenario: impl Into<String>, median_sec: f64, coords_per_s: Option<f64>) -> Self {
        Self {
            scenario: scenario.into(),
            median_sec,
            coords_per_s,
            down_bytes_per_round: None,
            up_bytes_per_round: None,
            sim_time_sec: None,
            master_secs: None,
            replica_bytes: None,
        }
    }

    /// Attach the measured per-worker downlink bytes/round.
    pub fn with_down_bytes(mut self, bytes_per_round: f64) -> Self {
        self.down_bytes_per_round = Some(bytes_per_round);
        self
    }

    /// Attach the measured per-worker uplink payload bytes/round.
    pub fn with_up_bytes(mut self, bytes_per_round: f64) -> Self {
        self.up_bytes_per_round = Some(bytes_per_round);
        self
    }

    /// Attach the simulated wall clock (`NetworkAccountant::sim_time`).
    pub fn with_sim_time(mut self, sim_time_sec: f64) -> Self {
        self.sim_time_sec = Some(sim_time_sec);
        self
    }

    /// Attach the measured master-CPU seconds per round.
    pub fn with_master_secs(mut self, master_secs: f64) -> Self {
        self.master_secs = Some(master_secs);
        self
    }

    /// Attach the resident fleet replica memory in bytes.
    pub fn with_replica_bytes(mut self, replica_bytes: f64) -> Self {
        self.replica_bytes = Some(replica_bytes);
        self
    }
}

/// Merge scenario rows into a JSON report (scenario → {median_sec,
/// coords_per_s}). Existing entries for other scenarios are preserved so
/// the perf benches can each contribute to one `results/BENCH_perf.json`
/// and the perf trajectory can be diffed across PRs.
pub fn write_bench_json(path: &str, rows: &[JsonScenario]) -> std::io::Result<()> {
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    let mut merged: BTreeMap<String, Json> = BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(Json::Obj(obj)) = Json::parse(&text) {
            merged = obj;
        }
    }
    for r in rows {
        let mut fields = vec![("median_sec", Json::num(r.median_sec))];
        if let Some(c) = r.coords_per_s {
            fields.push(("coords_per_s", Json::num(c)));
        }
        if let Some(b) = r.down_bytes_per_round {
            fields.push(("down_bytes_per_round", Json::num(b)));
        }
        if let Some(b) = r.up_bytes_per_round {
            fields.push(("up_bytes_per_round", Json::num(b)));
        }
        if let Some(t) = r.sim_time_sec {
            fields.push(("sim_time_sec", Json::num(t)));
        }
        if let Some(t) = r.master_secs {
            fields.push(("master_secs", Json::num(t)));
        }
        if let Some(b) = r.replica_bytes {
            fields.push(("replica_bytes", Json::num(b)));
        }
        merged.insert(r.scenario.clone(), Json::obj(fields));
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, Json::Obj(merged).to_pretty())
}

/// Write bench results as a CSV file under `results/`.
pub fn write_csv(path: &str, header: &str, rows: &[String]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut body = String::with_capacity(rows.len() * 32 + header.len() + 1);
    body.push_str(header);
    body.push('\n');
    for r in rows {
        body.push_str(r);
        body.push('\n');
    }
    std::fs::write(path, body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let s = BenchStats {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 10.0],
            iters_per_sample: 1,
        };
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.mad(), 1.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_json_merges_scenarios() {
        let dir = std::env::temp_dir().join("shiftcomp_bench_json");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH_perf.json");
        let path_s = path.to_str().unwrap();
        write_bench_json(
            path_s,
            &[JsonScenario::new("a", 0.5, Some(1e6))],
        )
        .unwrap();
        // second write adds a scenario and overwrites the first
        write_bench_json(
            path_s,
            &[
                JsonScenario::new("a", 0.25, Some(2e6)),
                JsonScenario::new("b", 1.5, None)
                    .with_down_bytes(512.0)
                    .with_sim_time(42.5)
                    .with_master_secs(0.125)
                    .with_replica_bytes(3.2e6),
            ],
        )
        .unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("a").get("median_sec").as_f64(), Some(0.25));
        assert_eq!(j.get("a").get("coords_per_s").as_f64(), Some(2e6));
        assert!(j.get("a").get("sim_time_sec").is_null());
        assert_eq!(j.get("b").get("median_sec").as_f64(), Some(1.5));
        assert!(j.get("b").get("coords_per_s").is_null());
        assert_eq!(j.get("b").get("down_bytes_per_round").as_f64(), Some(512.0));
        assert_eq!(j.get("b").get("sim_time_sec").as_f64(), Some(42.5));
        assert_eq!(j.get("b").get("master_secs").as_f64(), Some(0.125));
        assert!(j.get("a").get("master_secs").is_null());
        assert_eq!(j.get("b").get("replica_bytes").as_f64(), Some(3.2e6));
        assert!(j.get("a").get("replica_bytes").is_null());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let stats = bench_config(
            "noop",
            Duration::from_millis(5),
            3,
            Duration::from_millis(5),
            &mut || {
                acc = acc.wrapping_add(bb(1));
            },
        );
        assert_eq!(stats.samples.len(), 3);
        assert!(stats.median() >= 0.0);
    }
}
