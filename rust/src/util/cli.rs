//! Tiny declarative command-line parser (clap substitute for the offline
//! environment).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and subcommands. Generates `--help` text from declared specs.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
    pub positionals: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            args: Vec::new(),
            positionals: Vec::new(),
        }
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_bool: true,
        });
        self
    }
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_bool: false,
        });
        self
    }
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_bool: false,
        });
        self
    }
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push(ArgSpec {
            name,
            help,
            default: None,
            is_bool: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  shiftcomp {}", self.name, self.about, self.name);
        for p in &self.positionals {
            s.push_str(&format!(" <{}>", p.name));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for a in &self.args {
            let default = a
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", a.name, a.help, default));
        }
        for p in &self.positionals {
            s.push_str(&format!("  <{:<18}> {}\n", p.name, p.help));
        }
        s
    }

    /// Parse `argv` (not including the subcommand token itself).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut pos_values: Vec<String> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_bool {
                    values.insert(key, "true".into());
                } else if let Some(v) = inline_val {
                    values.insert(key, v);
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| format!("option --{key} needs a value"))?;
                    values.insert(key, v.clone());
                }
            } else {
                pos_values.push(tok.clone());
            }
            i += 1;
        }
        if pos_values.len() > self.positionals.len() {
            return Err(format!(
                "unexpected positional argument '{}'\n\n{}",
                pos_values[self.positionals.len()],
                self.usage()
            ));
        }
        // defaults
        for a in &self.args {
            if let Some(d) = a.default {
                values.entry(a.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        // required non-bool args without default must be present
        for a in &self.args {
            if !a.is_bool && a.default.is_none() && !values.contains_key(a.name) {
                return Err(format!("missing required option --{}\n\n{}", a.name, self.usage()));
            }
        }
        let mut positionals = BTreeMap::new();
        for (spec, v) in self.positionals.iter().zip(pos_values.iter()) {
            positionals.insert(spec.name.to_string(), v.clone());
        }
        Ok(Parsed {
            values,
            positionals,
        })
    }
}

#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    positionals: BTreeMap<String, String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn positional(&self, name: &str) -> Option<&str> {
        self.positionals.get(name).map(|s| s.as_str())
    }
    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name).map(|v| v == "true").unwrap_or(false)
    }
    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }
    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| format!("--{name}: {e}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run an experiment")
            .opt("steps", "100", "number of rounds")
            .opt("gamma", "0.1", "step size")
            .flag("verbose", "chatty output")
            .required("method", "algorithm name")
            .positional("config", "config path")
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let p = cmd()
            .parse(&argv(&["--method", "diana", "--steps=500", "--verbose", "cfg.json"]))
            .unwrap();
        assert_eq!(p.get("method"), Some("diana"));
        assert_eq!(p.get_usize("steps").unwrap(), 500);
        assert_eq!(p.get_f64("gamma").unwrap(), 0.1);
        assert!(p.get_bool("verbose"));
        assert_eq!(p.positional("config"), Some("cfg.json"));
    }

    #[test]
    fn missing_required_errors() {
        let err = cmd().parse(&argv(&["cfg.json"])).unwrap_err();
        assert!(err.contains("--method"), "{err}");
    }

    #[test]
    fn unknown_option_errors() {
        let err = cmd()
            .parse(&argv(&["--method", "x", "--bogus", "1"]))
            .unwrap_err();
        assert!(err.contains("bogus"));
    }

    #[test]
    fn f64_list() {
        let p = Command::new("t", "")
            .opt("qs", "0.1,0.5,0.9", "q values")
            .parse(&[])
            .unwrap();
        assert_eq!(p.get_f64_list("qs").unwrap(), vec![0.1, 0.5, 0.9]);
    }

    #[test]
    fn help_is_usage_error() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
    }
}
