//! Small self-contained substrates: RNG, JSON, CLI parsing, property
//! testing, and the micro-benchmark harness. These replace external crates
//! (`rand`, `serde_json`, `clap`, `proptest`, `criterion`) that are
//! unavailable in the offline build environment — see DESIGN.md
//! §Substitutions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest_lite;
pub mod rng;

/// Boxed dynamic error used by fallible I/O-ish paths (replaces `anyhow`,
/// unavailable in the offline build environment).
pub type AnyError = Box<dyn std::error::Error + Send + Sync + 'static>;

/// `Result` alias over [`AnyError`] (replaces `anyhow::Result`).
pub type AnyResult<T> = std::result::Result<T, AnyError>;

/// Construct an [`AnyError`] from a message (replaces `anyhow!`).
pub fn any_err(msg: impl Into<String>) -> AnyError {
    msg.into().into()
}
