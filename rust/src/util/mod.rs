//! Small self-contained substrates: RNG, JSON, CLI parsing, property
//! testing, and the micro-benchmark harness. These replace external crates
//! (`rand`, `serde_json`, `clap`, `proptest`, `criterion`) that are
//! unavailable in the offline build environment — see DESIGN.md
//! §Substitutions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest_lite;
pub mod rng;
