//! Self-contained pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so the repository ships
//! its own generators. Everything in the library that needs randomness
//! (compressor sampling, data generation, starting points, Rand-DIANA
//! reference-point refreshes, ...) goes through [`Pcg64`], a permuted
//! congruential generator (PCG-XSL-RR 128/64, O'Neill 2014). It is fast,
//! statistically solid for simulation purposes, and — critically for our
//! reproducibility story — fully deterministic across platforms given a seed.
//!
//! Seeding discipline: every experiment config carries one master `seed`;
//! per-worker / per-component streams are derived with [`Pcg64::stream`] so
//! that runs are reproducible regardless of thread scheduling.

thread_local! {
    /// Membership scratch for [`Pcg64::subset_into`] — lets repeated
    /// Rand-K sampling run without per-call heap allocation.
    static SUBSET_BITMAP: std::cell::RefCell<Vec<u64>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// SplitMix64: used to expand a small seed into full generator state.
/// (Steele, Lea & Flood 2014.)
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64 — the main generator.
///
/// 128-bit LCG state, 64-bit output via xorshift-low + random rotation.
/// Period 2^128 per stream; 2^127 distinct streams.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // stream selector; must be odd
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator on a distinct stream. Different `stream` values
    /// yield statistically independent sequences for the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let s1 = sm.next_u64();
        let mut smi = SplitMix64::new(stream ^ 0xda3e_39cb_94b9_5bdb);
        let i0 = smi.next_u64();
        let i1 = smi.next_u64();
        let mut g = Self {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: ((((i0 as u128) << 64) | i1 as u128) << 1) | 1,
        };
        // advance a couple of times to decorrelate from seeding
        g.next_u64();
        g.next_u64();
        g
    }

    /// Derive a new independent stream from this generator; used to hand
    /// deterministic sub-generators to workers/components.
    pub fn stream(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg64::with_stream(seed, tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Unbiased uniform integer in [0, n). Lemire's rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar discarded half not cached — the
    /// simplicity is worth more than the lost sample here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with i.i.d. N(0, 1).
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Vector of i.i.d. N(mu, sigma^2).
    pub fn normal_vec(&mut self, n: usize, mu: f64, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal_ms(mu, sigma)).collect()
    }

    /// Sample a uniformly random subset of `{0, .., n-1}` of size `k`,
    /// returned **sorted**. Robert Floyd's algorithm: O(k) expected time,
    /// no allocation proportional to n.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(k);
        self.subset_into(n, k, &mut out);
        out
    }

    /// Allocation-free variant of [`subset`](Self::subset): the result is
    /// written into `out` (cleared first), reusing its capacity. Membership
    /// scratch lives in a thread-local bitmap, so steady-state sampling
    /// performs no heap allocation. Draws from the generator in exactly the
    /// same sequence as `subset`.
    pub fn subset_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        assert!(k <= n, "subset size {k} exceeds universe {n}");
        out.clear();
        // For k close to n a Fisher–Yates prefix is cheaper and avoids the
        // membership bitmap; cutoff chosen empirically.
        if k * 4 >= n * 3 {
            out.extend(0..n as u32);
            for i in 0..k {
                let j = i + self.below((n - i) as u64) as usize;
                out.swap(i, j);
            }
            out.truncate(k);
            out.sort_unstable();
            return;
        }
        // Membership via a u64 bitmap: zeroing ⌈n/64⌉ words is far cheaper
        // than hashing k inserts (§Perf: ~10× on d=100k Rand-K sampling).
        SUBSET_BITMAP.with(|bm| {
            let mut bitmap = bm.borrow_mut();
            bitmap.clear();
            bitmap.resize((n + 63) / 64, 0u64);
            let mut set = |bm: &mut [u64], i: u32| -> bool {
                let (w, b) = ((i / 64) as usize, i % 64);
                let hit = bm[w] & (1 << b) != 0;
                bm[w] |= 1 << b;
                !hit
            };
            for j in (n - k)..n {
                let t = self.below((j + 1) as u64) as u32;
                if set(&mut bitmap, t) {
                    out.push(t);
                } else {
                    set(&mut bitmap, j as u32);
                    out.push(j as u32);
                }
            }
        });
        out.sort_unstable();
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_are_independent() {
        let mut root = Pcg64::new(7);
        let mut s1 = root.stream(1);
        let mut s2 = root.stream(2);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = g.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut g = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg64::new(13);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = g.normal();
            s += x;
            s2 += x * x;
            s3 += x * x * x;
        }
        let mean = s / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((s2 / n as f64 - 1.0).abs() < 0.02, "var {}", s2 / n as f64);
        assert!((s3 / n as f64).abs() < 0.05, "skew {}", s3 / n as f64);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut g = Pcg64::new(17);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[g.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn subset_properties() {
        let mut g = Pcg64::new(19);
        for &(n, k) in &[(10, 3), (80, 8), (80, 79), (5, 5), (100, 1), (7, 0)] {
            let s = g.subset(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1], "sorted unique");
            }
            for &i in &s {
                assert!((i as usize) < n);
            }
        }
    }

    #[test]
    fn subset_is_uniform_marginally() {
        // Each element should appear with probability k/n.
        let mut g = Pcg64::new(23);
        let (n, k, trials) = (20usize, 5usize, 40_000usize);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in g.subset(n, k) {
                counts[i as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < 0.05 * expect,
                "count {c} vs {expect}"
            );
        }
    }

    #[test]
    fn subset_into_matches_subset_given_same_state() {
        for &(n, k) in &[(10usize, 3usize), (80, 8), (80, 79), (5, 5), (100, 1), (7, 0)] {
            let mut a = Pcg64::new(37);
            let mut b = a.clone();
            let plain = a.subset(n, k);
            // dirty buffer with stale capacity/content must be fully reset
            let mut reused = vec![9u32; 17];
            b.subset_into(n, k, &mut reused);
            assert_eq!(plain, reused, "n={n} k={k}");
            // generators must end in the same state
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut g = Pcg64::new(29);
        let p = g.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut g = Pcg64::new(31);
        let hits = (0..100_000).filter(|_| g.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
