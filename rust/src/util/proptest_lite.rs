//! A miniature property-based testing framework (proptest substitute).
//!
//! Usage:
//! ```ignore
//! proptest_lite::run(100, 0xC0FFEE, |g| {
//!     let d = g.usize_in(1, 512);
//!     let x = g.vec_f64(d, -10.0, 10.0);
//!     // ... assertions; return Err(msg) to fail, Ok(()) to pass
//!     Ok(())
//! });
//! ```
//!
//! On failure it reports the case index and the per-case seed so the exact
//! input can be replayed deterministically (`replay(seed, f)`).

use crate::util::rng::Pcg64;

/// Generator handed to each property case.
pub struct Gen {
    pub rng: Pcg64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }
    pub fn vec_normal(&mut self, n: usize, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal() * sigma).collect()
    }
    /// A vector drawn from a mix of scales (exercises denormals-ish, large,
    /// zero entries) — good for compressor edge cases.
    pub fn vec_mixed_scale(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match self.rng.below(5) {
                0 => 0.0,
                1 => self.rng.normal() * 1e-8,
                2 => self.rng.normal(),
                3 => self.rng.normal() * 1e6,
                _ => self.rng.normal() * 1e-3,
            })
            .collect()
    }
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `cases` property evaluations; panic with a replayable report on the
/// first failure.
pub fn run<F>(cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut root = Pcg64::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen {
            rng: Pcg64::new(case_seed),
        };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Pcg64::new(case_seed),
    };
    if let Err(msg) = property(&mut g) {
        panic!("replayed property failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assert two slices are elementwise close. Returns Err for use inside
/// properties.
pub fn check_close(a: &[f64], b: &[f64], atol: f64, rtol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("{what}: index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run(50, 1, |g| {
            count += 1;
            let n = g.usize_in(1, 10);
            let v = g.vec_f64(n, -1.0, 1.0);
            if v.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(50, 2, |g| {
            let x = g.f64_in(0.0, 1.0);
            if x < 0.9 {
                Ok(())
            } else {
                Err(format!("x = {x}"))
            }
        });
    }

    #[test]
    fn check_close_detects_mismatch() {
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, 0.0, "t").is_ok());
        assert!(check_close(&[1.0], &[1.1], 1e-9, 0.0, "t").is_err());
        assert!(check_close(&[1.0], &[1.0, 2.0], 1e-9, 0.0, "t").is_err());
    }

    #[test]
    fn mixed_scale_hits_zero_and_large() {
        let mut g = Gen {
            rng: Pcg64::new(5),
        };
        let v = g.vec_mixed_scale(1000);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.iter().any(|&x| x.abs() > 1e4));
    }
}
