//! DCGD-SHIFT — Algorithm 1, the paper's meta-algorithm.
//!
//! ```text
//! for k = 0, 1, 2, …
//!   broadcast x^k
//!   worker i:  m_i^k = Q_i(∇f_i(x^k) − h_i^k);  update h_i^{k+1};  send
//!   master:    g^k = h^k + (1/n) Σ m_i^k;  x^{k+1} = x^k − γ g^k;
//!              h^{k+1} = (1/n) Σ h_i^{k+1}
//! ```
//!
//! The shift rule (line 8) is pluggable — see [`ShiftRule`]. The master's
//! aggregate shift `h^k` is maintained incrementally from the same wire
//! messages the workers send (never from private worker state), so the
//! driver is faithful to what a real deployment can know.
//!
//! # Zero-allocation round contract
//!
//! `step` is two-phase, mirroring [`crate::coordinator::DistributedRunner`]
//! op for op (the coordinator tests pin the trajectories to be
//! bit-identical):
//!
//! 1. **worker phase** — each slot computes its gradient, compresses into
//!    its *recycled* scratch packets ([`Compressor::compress_into`]) and
//!    applies its own shift update straight from the packets
//!    ([`Packet::add_scaled_into`]);
//! 2. **master phase** — the gradient estimator is seeded from the
//!    maintained aggregate `h_sum` in one O(d) pass, then each worker's
//!    packets are folded in at O(nnz).
//!
//! Every buffer (gradients, diffs, packets, the estimator, `h_sum`) is
//! preallocated at construction; steady-state rounds perform **zero heap
//! allocations** (enforced by `tests/alloc_free.rs`). Aggregation cost is
//! O(d + Σᵢ nnzᵢ) per round instead of the former O(n·d).
//!
//! The gradient step itself goes through the same downlink delta packet
//! the threaded coordinator broadcasts ([`wire::build_update_packet`]):
//! `x += 1·(−γ·g)` with identical roundings, so the two drivers stay
//! bit-identical coordinate for coordinate, and `bits_down` reports the
//! measured delta-frame size (O(nnz) when the aggregate is sparse)
//! instead of the dense `n·d` formula. Rand-DIANA refreshes likewise
//! mirror the coordinator's sparse shift-refresh delta, and every
//! compressed packet is quantized to the wire precision at the source
//! (`Packet::quantize`), so an f32-precision run is bit-identical to an
//! f32 cluster — shift state included.
//!
//! # Error-fed-back downlink mirror
//!
//! [`DcgdShift::set_downlink`] arms the same lossy broadcast the
//! coordinator supports ([`crate::downlink::EfDownlink`]): the driver then
//! keeps one shared worker replica `x̂` (the broadcast reaches every
//! worker identically), evaluates all local gradients at `x̂`, and after
//! the exact master step folds the delta into the EF accumulator,
//! compresses, and applies the compressed packet to `x̂` — op for op what
//! the threaded cluster does, so trajectories and `bits_down` stay
//! bit-identical across drivers (pinned by `tests/coordinator.rs`). The
//! glue lives in the shared [`crate::downlink::DownlinkState`].
//!
//! # Error-fed-back uplink mirror
//!
//! [`DcgdShift::set_uplink_ef`] arms the single-process mirror of
//! [`crate::coordinator::ClusterConfig::uplink_ef`]: every worker slot
//! keeps an accumulator `e_i` ([`crate::ef::EfUplink`]) and its Q-frame
//! ships `c_i = C_i(e_i + m_i)` instead of `Q_i(m_i)` — the EF-BV
//! construction that makes contractive (biased) per-worker compressors
//! like Top-K valid on the uplink. The compression goes through the same
//! [`crate::ef::compress_uplink`] helper the threaded worker loop uses, in
//! the same operation order, so cluster and mirror stay bit-identical —
//! including the per-sub-step fold under `local_steps` batching and the
//! accumulator flush on `set_x0` (the mirror of the cluster's
//! resync-flushes-the-uplink rule). Step sizes for the contractive regime
//! come from [`crate::theory::ef_uplink`].
//!
//! # Local-step batched rounds
//!
//! [`DcgdShift::set_local_steps`] = τ mirrors
//! [`crate::coordinator::ClusterConfig::local_steps`] bit for bit: each
//! worker slot performs τ local shifted sub-steps per round (gradient at a
//! local iterate, quantized packet, local step `x̂ ← x̂ − γ(h + q_t)`, DIANA
//! shift learning per sub-step), and the master phase replays the fold
//! sub-step-major — exactly the order in which the threaded master decodes
//! the batched frames — before shipping the composite delta. τ = 1 is
//! today's per-round protocol, verbatim.

use crate::algorithms::shift_rules::ShiftRule;
use crate::algorithms::{Algorithm, StepStats};
use crate::compressors::{Compressor, Packet, PayloadBitsCache, ValPrec};
use crate::coordinator::participation::ParticipationSampler;
use crate::downlink::DownlinkState;
use crate::ef::{self, EfUplink};
use crate::linalg::{ax_into, axpy, sub_into, zero};
use crate::problems::Problem;
use crate::theory;
use crate::util::rng::Pcg64;
use crate::wire;

/// Per-worker state (compressor, shift, rule, RNG stream, scratch).
struct WorkerSlot {
    q: Box<dyn Compressor>,
    rule: ShiftRule,
    /// current shift h_i^k
    h: Vec<f64>,
    rng: Pcg64,
    // scratch buffers and recycled packets (allocation-free hot path)
    grad: Vec<f64>,
    diff: Vec<f64>,
    q_pkt: Packet,
    c_pkt: Packet,
    /// Rand-DIANA refresh-delta builder (mirrors the coordinator worker)
    refresh: wire::DeltaScratch,
    /// per-shape payload-bits caches (Q / C / refresh frames)
    q_bits: PayloadBitsCache,
    c_bits: PayloadBitsCache,
    r_bits: PayloadBitsCache,
    /// Rand-DIANA: did this round refresh the shift?
    refreshed: bool,
    /// batched rounds: the round's τ sub-step packets in sub-step order
    /// (the single-process stand-in for the wire batch frame; empty while
    /// `local_steps = 1`)
    batch: Vec<Packet>,
    /// worker-side error feedback on the uplink (`None` = exact uplink);
    /// the Q-frame then ships `C(e + m)` — see the module doc
    ef: Option<EfUplink>,
}

impl WorkerSlot {
    /// The Q-frame packet this round shipped: the EF re-pack when the EF
    /// uplink is armed, the recycled compressor scratch otherwise.
    fn q_packet(&self) -> &Packet {
        self.ef.as_ref().map_or(&self.q_pkt, |ef| ef.packet())
    }
}

pub struct DcgdShift {
    name: String,
    x: Vec<f64>,
    pub gamma: f64,
    /// wire precision used for bit accounting inside `step`
    pub prec: ValPrec,
    workers: Vec<WorkerSlot>,
    /// master's maintained aggregate Σᵢ h_i^k over workers with a non-STAR
    /// rule (STAR rebuilds its shift from the current gradient every round
    /// and contributes densely per worker; see `step`). Updated only from
    /// wire-observable content, never from private worker state.
    h_sum: Vec<f64>,
    /// gradient estimator g^k (master scratch)
    est: Vec<f64>,
    /// downlink delta builder (master scratch, pre-sized to d)
    delta: wire::DeltaScratch,
    /// shared driver-side downlink glue ([`crate::downlink::DownlinkState`]):
    /// the optional error-fed-back broadcast mirror (shared worker replica
    /// x̂, EF accumulator — see the module doc) and the measured
    /// next-frame accounting, which mirrors the coordinator: its round-k
    /// frame (round-0 resync, then the previous round's delta) is encoded
    /// before round k runs
    dl: DownlinkState,
    /// local sub-steps per communication round (≥ 1; see the module doc)
    local_steps: usize,
    /// batched rounds: Σ_t est^t accumulator (empty while τ = 1)
    g_acc: Vec<f64>,
    /// batched rounds: shared local-iterate scratch, one worker at a time
    /// (empty while τ = 1)
    x_loc: Vec<f64>,
    /// degraded-fleet mask ([`DcgdShift::quarantine_worker`]): an inactive
    /// worker is skipped in both phases — no gradient, no RNG draw, no
    /// fold — exactly what a quarantined worker contributes to a threaded
    /// round, so this driver mirrors the cluster's degraded trajectory
    active: Vec<bool>,
    /// workers currently active (the aggregate reweights to 1/n_active)
    n_active: usize,
    /// construction seed, kept so [`DcgdShift::set_participation`] can
    /// derive the identical sampler stream the cluster derives
    seed: u64,
    /// seeded per-round partial participation
    /// ([`DcgdShift::set_participation`]; `None` = every active worker
    /// works every round)
    sampler: Option<ParticipationSampler>,
    /// this round's participation mask (all-true without a sampler)
    sampled: Vec<bool>,
}

impl DcgdShift {
    // ------------------------------------------------------- constructors

    /// Plain DCGD (Khirirat et al., 2018): zero fixed shifts.
    pub fn dcgd(p: &dyn Problem, q: impl Compressor + Clone + 'static, seed: u64) -> Self {
        let n = p.n_workers();
        let shifts = vec![vec![0.0; p.dim()]; n];
        Self::fixed_shift(p, q, shifts, seed)
    }

    /// Plain DCGD with an error-fed-back uplink (EF-BV): zero fixed
    /// shifts, every worker ships `C(e_i + ∇f_i)` from its accumulator,
    /// and γ comes from [`theory::ef_uplink`] using the compressor's
    /// contraction δ. This is the constructor that accepts contractive
    /// (biased) compressors like Top-K — [`dcgd`](Self::dcgd) requires an
    /// unbiased Q. With `C = Identity` (δ = 1) it reduces to exact DGD
    /// with γ = 1/L.
    pub fn dcgd_ef(p: &dyn Problem, c: impl Compressor + Clone + 'static, seed: u64) -> Self {
        let n = p.n_workers();
        let delta = c.delta().unwrap_or(0.0);
        let ss = theory::ef_uplink(p, &vec![delta; n]);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(c.clone()) as Box<dyn Compressor>)
            .collect();
        let rules = (0..n).map(|_| ShiftRule::Fixed).collect();
        let shifts = vec![vec![0.0; p.dim()]; n];
        Self::build("dcgd-ef", p, qs, rules, shifts, ss.gamma, seed).with_uplink_ef()
    }

    /// DCGD-SHIFT with arbitrary fixed shifts (Theorem 1).
    pub fn fixed_shift(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        shifts: Vec<Vec<f64>>,
        seed: u64,
    ) -> Self {
        let omegas = vec![q.omega().expect("DCGD-SHIFT needs unbiased Q"); p.n_workers()];
        let ss = theory::dcgd_fixed(p, &omegas);
        let qs: Vec<Box<dyn Compressor>> = (0..p.n_workers())
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        let rules = (0..p.n_workers()).map(|_| ShiftRule::Fixed).collect();
        Self::build("dcgd-shift(fixed)", p, qs, rules, shifts, ss.gamma, seed)
    }

    /// DCGD-STAR (Theorem 2). `c` compresses the gradient displacement from
    /// the optimum; `None` = zero operator (pure h* shift).
    pub fn star(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        c: Option<Box<dyn Compressor>>,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let omega = q.omega().expect("DCGD-STAR needs unbiased Q");
        let delta = match &c {
            // C_i ∈ U(δ_i) in Theorem 2: unbiased "compressor of the
            // displacement" with variance δ_i; zero operator ⇒ δ = 0.
            Some(cc) => cc.omega().unwrap_or(0.0),
            None => 0.0,
        };
        // Theorem 2 uses ω_i(1−δ_i) with δ from the *contractive* view; for
        // unbiased C_i the induced variance is ω(1−δ_ind). We use the
        // contractive δ of C when available, else 0.
        let delta_contr = c.as_ref().and_then(|cc| cc.delta()).unwrap_or(0.0);
        let _ = delta;
        let ss = theory::dcgd_star(
            p,
            &vec![omega; n],
            &vec![delta_contr; n],
        );
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        let rules = (0..n)
            .map(|_| ShiftRule::Star {
                c: c.as_ref().map(|cc| cc.clone_box()),
            })
            .collect();
        // initial shift: ∇f_i(x*) (the rule recomputes every round anyway)
        let shifts = (0..n).map(|i| p.grad_star(i).to_vec()).collect();
        Self::build("dcgd-star", p, qs, rules, shifts, ss.gamma, seed)
    }

    /// Generalized DIANA (Theorem 3). `c` is the optional biased compressor
    /// in the shift update; `None` recovers classic DIANA.
    pub fn diana(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        c: Option<Box<dyn Compressor>>,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let omega = q.omega().expect("DIANA needs unbiased Q");
        let delta = c.as_ref().and_then(|cc| cc.delta()).unwrap_or(0.0);
        let ss = theory::diana(p, &vec![omega; n], &vec![delta; n], 2.0);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        let rules = (0..n)
            .map(|_| ShiftRule::Diana {
                alpha: ss.alpha,
                c: c.as_ref().map(|cc| cc.clone_box()),
            })
            .collect();
        let shifts = vec![vec![0.0; p.dim()]; n];
        Self::build("diana", p, qs, rules, shifts, ss.gamma, seed)
    }

    /// Rand-DIANA (Theorem 4). `p_refresh = None` uses the paper's
    /// `p = 1/(ω+1)`; `m_override` feeds the Figure-2 stability study.
    pub fn rand_diana(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        p_refresh: Option<f64>,
        seed: u64,
    ) -> Self {
        Self::rand_diana_with_m(p, q, p_refresh, None, seed)
    }

    pub fn rand_diana_with_m(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        p_refresh: Option<f64>,
        m_override: Option<f64>,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let omega = q.omega().expect("Rand-DIANA needs unbiased Q");
        let pr = p_refresh.unwrap_or_else(|| theory::rand_diana_default_p(omega));
        let probs = vec![pr; n];
        let ss = theory::rand_diana(p, omega, &probs, m_override);
        let qs: Vec<Box<dyn Compressor>> = (0..n)
            .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
            .collect();
        let rules = (0..n).map(|_| ShiftRule::RandDiana { p: pr }).collect();
        // h_i⁰ = ∇f_i(w_i⁰) with w⁰ = x⁰ unknown until x0 set; initialize to
        // zero — the first refresh fixes it, and Theorem 4 allows any h⁰.
        let shifts = vec![vec![0.0; p.dim()]; n];
        Self::build("rand-diana", p, qs, rules, shifts, ss.gamma, seed)
    }

    /// Fully custom construction (heterogeneous compressors / rules).
    pub fn custom(
        name: &str,
        p: &dyn Problem,
        qs: Vec<Box<dyn Compressor>>,
        rules: Vec<ShiftRule>,
        shifts: Vec<Vec<f64>>,
        gamma: f64,
        seed: u64,
    ) -> Self {
        Self::build(name, p, qs, rules, shifts, gamma, seed)
    }

    fn build(
        name: &str,
        p: &dyn Problem,
        qs: Vec<Box<dyn Compressor>>,
        rules: Vec<ShiftRule>,
        shifts: Vec<Vec<f64>>,
        gamma: f64,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let d = p.dim();
        assert_eq!(qs.len(), n);
        assert_eq!(shifts.len(), n);
        let mut root = Pcg64::with_stream(seed, 0xa160);
        // Σ h_i over non-STAR workers (STAR shifts are rebuilt every round
        // and aggregated densely; keeping them out of h_sum keeps the
        // maintained sum exact). Worker order matters for bit-identity with
        // the threaded coordinator.
        let mut h_sum = vec![0.0; d];
        for (rule, h) in rules.iter().zip(shifts.iter()) {
            if !matches!(rule, ShiftRule::Star { .. }) {
                axpy(1.0, h, &mut h_sum);
            }
        }
        let workers: Vec<WorkerSlot> = qs
            .into_iter()
            .zip(rules)
            .zip(shifts)
            .enumerate()
            .map(|(i, ((q, rule), h))| WorkerSlot {
                q,
                rule,
                h,
                rng: root.stream(i as u64 + 1),
                grad: vec![0.0; d],
                diff: vec![0.0; d],
                q_pkt: Packet::Zero { dim: d as u32 },
                c_pkt: Packet::Zero { dim: d as u32 },
                refresh: wire::DeltaScratch::with_capacity(0),
                q_bits: PayloadBitsCache::new(),
                c_bits: PayloadBitsCache::new(),
                r_bits: PayloadBitsCache::new(),
                refreshed: false,
                batch: Vec::new(),
                ef: None,
            })
            .collect();
        // downlink compressor stream: worker streams are 1..=n, so n+1 —
        // identical derivation to the coordinator's. DownlinkState starts
        // with round 0 broadcasting the dense resync that bootstraps
        // replicas.
        let dl_rng = root.stream(workers.len() as u64 + 1);
        let x = crate::algorithms::paper_x0(d, seed);
        let dl = DownlinkState::new(&x, dl_rng);
        let n_active = workers.len();
        Self {
            name: name.to_string(),
            x,
            gamma,
            prec: ValPrec::F64,
            workers,
            h_sum,
            est: vec![0.0; d],
            delta: wire::DeltaScratch::with_capacity(d),
            dl,
            local_steps: 1,
            g_acc: Vec::new(),
            x_loc: Vec::new(),
            active: vec![true; n_active],
            n_active,
            seed,
            sampler: None,
            sampled: vec![true; n_active],
        }
    }

    /// Arm the error-fed-back downlink mirror (see the module doc); the
    /// equivalent of setting [`crate::coordinator::ClusterConfig`]'s
    /// `downlink` on the threaded cluster. The replica is bootstrapped
    /// from the current iterate — the same state the coordinator's next
    /// dense resync would broadcast.
    pub fn set_downlink(&mut self, comp: Box<dyn Compressor>) {
        self.dl.arm(comp, &self.x);
    }

    /// Builder-style [`set_downlink`](Self::set_downlink).
    pub fn with_downlink(mut self, comp: Box<dyn Compressor>) -> Self {
        self.set_downlink(comp);
        self
    }

    /// Arm worker-side error feedback on the uplink (see the module doc);
    /// the bit-identical mirror of
    /// [`crate::coordinator::ClusterConfig::uplink_ef`]. Each worker's
    /// Q-frame then ships `C_i(e_i + m_i)` from a fresh accumulator,
    /// unlocking contractive (biased) per-worker compressors. Arm before
    /// the first step: a mid-run arm starts from empty accumulators, which
    /// the threaded cluster has no protocol for.
    pub fn set_uplink_ef(&mut self) {
        let d = self.x.len();
        for w in &mut self.workers {
            w.ef = Some(EfUplink::new(d));
        }
    }

    /// Builder-style [`set_uplink_ef`](Self::set_uplink_ef).
    pub fn with_uplink_ef(mut self) -> Self {
        self.set_uplink_ef();
        self
    }

    /// A worker's EF uplink accumulator `Σ (m − c)` (`None` on the exact
    /// uplink). Tests compare this against the cluster's worker snapshots.
    pub fn uplink_error(&self, worker: usize) -> Option<&[f64]> {
        self.workers[worker].ef.as_ref().map(|ef| ef.error())
    }

    /// Batch `tau` local shifted sub-steps per communication round — the
    /// bit-identical single-process mirror of
    /// [`crate::coordinator::ClusterConfig::local_steps`] (see the module
    /// doc). Supported for the fixed-shift and DIANA-without-C rules;
    /// panics otherwise. `1` restores the per-round protocol verbatim.
    pub fn set_local_steps(&mut self, tau: usize) {
        assert!(
            tau >= 1 && tau <= u16::MAX as usize,
            "local_steps must be in 1..=65535 (the batch frame's count field)"
        );
        if tau > 1 {
            assert!(
                self.workers.iter().all(|w| matches!(
                    w.rule,
                    ShiftRule::Fixed | ShiftRule::Diana { c: None, .. }
                )),
                "local-step batching (local_steps > 1) supports the fixed-shift and \
                 DIANA-without-C rules; this driver ships one frame per round"
            );
            assert!(
                self.sampler.is_none(),
                "local-step batching does not compose with partial participation"
            );
            let d = self.x.len();
            self.g_acc = vec![0.0; d];
            self.x_loc = vec![0.0; d];
        }
        self.local_steps = tau;
    }

    /// Builder-style [`set_local_steps`](Self::set_local_steps).
    pub fn with_local_steps(mut self, tau: usize) -> Self {
        self.set_local_steps(tau);
        self
    }

    /// The EF downlink's error accumulator (`None` on the exact path).
    pub fn ef_error(&self) -> Option<&[f64]> {
        self.dl.ef_error()
    }

    /// The shared worker replica x̂ (`None` on the exact path, where the
    /// replicas are bit-equal to [`Algorithm::x`] by construction).
    pub fn replica(&self) -> Option<&[f64]> {
        self.dl.replica()
    }

    pub fn set_x0(&mut self, x0: Vec<f64>) {
        assert_eq!(x0.len(), self.x.len());
        // the coordinator would resync its replicas after an out-of-band
        // iterate change; mirror the accounting — and on the EF path the
        // resync overwrites the replica and flushes the accumulator
        self.x = x0;
        self.dl.resync(&self.x);
        // the cluster's workers flush their EF uplink accumulators when
        // the resync frame arrives; mirror that here (nothing stale is
        // retried against the re-established state)
        for w in &mut self.workers {
            if let Some(ef) = &mut w.ef {
                ef.flush();
            }
        }
    }

    pub fn set_gamma(&mut self, gamma: f64) {
        self.gamma = gamma;
    }

    /// Access a worker's current shift (tests).
    pub fn shift(&self, worker: usize) -> &[f64] {
        &self.workers[worker].h
    }

    /// Drop `worker` from the fleet, the single-process mirror of the
    /// coordinator's quarantine: its shift is subtracted from the
    /// maintained `h_sum` in one O(d) `axpy` (the identical operation the
    /// threaded master performs, so the two drivers stay bit-equal), the
    /// aggregate reweights to `1/n_active`, and from the next [`step`]
    /// on the worker is skipped entirely — no gradient, no RNG draw, no
    /// fold. No-op when the worker is already inactive.
    ///
    /// [`step`]: Algorithm::step
    pub fn quarantine_worker(&mut self, worker: usize) {
        if !self.active[worker] {
            return;
        }
        self.active[worker] = false;
        self.n_active -= 1;
        if !matches!(self.workers[worker].rule, ShiftRule::Star { .. }) {
            axpy(-1.0, &self.workers[worker].h, &mut self.h_sum);
        }
    }

    /// Re-admit a quarantined worker, the mirror of
    /// [`crate::coordinator::DistributedRunner::rejoin`]: the shift is
    /// added back into `h_sum` (the exact fp inverse of the quarantine
    /// subtraction) and the worker's EF uplink accumulator is flushed —
    /// the same state-reset rule the cluster's rejoin bootstrap (a dense
    /// resync) applies on the worker thread. No-op when already active.
    pub fn rejoin_worker(&mut self, worker: usize) {
        if self.active[worker] {
            return;
        }
        self.active[worker] = true;
        self.n_active += 1;
        if !matches!(self.workers[worker].rule, ShiftRule::Star { .. }) {
            axpy(1.0, &self.workers[worker].h, &mut self.h_sum);
        }
        if let Some(ef) = &mut self.workers[worker].ef {
            ef.flush();
        }
    }

    /// Workers currently in the fleet (n minus quarantined).
    pub fn active_workers(&self) -> usize {
        self.n_active
    }

    /// Sample a seeded `fraction` of the fleet each round — the
    /// bit-identical single-process mirror of
    /// [`crate::coordinator::ClusterConfig::participation`]. The sampler
    /// is derived from the construction seed on the same disjoint RNG
    /// stream the cluster uses ([`ParticipationSampler::seeded`], worker
    /// 0 always in), so both drivers replay the identical per-round
    /// schedule. A sampled-out worker is frozen for the round — no
    /// gradient, no RNG draw, shift untouched — exactly what the
    /// cluster's sync-only command leaves behind, and the estimator
    /// reweights to the sampled reporters. Requires the fixed-shift rule
    /// with `local_steps = 1` (the same gate the cluster asserts).
    pub fn set_participation(&mut self, fraction: f64) {
        assert!(
            self.workers
                .iter()
                .all(|w| matches!(w.rule, ShiftRule::Fixed)),
            "partial participation requires the fixed-shift rule: shift-learning rules \
             would advance h_i only on sampled rounds and desynchronize from the schedule"
        );
        assert!(
            self.local_steps == 1,
            "partial participation does not compose with local-step batching (local_steps = {})",
            self.local_steps
        );
        self.sampler = Some(ParticipationSampler::seeded(
            self.seed,
            self.workers.len(),
            fraction,
        ));
    }

    /// Builder-style [`set_participation`](Self::set_participation).
    pub fn with_participation(mut self, fraction: f64) -> Self {
        self.set_participation(fraction);
        self
    }
}

impl Algorithm for DcgdShift {
    fn name(&self) -> String {
        let rule = self
            .workers
            .first()
            .map(|w| w.rule.label())
            .unwrap_or_default();
        if self.name == "dcgd-shift(fixed)" || self.name == "dcgd-star" {
            self.name.clone()
        } else {
            format!("{}[{rule}]", self.name)
        }
    }

    fn compressor_desc(&self) -> String {
        self.workers
            .first()
            .map(|w| w.q.name())
            .unwrap_or_default()
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn step(&mut self, p: &dyn Problem) -> StepStats {
        if self.local_steps > 1 {
            return self.step_batched(p);
        }
        // partial participation: draw this round's seeded sample S_k —
        // exactly one draw per round, the same schedule the cluster
        // replays. Without a sampler the mask stays all-true.
        if let Some(sampler) = self.sampler.as_mut() {
            sampler.next_round();
            self.sampled.copy_from_slice(sampler.mask());
        }
        let reporters = (0..self.workers.len())
            .filter(|&wi| self.active[wi] && self.sampled[wi])
            .count();
        let inv_n = if reporters > 0 {
            1.0 / reporters as f64
        } else {
            0.0
        };
        let mut bits_up: u64 = 0;
        let mut bits_refresh: u64 = 0;

        // ---- phase 1: workers (mirrors coordinator::worker_loop op for op;
        // quarantined workers are skipped entirely — state frozen, RNG
        // stream untouched, exactly like a thread out of the rotation —
        // and a sampled-out worker is frozen for the round the same way,
        // mirroring the cluster's sync-only command)
        for (wi, w) in self.workers.iter_mut().enumerate() {
            if !self.active[wi] || !self.sampled[wi] {
                continue;
            }
            // line 6: local gradient at the iterate the worker actually
            // has (the shared lossy-broadcast replica on the EF path)
            let x_eval: &[f64] = self.dl.x_eval(&self.x);
            p.local_grad_into(wi, x_eval, &mut w.grad);
            w.refreshed = false;

            match &mut w.rule {
                // -------------------------------------------------- Fixed
                ShiftRule::Fixed => {
                    sub_into(&w.grad, &w.h, &mut w.diff);
                    let pkt = ef::compress_uplink(
                        w.q.as_ref(),
                        &mut w.rng,
                        w.ef.as_mut(),
                        &w.diff,
                        self.prec,
                        &mut w.q_pkt,
                    );
                    bits_up += w.q_bits.bits(pkt, self.prec);
                    // h unchanged
                }
                // --------------------------------------------------- Star
                ShiftRule::Star { c } => {
                    // h_i^k = ∇f_i(x*) + C_i(∇f_i(x^k) − ∇f_i(x*))  (B.3:
                    // rebuilt from the current gradient every round)
                    let gs = p.grad_star(wi);
                    if let Some(cc) = c {
                        sub_into(&w.grad, gs, &mut w.diff);
                        cc.compress_into(&mut w.rng, &w.diff, &mut w.c_pkt);
                        w.c_pkt.quantize(self.prec);
                        bits_up += w.c_bits.bits(&w.c_pkt, self.prec);
                        // h_i = ∇f_i(x*) + C_i(…), in place like the
                        // coordinator worker
                        w.h.copy_from_slice(gs);
                        w.c_pkt.add_scaled_into(1.0, &mut w.h);
                    } else {
                        w.h.copy_from_slice(gs);
                    }
                    // m_i = Q_i(∇f_i − h_i^k)
                    sub_into(&w.grad, &w.h, &mut w.diff);
                    let pkt = ef::compress_uplink(
                        w.q.as_ref(),
                        &mut w.rng,
                        w.ef.as_mut(),
                        &w.diff,
                        self.prec,
                        &mut w.q_pkt,
                    );
                    bits_up += w.q_bits.bits(pkt, self.prec);
                }
                // -------------------------------------------------- DIANA
                ShiftRule::Diana { alpha, c } => {
                    // v = ∇f_i − h_i^k
                    sub_into(&w.grad, &w.h, &mut w.diff);
                    if let Some(cc) = c {
                        // c_i^k = C_i(v); residual v − c stays in diff
                        cc.compress_into(&mut w.rng, &w.diff, &mut w.c_pkt);
                        w.c_pkt.quantize(self.prec);
                        bits_up += w.c_bits.bits(&w.c_pkt, self.prec);
                        w.c_pkt.add_scaled_into(-1.0, &mut w.diff);
                    }
                    // m_i^k = Q_i(v − c)  (EF: C_i(e_i + v − c), same slot)
                    let pkt = ef::compress_uplink(
                        w.q.as_ref(),
                        &mut w.rng,
                        w.ef.as_mut(),
                        &w.diff,
                        self.prec,
                        &mut w.q_pkt,
                    );
                    bits_up += w.q_bits.bits(pkt, self.prec);
                    // shift learning h_i += α(c + q), straight from the
                    // packets at O(nnz)
                    if c.is_some() {
                        w.c_pkt.add_scaled_into(*alpha, &mut w.h);
                    }
                    pkt.add_scaled_into(*alpha, &mut w.h);
                }
                // --------------------------------------------- Rand-DIANA
                ShiftRule::RandDiana { p: pr } => {
                    sub_into(&w.grad, &w.h, &mut w.diff);
                    let pkt = ef::compress_uplink(
                        w.q.as_ref(),
                        &mut w.rng,
                        w.ef.as_mut(),
                        &w.diff,
                        self.prec,
                        &mut w.q_pkt,
                    );
                    bits_up += w.q_bits.bits(pkt, self.prec);
                    // w_i^{k+1} = x^k w.p. p — refresh ships a delta of the
                    // shift vs the master's replica: h_new = ∇f = h + diff,
                    // so only diff's support travels (sparse when x moved
                    // sparsely since the last refresh). Both ends apply the
                    // identical quantized packet; h lands within one
                    // rounding of ∇f_i(x^k).
                    if w.rng.bernoulli(*pr) {
                        w.refreshed = true;
                        let r_pkt =
                            wire::build_update_packet(&w.diff, 1.0, self.prec, &mut w.refresh);
                        r_pkt.add_scaled_into(1.0, &mut w.h);
                        bits_refresh += w.r_bits.bits(r_pkt, self.prec);
                    }
                }
            }
        }

        // ---- phase 2: master aggregation (mirrors DistributedRunner's
        // try_step). g^k = (1/|active|) Σ_active (h_i^{used} + m_i): seed
        // from the maintained h_sum in one O(d) pass, then fold the active
        // workers' packets in at O(nnz). A fully-quarantined fleet takes a
        // zero step (the iterate holds), like the cluster's zero-reporter
        // round.
        if reporters == 0 {
            zero(&mut self.est);
        } else {
            ax_into(inv_n, &self.h_sum, &mut self.est);
        }
        // sampled-out active workers: excluded from this round's
        // estimator without touching h_sum — the same worker-order
        // subtraction pass the cluster's fold runs before any reporter
        // folds (no-op without a sampler, so the full-participation path
        // is untouched)
        if self.sampler.is_some() && reporters > 0 {
            for (wi, w) in self.workers.iter().enumerate() {
                if self.active[wi] && !self.sampled[wi] {
                    axpy(-inv_n, &w.h, &mut self.est);
                }
            }
        }
        for (wi, w) in self.workers.iter_mut().enumerate() {
            if !self.active[wi] || !self.sampled[wi] {
                continue;
            }
            match &w.rule {
                ShiftRule::Fixed => {
                    w.q_packet().add_scaled_into(inv_n, &mut self.est);
                }
                ShiftRule::Star { .. } => {
                    // same-round rebuilt shift, aggregated densely (STAR is
                    // the paper's "impractical but insightful" method)
                    axpy(inv_n, &w.h, &mut self.est);
                    w.q_packet().add_scaled_into(inv_n, &mut self.est);
                }
                ShiftRule::Diana { alpha, c } => {
                    if c.is_some() {
                        w.c_pkt.add_scaled_into(inv_n, &mut self.est);
                        w.c_pkt.add_scaled_into(*alpha, &mut self.h_sum);
                    }
                    w.q_packet().add_scaled_into(inv_n, &mut self.est);
                    w.q_packet().add_scaled_into(*alpha, &mut self.h_sum);
                }
                ShiftRule::RandDiana { .. } => {
                    w.q_packet().add_scaled_into(inv_n, &mut self.est);
                    if w.refreshed {
                        // same packet the worker applied to its shift
                        w.refresh.packet().add_scaled_into(1.0, &mut self.h_sum);
                    }
                }
            }
        }
        // gradient step, via the same downlink delta packet the threaded
        // coordinator broadcasts: x += 1·(−γ·g) with identical roundings
        // (bit-identical to axpy(−γ, g, x) on every touched coordinate)
        let delta = wire::build_update_packet(&self.est, -self.gamma, self.prec, &mut self.delta);
        delta.add_scaled_into(1.0, &mut self.x);
        // Measured broadcast cost, mirroring the coordinator frame for
        // frame: this round shipped the frame decided last round (round 0:
        // the dense bootstrap resync), and the frame just built ships next
        // round. On the EF path the broadcast is the compressed C(e + Δ),
        // applied to the shared replica with the same op the workers use.
        // (Periodic `resync_every` redundancy is a runner-only operational
        // knob and is not mirrored here.) Degraded fleets broadcast to the
        // active workers only — and under partial participation only S_k
        // is commanded — matching the cluster's per-recipient charge.
        let bits_down = self.dl.finish_round_packet(delta, &self.x, reporters, self.prec);

        StepStats {
            bits_up,
            bits_down,
            bits_refresh,
            active_workers: reporters,
            replica_bytes: self.dl.replica_footprint(),
        }
    }
}

impl DcgdShift {
    /// Batched round: τ local shifted sub-steps per worker, then a
    /// sub-step-major master replay — op for op what the threaded
    /// coordinator does with the batched wire frames (see the module doc),
    /// pinned bit-identical by `tests/coordinator.rs`.
    fn step_batched(&mut self, p: &dyn Problem) -> StepStats {
        let tau = self.local_steps;
        let inv_n = if self.n_active > 0 {
            1.0 / self.n_active as f64
        } else {
            0.0
        };
        let mut bits_up: u64 = 0;

        // ---- phase 1: workers — τ local sub-steps each, packets kept in
        // sub-step order (the stand-in for the batched wire frame);
        // quarantined workers are skipped entirely
        for (wi, w) in self.workers.iter_mut().enumerate() {
            if !self.active[wi] {
                continue;
            }
            while w.batch.len() < tau {
                w.batch.push(Packet::Zero {
                    dim: self.x.len() as u32,
                });
            }
            let x_eval: &[f64] = self.dl.x_eval(&self.x);
            self.x_loc.copy_from_slice(x_eval);
            for t in 0..tau {
                p.local_grad_into(wi, &self.x_loc, &mut w.grad);
                sub_into(&w.grad, &w.h, &mut w.diff);
                match w.ef.as_mut() {
                    // per-sub-step EF fold, mirroring the threaded worker
                    // op for op; the batch slot (this driver's stand-in
                    // for the wire frame) receives a copy of the re-packed
                    // c_t = C(e + m_t), already quantized
                    Some(ef) => {
                        let c =
                            ef.fold_and_compress(w.q.as_ref(), &mut w.rng, &w.diff, self.prec);
                        w.batch[t].copy_from(c);
                    }
                    None => {
                        w.q.compress_into(&mut w.rng, &w.diff, &mut w.batch[t]);
                        w.batch[t].quantize(self.prec);
                    }
                }
                bits_up += w.q_bits.bits(&w.batch[t], self.prec);
                // local step x̂ ← x̂ − γ(h + q_t), h as used this sub-step
                axpy(-self.gamma, &w.h, &mut self.x_loc);
                w.batch[t].add_scaled_into(-self.gamma, &mut self.x_loc);
                if let ShiftRule::Diana { alpha, .. } = &w.rule {
                    w.batch[t].add_scaled_into(*alpha, &mut w.h);
                }
            }
        }

        // ---- phase 2: master — sub-step-major replay over the active
        // workers, worker order within each sub-step, matching the
        // threaded master's batched fold bit for bit
        zero(&mut self.g_acc);
        if self.n_active > 0 {
            for t in 0..tau {
                ax_into(inv_n, &self.h_sum, &mut self.est);
                for (wi, w) in self.workers.iter_mut().enumerate() {
                    if !self.active[wi] {
                        continue;
                    }
                    w.batch[t].add_scaled_into(inv_n, &mut self.est);
                    if let ShiftRule::Diana { alpha, .. } = &w.rule {
                        w.batch[t].add_scaled_into(*alpha, &mut self.h_sum);
                    }
                }
                axpy(1.0, &self.est, &mut self.g_acc);
            }
        }
        let delta = wire::build_update_packet(&self.g_acc, -self.gamma, self.prec, &mut self.delta);
        delta.add_scaled_into(1.0, &mut self.x);
        let bits_down = self.dl.finish_round_packet(delta, &self.x, self.n_active, self.prec);

        StepStats {
            bits_up,
            bits_down,
            bits_refresh: 0,
            active_workers: self.n_active,
            replica_bytes: self.dl.replica_footprint(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunOpts;
    use crate::compressors::{Identity, RandK};
    use crate::problems::{Problem, Quadratic, Ridge};

    fn ridge() -> Ridge {
        Ridge::paper_default(1)
    }

    #[test]
    fn dcgd_with_identity_is_exact_gd() {
        // Q = Identity ⇒ DCGD-SHIFT reduces to DGD; compare to hand-rolled
        // gradient descent with the same γ and x0.
        let p = ridge();
        let mut alg = DcgdShift::dcgd(&p, Identity::new(p.dim()), 7);
        let gamma = alg.gamma;
        let mut x = alg.x().to_vec();
        for _ in 0..50 {
            alg.step(&p);
            let g = p.grad(&x);
            crate::linalg::axpy(-gamma, &g, &mut x);
        }
        let diff = crate::linalg::dist_sq(alg.x(), &x).sqrt();
        assert!(diff < 1e-10, "diverged from exact GD by {diff}");
    }

    #[test]
    fn dcgd_converges_to_neighborhood_not_zero() {
        // Non-interpolating ridge ⇒ DCGD stalls at a positive error floor.
        let p = ridge();
        let mut alg = DcgdShift::dcgd(&p, RandK::with_q(p.dim(), 0.25), 3);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 8_000,
                tol: 1e-30,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(!trace.diverged);
        let floor = trace.error_floor();
        assert!(
            floor > 1e-12 && floor < 1e-1,
            "DCGD floor {floor} should be a (small) neighborhood"
        );
    }

    #[test]
    fn dcgd_exact_in_interpolation_regime() {
        // With ∇f_i(x*) = 0 and zero shifts, Theorem 1's neighborhood
        // vanishes: DCGD reaches the exact optimum.
        let p = Quadratic::interpolating(20, 5, 1.0, 10.0, 5);
        let mut alg = DcgdShift::dcgd(&p, RandK::with_q(20, 0.25), 5);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 30_000,
                tol: 1e-20,
                record_every: 20,
                ..Default::default()
            },
        );
        assert!(trace.converged, "floor {:e}", trace.error_floor());
    }

    #[test]
    fn star_converges_exactly() {
        let p = ridge();
        let mut alg = DcgdShift::star(&p, RandK::with_q(p.dim(), 0.25), None, 9);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 30_000,
                tol: 1e-24,
                record_every: 25,
                ..Default::default()
            },
        );
        assert!(trace.converged, "floor {:e}", trace.error_floor());
    }

    #[test]
    fn diana_converges_exactly() {
        // Well-conditioned quadratic (κ = 10) so deep tolerance is reached
        // in few rounds; the ridge-scale behaviour is covered by
        // `diana_breaks_dcgd_floor` and the integration tests.
        let p = Quadratic::random(20, 4, 1.0, 10.0, 11);
        let mut alg = DcgdShift::diana(&p, RandK::with_q(20, 0.25), None, 11);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 30_000,
                tol: 1e-24,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(trace.converged, "floor {:e}", trace.error_floor());
    }

    #[test]
    fn rand_diana_converges_exactly() {
        let p = Quadratic::random(20, 4, 1.0, 10.0, 13);
        let mut alg = DcgdShift::rand_diana(&p, RandK::with_q(20, 0.25), None, 13);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 30_000,
                tol: 1e-24,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(trace.converged, "floor {:e}", trace.error_floor());
    }

    #[test]
    fn diana_breaks_dcgd_floor_on_ridge() {
        // On the paper's (ill-conditioned, non-interpolating) ridge, DIANA's
        // error keeps decreasing far below the DCGD neighborhood within the
        // same round budget.
        let p = ridge();
        let opts = RunOpts {
            max_rounds: 60_000,
            tol: 1e-30,
            record_every: 50,
            ..Default::default()
        };
        let dcgd_floor = DcgdShift::dcgd(&p, RandK::with_q(p.dim(), 0.25), 11)
            .run(&p, &opts)
            .error_floor();
        let diana_floor = DcgdShift::diana(&p, RandK::with_q(p.dim(), 0.25), None, 11)
            .run(&p, &opts)
            .error_floor();
        assert!(
            diana_floor < dcgd_floor * 1e-2,
            "diana {diana_floor:e} vs dcgd {dcgd_floor:e}"
        );
    }

    #[test]
    fn diana_shifts_learn_optimal_gradients() {
        let p = ridge();
        let mut alg = DcgdShift::diana(&p, RandK::with_q(p.dim(), 0.5), None, 15);
        let _ = alg.run(
            &p,
            &RunOpts {
                max_rounds: 40_000,
                tol: 1e-22,
                record_every: 100,
                ..Default::default()
            },
        );
        for w in 0..p.n_workers() {
            let dist = crate::linalg::dist_sq(alg.shift(w), p.grad_star(w)).sqrt()
                / crate::linalg::nrm2(p.grad_star(w)).max(1e-12);
            assert!(dist < 1e-6, "worker {w} shift off by {dist}");
        }
    }

    #[test]
    fn bits_accounting_is_positive_and_monotone() {
        let p = ridge();
        let mut alg = DcgdShift::diana(&p, RandK::with_q(p.dim(), 0.1), None, 17);
        let t = alg.run(
            &p,
            &RunOpts {
                max_rounds: 50,
                tol: 0.0,
                ..Default::default()
            },
        );
        let bits: Vec<u64> = t.records.iter().map(|r| r.bits_up).collect();
        assert!(bits.windows(2).all(|w| w[0] <= w[1]));
        assert!(*bits.last().unwrap() > 0);
        // Rand-K(8/80) with f64 values: ≈ 8·(64+7)+64 ≈ 632 payload bits per
        // worker per round ⇒ 6320/round; sanity band:
        let per_round = *bits.last().unwrap() as f64 / 50.0;
        assert!(
            per_round > 3_000.0 && per_round < 12_000.0,
            "bits/round {per_round}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let p = ridge();
        let run = |seed| {
            let mut alg = DcgdShift::rand_diana(&p, RandK::with_q(p.dim(), 0.3), None, seed);
            let t = alg.run(
                &p,
                &RunOpts {
                    max_rounds: 100,
                    tol: 0.0,
                    ..Default::default()
                },
            );
            (alg.x().to_vec(), t.total_bits_up())
        };
        let (x1, b1) = run(21);
        let (x2, b2) = run(21);
        assert_eq!(x1, x2);
        assert_eq!(b1, b2);
        let (x3, _) = run(22);
        assert_ne!(x1, x3);
    }

    #[test]
    fn master_shift_sum_tracks_workers() {
        let p = ridge();
        let mut alg = DcgdShift::rand_diana(&p, RandK::with_q(p.dim(), 0.5), Some(0.3), 23);
        for _ in 0..200 {
            alg.step(&p);
        }
        let d = p.dim();
        let n = p.n_workers();
        let mut sum = vec![0.0; d];
        for w in 0..n {
            crate::linalg::axpy(1.0, alg.shift(w), &mut sum);
        }
        let diff = crate::linalg::dist_sq(&sum, &alg.h_sum).sqrt()
            / crate::linalg::nrm2(&sum).max(1e-12);
        assert!(diff < 1e-9, "master shift drift {diff}");
    }
}
