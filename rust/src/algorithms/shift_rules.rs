//! Shift-update rules for DCGD-SHIFT (the colored line 8 of Algorithm 1).
//!
//! Table 2 of the paper, realized as one enum. All rules are expressed in
//! the unified form `h_i^{k+1} = s_i^k + C_i(∇f_i(x^k) − s_i^k)`:
//!
//! | Rule        | `s_i^k`         | `C_i`                  | VR |
//! |-------------|-----------------|------------------------|----|
//! | `Fixed`     | `h_i⁰` (const)  | `O` (zero)             | ✗  |
//! | `Star`      | `∇f_i(x*)`      | any `C_i ∈ B(δ)`       | ✓  |
//! | `Diana`     | `h_i^k`         | `α·Q_ind,i`            | ✓  |
//! | `RandDiana` | `h_i^k`         | `B_{p_i}` (Bernoulli)  | ✓  |

use crate::compressors::Compressor;

/// Per-worker shift rule (owning the rule's compressor where applicable).
pub enum ShiftRule {
    /// `h_i^k ≡ h_i⁰` — covers plain DCGD (zero shifts) and DCGD-SHIFT
    /// with arbitrary fixed shifts (Theorem 1).
    Fixed,
    /// DCGD-STAR (Theorem 2): `h_i^k = ∇f_i(x*) + C_i(∇f_i(x^k) − ∇f_i(x*))`.
    /// `c = None` means the zero operator (simplest optimal shift
    /// `h_i = ∇f_i(x*)`), per the paper's "δ_i interpreted as zero".
    Star { c: Option<Box<dyn Compressor>> },
    /// Generalized DIANA (Theorem 3):
    /// `h_i^{k+1} = h_i^k + α·[C_i(v) + Q_i(v − C_i(v))]`, `v = ∇f_i − h_i^k`.
    /// `c = None` recovers the classic DIANA update (11).
    Diana {
        alpha: f64,
        c: Option<Box<dyn Compressor>>,
    },
    /// Rand-DIANA (Theorem 4): `h_i^k = ∇f_i(w_i^k)`, `w_i` refreshed to the
    /// current iterate with probability `p` each round.
    RandDiana { p: f64 },
}

impl ShiftRule {
    pub fn label(&self) -> String {
        match self {
            ShiftRule::Fixed => "fixed".into(),
            ShiftRule::Star { c } => match c {
                Some(c) => format!("star({})", c.name()),
                None => "star".into(),
            },
            ShiftRule::Diana { alpha, c } => match c {
                Some(c) => format!("diana(α={alpha:.4}, C={})", c.name()),
                None => format!("diana(α={alpha:.4})"),
            },
            ShiftRule::RandDiana { p } => format!("rand-diana(p={p:.4})"),
        }
    }

    /// Is this a variance-reduced rule (shift converges to ∇f_i(x*))?
    pub fn is_variance_reduced(&self) -> bool {
        !matches!(self, ShiftRule::Fixed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::TopK;

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(ShiftRule::Fixed.label(), "fixed");
        assert!(ShiftRule::Star { c: None }.label().starts_with("star"));
        let d = ShiftRule::Diana {
            alpha: 0.1,
            c: Some(Box::new(TopK::new(10, 2))),
        };
        assert!(d.label().contains("top-k"));
        assert!(ShiftRule::RandDiana { p: 0.25 }.label().contains("0.25"));
    }

    #[test]
    fn vr_classification_matches_table2() {
        assert!(!ShiftRule::Fixed.is_variance_reduced());
        assert!(ShiftRule::Star { c: None }.is_variance_reduced());
        assert!(ShiftRule::Diana {
            alpha: 0.1,
            c: None
        }
        .is_variance_reduced());
        assert!(ShiftRule::RandDiana { p: 0.1 }.is_variance_reduced());
    }
}
