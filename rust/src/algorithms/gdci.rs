//! Compressed-iterates methods: GDCI (Theorem 5) and VR-GDCI
//! (Algorithm 2 / Theorem 6).
//!
//! GDCI:
//! ```text
//! x^{k+1} = (1 − η) x^k + η (1/n) Σ_i Q_i(x^k − γ ∇f_i(x^k))
//! ```
//! Through the shifted-compressor lens (§3.3) this is a gradient step with
//! the shifted operator `Q̃ ∈ U(ω; x^k/γ)` — which is why the improved
//! κ(1+ω/n) rate follows from the same framework as DCGD-SHIFT.
//!
//! VR-GDCI adds a learned shift h_i on the *iterates*:
//! ```text
//! δ_i = Q_i(T_i(x^k) − h_i^k),  h_i^{k+1} = h_i^k + α δ_i,
//! x^{k+1} = (1 − η) x^k + η (h^k + δ^k)
//! ```
//! eliminating the compression neighborhood entirely.
//!
//! # Downlink
//!
//! Both drivers account the broadcast the same way the DCGD-SHIFT family
//! does: a round-0 dense resync, then one measured delta frame
//! `x^{k+1} − x^k` per round ([`crate::wire::build_update_packet`]) instead of
//! the former dense `n·d·prec` formula — and [`Gdci::set_downlink`] /
//! [`VrGdci::set_downlink`] arm the same error-fed-back compressed
//! broadcast ([`crate::downlink::EfDownlink`]) the coordinator supports,
//! with workers evaluating their gradient maps at the shared lossy
//! replica. The GDCI mixing update touches every coordinate, so the exact
//! delta is dense — exactly the regime where a Top-K EF downlink keeps
//! the broadcast O(K).

use crate::algorithms::{Algorithm, StepStats};
use crate::compressors::{Compressor, Packet, PayloadBitsCache, ValPrec};
use crate::downlink::DownlinkState;
use crate::linalg::{axpy, zero};
use crate::problems::Problem;
use crate::theory;
use crate::util::rng::Pcg64;

// The broadcast-side glue (measured delta-frame accounting, the optional
// error-fed-back downlink with its shared worker replica) lives in the
// library-wide [`DownlinkState`] — the GDCI drivers use its raw-difference
// [`DownlinkState::finish_round`] flavor, which folds the quantization
// residual of the mixing update into the EF accumulator too.

// ---------------------------------------------------------------------- GDCI

pub struct Gdci {
    x: Vec<f64>,
    pub gamma: f64,
    pub eta: f64,
    pub prec: ValPrec,
    qs: Vec<Box<dyn Compressor>>,
    rngs: Vec<Pcg64>,
    grad: Vec<f64>,
    t_buf: Vec<f64>,
    /// recycled compression scratch (workers are driven sequentially)
    pkt: Packet,
    /// per-shape payload-bits cache (homogeneous fleets hit every round)
    bits_cache: PayloadBitsCache,
    mix: Vec<f64>,
    downlink: DownlinkState,
}

impl Gdci {
    /// Step sizes from Theorem 5.
    pub fn new(p: &dyn Problem, q: impl Compressor + Clone + 'static, seed: u64) -> Self {
        let omega = q.omega().expect("GDCI needs unbiased Q");
        let ss = theory::gdci(p, omega);
        Self::with_steps(p, q, ss.gamma, ss.eta, seed)
    }

    /// Step sizes from the original Chraibi et al. (2019) analysis,
    /// specialized to gradient mappings — used by the ablation bench to
    /// show the κ² → κ improvement.
    pub fn new_chraibi(p: &dyn Problem, q: impl Compressor + Clone + 'static, seed: u64) -> Self {
        let omega = q.omega().expect("GDCI needs unbiased Q");
        // Original rate ~ κ·max{1, κω/n}: the older analysis forces the
        // mixing weight down by an extra κ (or κω/n) factor.
        let kappa = p.kappa();
        let n = p.n_workers() as f64;
        let ss = theory::gdci(p, omega);
        let slowdown = (kappa * omega / n).max(1.0);
        Self::with_steps(p, q, ss.gamma, ss.eta / slowdown, seed)
    }

    pub fn with_steps(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        gamma: f64,
        eta: f64,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let d = p.dim();
        let mut root = Pcg64::with_stream(seed, 0x6dc1);
        let x = crate::algorithms::paper_x0(d, seed);
        let rngs: Vec<Pcg64> = (0..n).map(|i| root.stream(i as u64 + 1)).collect();
        let mut downlink = DownlinkState::new(&x, root.stream(n as u64 + 1));
        downlink.track_deltas(&x);
        Self {
            x,
            gamma,
            eta,
            prec: ValPrec::F64,
            qs: (0..n)
                .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
                .collect(),
            rngs,
            grad: vec![0.0; d],
            t_buf: vec![0.0; d],
            pkt: Packet::Zero { dim: d as u32 },
            bits_cache: PayloadBitsCache::new(),
            mix: vec![0.0; d],
            downlink,
        }
    }

    pub fn set_x0(&mut self, x0: Vec<f64>) {
        self.x = x0;
        self.downlink.resync(&self.x);
    }

    /// Arm the error-fed-back compressed broadcast (see the module doc).
    pub fn set_downlink(&mut self, comp: Box<dyn Compressor>) {
        self.downlink.arm(comp, &self.x);
    }

    /// The EF downlink's error accumulator (`None` on the exact path).
    pub fn ef_error(&self) -> Option<&[f64]> {
        self.downlink.ef_error()
    }
}

impl Algorithm for Gdci {
    fn name(&self) -> String {
        "gdci".into()
    }
    fn compressor_desc(&self) -> String {
        self.qs.first().map(|q| q.name()).unwrap_or_default()
    }
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn step(&mut self, p: &dyn Problem) -> StepStats {
        let n = self.qs.len();
        let d = self.x.len();
        let inv_n = 1.0 / n as f64;
        let mut bits_up = 0;
        zero(&mut self.mix);
        for i in 0..n {
            // workers hold the (possibly lossy) broadcast replica
            let x_eval = self.downlink.x_eval(&self.x);
            p.local_grad_into(i, x_eval, &mut self.grad);
            // T_i(x̂) = x̂ − γ ∇f_i(x̂)
            for j in 0..d {
                self.t_buf[j] = x_eval[j] - self.gamma * self.grad[j];
            }
            self.qs[i].compress_into(&mut self.rngs[i], &self.t_buf, &mut self.pkt);
            self.pkt.quantize(self.prec);
            bits_up += self.bits_cache.bits(&self.pkt, self.prec);
            // sparse-aware O(nnz) aggregation, no dense decode
            self.pkt.add_scaled_into(inv_n, &mut self.mix);
        }
        // x^{k+1} = (1−η) x + η mix
        for j in 0..d {
            self.x[j] = (1.0 - self.eta) * self.x[j] + self.eta * self.mix[j];
        }
        let bits_down = self.downlink.finish_round(&self.x, n, self.prec);
        StepStats {
            bits_up,
            bits_down,
            bits_refresh: 0,
            active_workers: n,
            replica_bytes: self.downlink.replica_footprint(),
        }
    }
}

// ------------------------------------------------------------------- VR-GDCI

pub struct VrGdci {
    x: Vec<f64>,
    pub gamma: f64,
    pub eta: f64,
    pub alpha: f64,
    pub prec: ValPrec,
    qs: Vec<Box<dyn Compressor>>,
    rngs: Vec<Pcg64>,
    /// worker shifts h_i (on iterates)
    h: Vec<Vec<f64>>,
    /// master aggregate h^k
    h_master: Vec<f64>,
    grad: Vec<f64>,
    t_buf: Vec<f64>,
    /// recycled compression scratch (workers are driven sequentially)
    pkt: Packet,
    /// per-shape payload-bits cache (homogeneous fleets hit every round)
    bits_cache: PayloadBitsCache,
    delta_sum: Vec<f64>,
    downlink: DownlinkState,
}

impl VrGdci {
    pub fn new(p: &dyn Problem, q: impl Compressor + Clone + 'static, seed: u64) -> Self {
        let omega = q.omega().expect("VR-GDCI needs unbiased Q");
        let ss = theory::vr_gdci(p, omega);
        Self::with_steps(p, q, ss.gamma, ss.eta, ss.alpha, seed)
    }

    pub fn with_steps(
        p: &dyn Problem,
        q: impl Compressor + Clone + 'static,
        gamma: f64,
        eta: f64,
        alpha: f64,
        seed: u64,
    ) -> Self {
        let n = p.n_workers();
        let d = p.dim();
        let mut root = Pcg64::with_stream(seed, 0x76dc);
        let x = crate::algorithms::paper_x0(d, seed);
        let rngs: Vec<Pcg64> = (0..n).map(|i| root.stream(i as u64 + 1)).collect();
        let mut downlink = DownlinkState::new(&x, root.stream(n as u64 + 1));
        downlink.track_deltas(&x);
        Self {
            x,
            gamma,
            eta,
            alpha,
            prec: ValPrec::F64,
            qs: (0..n)
                .map(|_| Box::new(q.clone()) as Box<dyn Compressor>)
                .collect(),
            rngs,
            h: vec![vec![0.0; d]; n],
            h_master: vec![0.0; d],
            grad: vec![0.0; d],
            t_buf: vec![0.0; d],
            pkt: Packet::Zero { dim: d as u32 },
            bits_cache: PayloadBitsCache::new(),
            delta_sum: vec![0.0; d],
            downlink,
        }
    }

    pub fn set_x0(&mut self, x0: Vec<f64>) {
        self.x = x0;
        self.downlink.resync(&self.x);
    }

    /// Arm the error-fed-back compressed broadcast (see the module doc).
    pub fn set_downlink(&mut self, comp: Box<dyn Compressor>) {
        self.downlink.arm(comp, &self.x);
    }

    /// The EF downlink's error accumulator (`None` on the exact path).
    pub fn ef_error(&self) -> Option<&[f64]> {
        self.downlink.ef_error()
    }

    pub fn shift(&self, worker: usize) -> &[f64] {
        &self.h[worker]
    }
}

impl Algorithm for VrGdci {
    fn name(&self) -> String {
        "vr-gdci".into()
    }
    fn compressor_desc(&self) -> String {
        self.qs.first().map(|q| q.name()).unwrap_or_default()
    }
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn step(&mut self, p: &dyn Problem) -> StepStats {
        let n = self.qs.len();
        let d = self.x.len();
        let inv_n = 1.0 / n as f64;
        let mut bits_up = 0;
        zero(&mut self.delta_sum);
        for i in 0..n {
            // workers hold the (possibly lossy) broadcast replica
            let x_eval = self.downlink.x_eval(&self.x);
            p.local_grad_into(i, x_eval, &mut self.grad);
            // compress shifted local model: δ_i = Q_i(T_i(x̂) − h_i)
            for j in 0..d {
                self.t_buf[j] = x_eval[j] - self.gamma * self.grad[j] - self.h[i][j];
            }
            self.qs[i].compress_into(&mut self.rngs[i], &self.t_buf, &mut self.pkt);
            self.pkt.quantize(self.prec);
            bits_up += self.bits_cache.bits(&self.pkt, self.prec);
            // h_i^{k+1} = h_i^k + α δ_i — applied at O(nnz) from the packet
            self.pkt.add_scaled_into(self.alpha, &mut self.h[i]);
            self.pkt.add_scaled_into(inv_n, &mut self.delta_sum);
        }
        // master: Δ = δ + h^k; x = (1−η)x + ηΔ; h^{k+1} = h^k + αδ
        for j in 0..d {
            let big_delta = self.delta_sum[j] + self.h_master[j];
            self.x[j] = (1.0 - self.eta) * self.x[j] + self.eta * big_delta;
        }
        axpy(self.alpha, &self.delta_sum, &mut self.h_master);
        let bits_down = self.downlink.finish_round(&self.x, n, self.prec);
        StepStats {
            bits_up,
            bits_down,
            bits_refresh: 0,
            active_workers: n,
            replica_bytes: self.downlink.replica_footprint(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunOpts;
    use crate::compressors::{Identity, RandK};
    use crate::problems::{Problem, Ridge};
    use crate::theory;

    fn ridge() -> Ridge {
        Ridge::paper_default(2)
    }

    #[test]
    fn gdci_identity_reduces_to_relaxed_gd() {
        // Q = I ⇒ x^{k+1} = x − ηγ∇f(x): plain GD with step ηγ.
        let p = ridge();
        let mut alg = Gdci::new(&p, Identity::new(p.dim()), 3);
        let step = alg.eta * alg.gamma;
        let mut x = alg.x().to_vec();
        for _ in 0..30 {
            alg.step(&p);
            let g = p.grad(&x);
            crate::linalg::axpy(-step, &g, &mut x);
        }
        let diff = crate::linalg::dist_sq(alg.x(), &x).sqrt();
        assert!(diff < 1e-9, "drift {diff}");
    }

    #[test]
    fn gdci_converges_to_neighborhood() {
        let p = ridge();
        let mut alg = Gdci::new(&p, RandK::with_q(p.dim(), 0.5), 5);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 60_000,
                tol: 1e-30,
                record_every: 50,
                ..Default::default()
            },
        );
        assert!(!trace.diverged, "GDCI diverged");
        let floor = trace.error_floor();
        // Theorem 5 neighborhood (relative to ‖x⁰−x*‖²)
        let ss = theory::gdci(&p, 1.0);
        let x0 = crate::algorithms::paper_x0(p.dim(), 5);
        let denom = crate::linalg::dist_sq(&x0, p.x_star());
        let radius = theory::gdci_neighborhood(&p, 1.0, ss.gamma, ss.eta) / denom;
        assert!(
            floor <= radius * 10.0 && floor > radius / 1e6,
            "floor {floor:e} vs theoretical radius {radius:e}"
        );
    }

    #[test]
    fn vr_gdci_converges_exactly() {
        let p = ridge();
        let mut alg = VrGdci::new(&p, RandK::with_q(p.dim(), 0.5), 7);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 120_000,
                tol: 1e-22,
                record_every: 100,
                ..Default::default()
            },
        );
        assert!(
            trace.converged,
            "VR-GDCI floor {:e} (should be exact)",
            trace.error_floor()
        );
    }

    #[test]
    fn vr_gdci_beats_gdci_floor() {
        let p = ridge();
        let opts = RunOpts {
            max_rounds: 40_000,
            tol: 1e-26,
            record_every: 100,
            ..Default::default()
        };
        let gdci_floor = Gdci::new(&p, RandK::with_q(p.dim(), 0.5), 9)
            .run(&p, &opts)
            .error_floor();
        let vr_floor = VrGdci::new(&p, RandK::with_q(p.dim(), 0.5), 9)
            .run(&p, &opts)
            .error_floor();
        assert!(
            vr_floor < gdci_floor * 1e-3,
            "vr {vr_floor:e} should be orders below gdci {gdci_floor:e}"
        );
    }

    #[test]
    fn vr_gdci_shifts_learn_tx_star() {
        // h_i → T_i(x*) = x* − γ∇f_i(x*) (Theorem 6's σ → 0).
        let p = ridge();
        let mut alg = VrGdci::new(&p, RandK::with_q(p.dim(), 0.5), 11);
        let gamma = alg.gamma;
        let _ = alg.run(
            &p,
            &RunOpts {
                max_rounds: 120_000,
                tol: 1e-24,
                record_every: 200,
                ..Default::default()
            },
        );
        for w in 0..p.n_workers() {
            let gs = p.grad_star(w);
            let target: Vec<f64> = p
                .x_star()
                .iter()
                .zip(gs.iter())
                .map(|(x, g)| x - gamma * g)
                .collect();
            let rel = crate::linalg::dist_sq(alg.shift(w), &target).sqrt()
                / crate::linalg::nrm2(&target).max(1e-12);
            assert!(rel < 1e-5, "worker {w}: shift off by {rel}");
        }
    }

    #[test]
    fn improved_eta_larger_than_chraibi() {
        let p = ridge();
        let ours = Gdci::new(&p, RandK::with_q(p.dim(), 0.1), 1);
        let old = Gdci::new_chraibi(&p, RandK::with_q(p.dim(), 0.1), 1);
        assert!(
            ours.eta > 5.0 * old.eta,
            "improved η {} vs old {}",
            ours.eta,
            old.eta
        );
    }
}
