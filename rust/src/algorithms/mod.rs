//! Optimization algorithms: the DCGD-SHIFT meta-algorithm (Algorithm 1)
//! with pluggable shift rules, the compressed-iterates family (GDCI /
//! VR-GDCI, Algorithm 2), and uncompressed baselines.
//!
//! These single-process drivers are *semantically distributed*: each worker
//! slot owns its compressor, RNG stream and shift state, and every message
//! that would cross the network is materialized as a [`Packet`] whose
//! payload bits are accounted. The threaded runtime in
//! [`crate::coordinator`] runs the same per-worker code over channels and
//! is property-tested to produce bit-identical trajectories.

pub mod dcgd_shift;
pub mod gd;
pub mod gdci;
pub mod shift_rules;

pub use dcgd_shift::DcgdShift;
pub use gd::Gd;
pub use gdci::{Gdci, VrGdci};
pub use shift_rules::ShiftRule;

use crate::compressors::ValPrec;
use crate::metrics::{RoundRecord, Trace};
use crate::problems::Problem;

/// Alias kept for API compatibility: plain DCGD is DCGD-SHIFT with zero
/// fixed shifts.
pub type Dcgd = DcgdShift;

/// Options controlling a run.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub max_rounds: usize,
    /// stop when ‖x−x*‖²/‖x⁰−x*‖² ≤ tol
    pub tol: f64,
    /// record a trace point every this many rounds (1 = every round)
    pub record_every: usize,
    /// declare divergence when rel_err exceeds this
    pub blowup: f64,
    /// wire precision for bit accounting
    pub prec: ValPrec,
    /// also record f(x) (costs one extra pass per record)
    pub record_loss: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            max_rounds: 10_000,
            tol: 1e-12,
            record_every: 1,
            blowup: 1e9,
            prec: ValPrec::F64,
            record_loss: false,
        }
    }
}

/// Per-round statistics returned by [`Algorithm::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// worker→master gradient-message payload bits this round (sum over
    /// workers)
    pub bits_up: u64,
    /// master→worker broadcast bits this round
    pub bits_down: u64,
    /// shift-state synchronization bits this round (Rand-DIANA refreshes,
    /// STAR displacement frames) — tracked separately so both accounting
    /// conventions can be reported
    pub bits_refresh: u64,
    /// workers whose reports folded into this round's aggregate — the
    /// fleet size for drivers that cannot degrade, fewer than that when
    /// the coordinator quarantined or missed workers (see
    /// [`crate::coordinator::DistributedRunner::health`])
    pub active_workers: usize,
    /// resident bytes of iterate-replica state this round: on the
    /// distributed runner, the fleet-shared snapshot/overlay publication
    /// (`O(d + overlay nnz)`, flat in the worker count) plus any
    /// worker-private dense iterate the workers reported (the
    /// `local_steps > 1` local iterate); single-process drivers report
    /// their downlink replica-mirror footprint (0 when no mirror exists)
    pub replica_bytes: u64,
}

/// A round-synchronous distributed optimization algorithm.
pub trait Algorithm {
    fn name(&self) -> String;
    /// Description of the compressor configuration (for trace labels).
    fn compressor_desc(&self) -> String;
    /// Current iterate.
    fn x(&self) -> &[f64];
    /// Execute one communication round.
    fn step(&mut self, p: &dyn Problem) -> StepStats;

    /// Drive the algorithm, recording a [`Trace`].
    fn run(&mut self, p: &dyn Problem, opts: &RunOpts) -> Trace {
        let mut trace = Trace::new(&self.name(), &self.compressor_desc());
        let x_star = p.x_star().to_vec();
        let denom = crate::linalg::dist_sq(self.x(), &x_star).max(1e-300);
        let mut bits_up: u64 = 0;
        let mut bits_down: u64 = 0;
        let mut bits_refresh: u64 = 0;

        // round 0 record
        trace.push(RoundRecord {
            round: 0,
            rel_err: 1.0,
            bits_up: 0,
            bits_refresh: 0,
            bits_down: 0,
            sim_time: 0.0,
            loss: if opts.record_loss {
                p.loss(self.x())
            } else {
                f64::NAN
            },
        });

        for k in 0..opts.max_rounds {
            let stats = self.step(p);
            bits_up += stats.bits_up;
            bits_down += stats.bits_down;
            bits_refresh += stats.bits_refresh;
            let record_now = (k + 1) % opts.record_every == 0 || k + 1 == opts.max_rounds;
            if record_now {
                let rel_err = crate::linalg::dist_sq(self.x(), &x_star) / denom;
                trace.push(RoundRecord {
                    round: k + 1,
                    rel_err,
                    bits_up,
                    bits_refresh,
                    bits_down,
                    sim_time: 0.0,
                    loss: if opts.record_loss {
                        p.loss(self.x())
                    } else {
                        f64::NAN
                    },
                });
                if rel_err <= opts.tol {
                    trace.converged = true;
                    break;
                }
                if !rel_err.is_finite() || rel_err > opts.blowup {
                    trace.diverged = true;
                    break;
                }
            }
        }
        trace
    }
}

/// Sample the paper's starting point: entries i.i.d. normal with std 10
/// ("sampled from the normal distribution N(0, 10)").
pub fn paper_x0(d: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::util::rng::Pcg64::with_stream(seed, 0x0f0);
    (0..d).map(|_| rng.normal() * 10.0).collect()
}
