//! Uncompressed baselines: (distributed) gradient descent.
//!
//! DGD is DCGD-SHIFT with the identity operator (Table 2, "folklore" row);
//! this standalone implementation is the cross-check oracle for the
//! reductions in the property tests, and the no-compression baseline in the
//! figures (it transfers `n·d` values per round).

use crate::algorithms::{Algorithm, StepStats};
use crate::compressors::ValPrec;
use crate::problems::Problem;

pub struct Gd {
    x: Vec<f64>,
    pub gamma: f64,
    pub prec: ValPrec,
    n_workers: usize,
    grad: Vec<f64>,
}

impl Gd {
    /// γ = 2/(L+μ), the optimal fixed step for smooth strongly convex GD.
    pub fn new(p: &dyn Problem, seed: u64) -> Self {
        Self::with_gamma(p, 2.0 / (p.l() + p.mu()), seed)
    }

    /// γ = 1/L (the conservative textbook step).
    pub fn conservative(p: &dyn Problem, seed: u64) -> Self {
        Self::with_gamma(p, 1.0 / p.l(), seed)
    }

    pub fn with_gamma(p: &dyn Problem, gamma: f64, seed: u64) -> Self {
        Self {
            x: crate::algorithms::paper_x0(p.dim(), seed),
            gamma,
            prec: ValPrec::F64,
            n_workers: p.n_workers(),
            grad: vec![0.0; p.dim()],
        }
    }

    pub fn set_x0(&mut self, x0: Vec<f64>) {
        self.x = x0;
    }
}

impl Algorithm for Gd {
    fn name(&self) -> String {
        "dgd".into()
    }
    fn compressor_desc(&self) -> String {
        "identity".into()
    }
    fn x(&self) -> &[f64] {
        &self.x
    }
    fn step(&mut self, p: &dyn Problem) -> StepStats {
        p.grad_into(&self.x, &mut self.grad);
        crate::linalg::axpy(-self.gamma, &self.grad, &mut self.x);
        let d = self.x.len() as u64;
        StepStats {
            bits_up: self.n_workers as u64 * d * self.prec.bits(),
            bits_down: self.n_workers as u64 * d * self.prec.bits(),
            bits_refresh: 0,
            active_workers: self.n_workers,
            replica_bytes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::RunOpts;
    use crate::problems::Ridge;

    #[test]
    fn gd_converges_linearly_to_exact_optimum() {
        let p = Ridge::paper_default(3);
        let mut alg = Gd::new(&p, 3);
        let trace = alg.run(
            &p,
            &RunOpts {
                max_rounds: 20_000,
                tol: 1e-24,
                record_every: 10,
                ..Default::default()
            },
        );
        assert!(trace.converged, "floor {:e}", trace.error_floor());
        // monotone decrease (deterministic method, suitable γ)
        let errs: Vec<f64> = trace.records.iter().map(|r| r.rel_err).collect();
        assert!(errs.windows(2).all(|w| w[1] <= w[0] * (1.0 + 1e-12)));
    }

    #[test]
    fn optimal_step_beats_conservative() {
        let p = Ridge::paper_default(4);
        let opts = RunOpts {
            max_rounds: 5_000,
            tol: 1e-20,
            record_every: 1,
            ..Default::default()
        };
        let fast = Gd::new(&p, 4).run(&p, &opts);
        let slow = Gd::conservative(&p, 4).run(&p, &opts);
        match (fast.rounds_to_tol(1e-10), slow.rounds_to_tol(1e-10)) {
            (Some(a), Some(b)) => assert!(a <= b, "{a} vs {b}"),
            (Some(_), None) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn bits_count_full_vectors() {
        let p = Ridge::paper_default(5);
        let mut alg = Gd::new(&p, 5);
        let stats = alg.step(&p);
        assert_eq!(stats.bits_up, 10 * 80 * 64);
    }
}
