//! Optimization problems: `min_x f(x) = (1/n) Σ f_i(x)` (problem (★)).
//!
//! Every problem exposes the local gradient oracles `∇f_i`, the smoothness
//! constants `L_i`, `L`, the strong-convexity constant `μ`, the optimum
//! `x*` and the optimal local gradients `∇f_i(x*)` — everything the paper's
//! step-size rules (Theorems 1–6) and the DCGD-STAR shift need.

pub mod agd;
pub mod logistic;
pub mod quadratic;
pub mod ridge;

pub use logistic::Logistic;
pub use quadratic::Quadratic;
pub use ridge::Ridge;

/// A distributed, smooth, strongly convex problem.
pub trait Problem: Send + Sync {
    /// Parameter dimension d.
    fn dim(&self) -> usize;
    /// Number of workers n.
    fn n_workers(&self) -> usize;

    /// Local gradient `∇f_i(x)` into a preallocated buffer.
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]);

    /// Local objective `f_i(x)`.
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64;

    /// Smoothness constant of `f_i`.
    fn l_i(&self, worker: usize) -> f64;

    /// Smoothness constant of `f` (≤ mean of `L_i`; problems compute the
    /// exact/global value where available).
    fn l(&self) -> f64;

    /// Strong convexity constant of `f`.
    fn mu(&self) -> f64;

    /// The optimum `x*`.
    fn x_star(&self) -> &[f64];

    /// Optimal local gradient `∇f_i(x*)` (precomputed at construction).
    fn grad_star(&self, worker: usize) -> &[f64];

    // ------------------------------------------------ provided methods

    fn l_max(&self) -> f64 {
        (0..self.n_workers())
            .map(|i| self.l_i(i))
            .fold(0.0, f64::max)
    }

    /// Condition number κ = L/μ.
    fn kappa(&self) -> f64 {
        self.l() / self.mu()
    }

    /// Full gradient `∇f(x) = (1/n) Σ ∇f_i(x)` into a buffer.
    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n_workers();
        let mut tmp = vec![0.0; self.dim()];
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            self.local_grad_into(i, x, &mut tmp);
            crate::linalg::axpy(1.0 / n as f64, &tmp, out);
        }
    }

    fn grad(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.grad_into(x, &mut out);
        out
    }

    /// Full objective `f(x)`.
    fn loss(&self, x: &[f64]) -> f64 {
        let n = self.n_workers();
        (0..n).map(|i| self.local_loss(i, x)).sum::<f64>() / n as f64
    }

    /// Is the problem (numerically) in the interpolation regime
    /// `∇f_i(x*) = 0 ∀i`?
    fn is_interpolating(&self, tol: f64) -> bool {
        (0..self.n_workers()).all(|i| crate::linalg::nrm2(self.grad_star(i)) <= tol)
    }

    /// Mean squared optimal-gradient norm `(1/n) Σ ‖∇f_i(x*)‖²` — the
    /// quantity that controls the DCGD convergence neighborhood (Thm 1).
    fn grad_star_second_moment(&self) -> f64 {
        let n = self.n_workers();
        (0..n)
            .map(|i| crate::linalg::nrm2_sq(self.grad_star(i)))
            .sum::<f64>()
            / n as f64
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::Problem;

    /// Finite-difference check of local gradients — shared by problem tests.
    pub fn check_local_grads(p: &dyn Problem, x: &[f64], tol: f64) {
        let d = p.dim();
        let eps = 1e-6;
        for w in 0..p.n_workers() {
            let mut g = vec![0.0; d];
            p.local_grad_into(w, x, &mut g);
            for j in (0..d).step_by((d / 7).max(1)) {
                let mut xp = x.to_vec();
                xp[j] += eps;
                let mut xm = x.to_vec();
                xm[j] -= eps;
                let fd = (p.local_loss(w, &xp) - p.local_loss(w, &xm)) / (2.0 * eps);
                assert!(
                    (fd - g[j]).abs() <= tol * (1.0 + fd.abs()),
                    "worker {w} coord {j}: fd {fd} vs analytic {}",
                    g[j]
                );
            }
        }
    }

    /// The defining identity of (★): ∇f = mean of ∇f_i, and x* is a
    /// stationary point.
    pub fn check_stationarity(p: &dyn Problem, tol: f64) {
        let g = p.grad(p.x_star());
        let n = crate::linalg::nrm2(&g);
        assert!(n <= tol, "‖∇f(x*)‖ = {n} > {tol}");
        // grad_star consistency
        for w in 0..p.n_workers() {
            let mut g = vec![0.0; p.dim()];
            p.local_grad_into(w, p.x_star(), &mut g);
            let diff = crate::linalg::dist_sq(&g, p.grad_star(w)).sqrt();
            assert!(diff <= 1e-9, "worker {w}: grad_star stale by {diff}");
        }
    }
}
