//! Distributed ridge regression — the paper's Section 4 testbed.
//!
//! Global objective (paper formulation):
//! ```text
//! f(x) = 1/2 ‖A x − y‖² + λ/2 ‖x‖²,   λ = 1/m,
//! ```
//! with `A ∈ R^{m×d}, y ∈ R^m` from `make_regression` (m=100, d=80), rows
//! distributed uniformly/evenly/randomly over n=10 workers. Writing `S_i`
//! for worker i's rows, the local objective that makes `(1/n) Σ f_i = f` is
//! ```text
//! f_i(x) = n/2 Σ_{l ∈ S_i} (a_lᵀx − y_l)² + λ/2 ‖x‖².
//! ```
//! Hessians are constant: `∇²f_i = n·A_iᵀA_i + λI`, `∇²f = AᵀA + λI`, so
//! `L_i`, `L`, `μ` are exact eigenvalue computations, and `x*` solves the
//! normal equations `(AᵀA + λI) x = Aᵀy` (Cholesky).

use crate::data::{make_regression, partition_evenly, RegressionOpts};
use crate::linalg::{cholesky_solve, lambda_max, lambda_min_psd, Mat, SpectralOpts};
use crate::problems::Problem;
use crate::util::rng::Pcg64;

pub struct Ridge {
    d: usize,
    n: usize,
    lambda: f64,
    /// per-worker design matrix (m_i × d) and targets
    a_local: Vec<Mat>,
    y_local: Vec<Vec<f64>>,
    l_i: Vec<f64>,
    l: f64,
    mu: f64,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

impl Ridge {
    /// The paper's exact setup: `make_regression` defaults, m=100, d=80,
    /// λ = 1/m, 10 workers.
    pub fn paper_default(seed: u64) -> Self {
        let opts = RegressionOpts {
            n_samples: 100,
            n_features: 80,
            seed,
            ..Default::default()
        };
        Self::new(&opts, 10, 1.0 / opts.n_samples as f64, seed)
    }

    pub fn new(opts: &RegressionOpts, n_workers: usize, lambda: f64, seed: u64) -> Self {
        let ds = make_regression(opts);
        Self::from_data(ds.a, ds.y, n_workers, lambda, seed)
    }

    /// Build from explicit data (used by tests and custom drivers).
    pub fn from_data(a: Mat, y: Vec<f64>, n_workers: usize, lambda: f64, seed: u64) -> Self {
        let m = a.rows;
        let d = a.cols;
        assert_eq!(y.len(), m);
        let mut part_rng = Pcg64::with_stream(seed, 0x9a47);
        let parts = partition_evenly(m, n_workers, &mut part_rng);

        let mut a_local = Vec::with_capacity(n_workers);
        let mut y_local = Vec::with_capacity(n_workers);
        for rows in &parts {
            let mut ai = Mat::zeros(rows.len(), d);
            let mut yi = Vec::with_capacity(rows.len());
            for (r, &idx) in rows.iter().enumerate() {
                ai.row_mut(r).copy_from_slice(a.row(idx));
                yi.push(y[idx]);
            }
            a_local.push(ai);
            y_local.push(yi);
        }

        // Exact optimum via the normal equations.
        let mut h = a.gram(); // AᵀA
        h.add_diag(lambda);
        let aty = a.t_matvec(&y);
        let x_star = cholesky_solve(&h, &aty).expect("ridge Hessian must be SPD");

        // Constants.
        let sopts = SpectralOpts::default();
        let l = lambda_max(&h, sopts);
        let mu = lambda_min_psd(&h, sopts).max(lambda);
        let n_f = n_workers as f64;
        let l_i: Vec<f64> = a_local
            .iter()
            .map(|ai| {
                let mut hi = ai.gram();
                hi.scale(n_f);
                hi.add_diag(lambda);
                lambda_max(&hi, sopts)
            })
            .collect();

        let mut me = Self {
            d,
            n: n_workers,
            lambda,
            a_local,
            y_local,
            l_i,
            l,
            mu,
            x_star,
            grad_star: Vec::new(),
        };
        let mut gs = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let mut g = vec![0.0; d];
            me.local_grad_raw(w, &me.x_star.clone(), &mut g);
            gs.push(g);
        }
        me.grad_star = gs;
        me
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    fn local_grad_raw(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        // ∇f_i(x) = n·A_iᵀ(A_i x − y_i) + λ x
        let ai = &self.a_local[worker];
        let yi = &self.y_local[worker];
        let mut resid = ai.matvec(x);
        for (r, t) in resid.iter_mut().zip(yi.iter()) {
            *r -= t;
        }
        ai.t_matvec_into(&resid, out);
        let n = self.n as f64;
        for j in 0..self.d {
            out[j] = n * out[j] + self.lambda * x[j];
        }
    }
}

impl Problem for Ridge {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        self.local_grad_raw(worker, x, out);
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        let ai = &self.a_local[worker];
        let yi = &self.y_local[worker];
        let resid = ai.matvec(x);
        let ss: f64 = resid
            .iter()
            .zip(yi.iter())
            .map(|(r, t)| (r - t) * (r - t))
            .sum();
        0.5 * self.n as f64 * ss + 0.5 * self.lambda * crate::linalg::nrm2_sq(x)
    }
    fn l_i(&self, worker: usize) -> f64 {
        self.l_i[worker]
    }
    fn l(&self) -> f64 {
        self.l
    }
    fn mu(&self) -> f64 {
        self.mu
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::test_util::{check_local_grads, check_stationarity};

    fn problem() -> Ridge {
        Ridge::paper_default(42)
    }

    #[test]
    fn dimensions() {
        let p = problem();
        assert_eq!(p.dim(), 80);
        assert_eq!(p.n_workers(), 10);
        assert!((p.lambda() - 0.01).abs() < 1e-15);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let p = problem();
        let mut rng = Pcg64::new(7);
        let x: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        check_local_grads(&p, &x, 2e-4);
    }

    #[test]
    fn x_star_is_stationary() {
        let p = problem();
        check_stationarity(&p, 1e-8);
    }

    #[test]
    fn not_interpolating() {
        // Regularized regression with noiseless targets but λ > 0:
        // individual ∇f_i(x*) ≠ 0 — the regime the paper targets.
        let p = problem();
        assert!(!p.is_interpolating(1e-6));
        assert!(p.grad_star_second_moment() > 0.0);
    }

    #[test]
    fn constants_are_consistent() {
        let p = problem();
        assert!(p.mu() > 0.0);
        assert!(p.l() >= p.mu());
        // mean of local Hessians = global Hessian ⇒ L ≤ mean L_i ≤ L_max
        let mean_li: f64 =
            (0..p.n_workers()).map(|i| p.l_i(i)).sum::<f64>() / p.n_workers() as f64;
        assert!(p.l() <= mean_li * (1.0 + 1e-9), "{} vs {}", p.l(), mean_li);
        assert!(p.l_max() >= mean_li * (1.0 - 1e-9));
    }

    #[test]
    fn mean_of_local_losses_matches_global_formula() {
        let p = problem();
        let mut rng = Pcg64::new(9);
        let x: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        // rebuild global A,y from the same generator stream
        let ds = make_regression(&RegressionOpts {
            n_samples: 100,
            n_features: 80,
            seed: 42,
            ..Default::default()
        });
        let resid = ds.a.matvec(&x);
        let ss: f64 = resid
            .iter()
            .zip(ds.y.iter())
            .map(|(r, t)| (r - t) * (r - t))
            .sum();
        let expected = 0.5 * ss + 0.5 * 0.01 * crate::linalg::nrm2_sq(&x);
        let got = p.loss(&x);
        assert!(
            (got - expected).abs() < 1e-8 * expected.abs().max(1.0),
            "{got} vs {expected}"
        );
    }

    #[test]
    fn smoothness_bound_holds_along_random_directions() {
        // ‖∇f_i(x) − ∇f_i(y)‖ ≤ L_i ‖x − y‖
        let p = problem();
        let mut rng = Pcg64::new(11);
        for w in [0usize, 5, 9] {
            for _ in 0..5 {
                let x: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
                let mut gx = vec![0.0; 80];
                let mut gy = vec![0.0; 80];
                p.local_grad_into(w, &x, &mut gx);
                p.local_grad_into(w, &y, &mut gy);
                let lhs = crate::linalg::dist_sq(&gx, &gy).sqrt();
                let rhs = p.l_i(w) * crate::linalg::dist_sq(&x, &y).sqrt();
                assert!(lhs <= rhs * (1.0 + 1e-6), "worker {w}: {lhs} > {rhs}");
            }
        }
    }
}
