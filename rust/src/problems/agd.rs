//! Nesterov's Accelerated Gradient Descent for strongly convex objectives.
//!
//! Used exactly the way the paper uses it: "The 'optimum' x* is obtained by
//! running AGD for the whole dataset using one CPU core until
//! ‖∇f(x)‖² ≤ 1e-32". We expose a generic solver over a gradient closure so
//! the logistic problem (no closed form) can compute its reference optimum.

/// Result of an AGD solve.
#[derive(Clone, Debug)]
pub struct AgdResult {
    pub x: Vec<f64>,
    pub grad_norm_sq: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Minimize an L-smooth, μ-strongly-convex function given its gradient.
///
/// Constant-momentum variant: `β = (√κ − 1)/(√κ + 1)`, step `1/L`.
pub fn agd<G>(
    mut grad: G,
    x0: &[f64],
    l: f64,
    mu: f64,
    grad_tol_sq: f64,
    max_iters: usize,
) -> AgdResult
where
    G: FnMut(&[f64], &mut [f64]),
{
    let d = x0.len();
    assert!(l > 0.0 && mu > 0.0 && mu <= l);
    let kappa = l / mu;
    let beta = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
    let step = 1.0 / l;

    let mut x = x0.to_vec();
    let mut x_prev = x0.to_vec();
    let mut y = x0.to_vec();
    let mut g = vec![0.0; d];

    for k in 0..max_iters {
        grad(&y, &mut g);
        let gn = crate::linalg::nrm2_sq(&g);
        if gn <= grad_tol_sq {
            return AgdResult {
                x: y,
                grad_norm_sq: gn,
                iterations: k,
                converged: true,
            };
        }
        // x_{k+1} = y_k − (1/L) ∇f(y_k)
        for j in 0..d {
            let next = y[j] - step * g[j];
            x_prev[j] = x[j];
            x[j] = next;
        }
        // y_{k+1} = x_{k+1} + β (x_{k+1} − x_k)
        for j in 0..d {
            y[j] = x[j] + beta * (x[j] - x_prev[j]);
        }
    }
    grad(&x, &mut g);
    AgdResult {
        grad_norm_sq: crate::linalg::nrm2_sq(&g),
        x,
        iterations: max_iters,
        converged: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_quadratic_exactly() {
        // f(x) = 1/2 xᵀHx − bᵀx with known solution H⁻¹b.
        let mut rng = Pcg64::new(1);
        let n = 12;
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut h = b.transpose().matmul(&b);
        h.add_diag(0.5);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x_star = crate::linalg::cholesky_solve(&h, &rhs).unwrap();
        let l = crate::linalg::lambda_max(&h, Default::default());
        let mu = crate::linalg::lambda_min_psd(&h, Default::default());

        let res = agd(
            |x, g| {
                h.matvec_into(x, g);
                for j in 0..n {
                    g[j] -= rhs[j];
                }
            },
            &vec![0.0; n],
            l,
            mu,
            1e-28,
            200_000,
        );
        assert!(res.converged, "grad² {}", res.grad_norm_sq);
        let err = crate::linalg::dist_sq(&res.x, &x_star).sqrt();
        assert!(err < 1e-10, "err {err}");
    }

    #[test]
    fn accelerated_beats_plain_gd_iterations() {
        // Ill-conditioned diagonal: AGD should need far fewer iterations.
        let d = 50;
        let diag: Vec<f64> = (0..d).map(|i| 1.0 + 999.0 * i as f64 / (d - 1) as f64).collect();
        let grad = |x: &[f64], g: &mut [f64]| {
            for j in 0..d {
                g[j] = diag[j] * x[j];
            }
        };
        let x0 = vec![1.0; d];
        let res = agd(grad, &x0, 1000.0, 1.0, 1e-20, 100_000);
        assert!(res.converged);
        // plain GD needs ~ κ ln(1/ε) ≈ 1000·23 ≈ 23000; AGD ~ √κ·23 ≈ 730.
        assert!(res.iterations < 3_000, "iters {}", res.iterations);
    }

    #[test]
    fn reports_nonconvergence() {
        // Deliberately mis-specified L (too small ⇒ overshooting steps):
        // AGD cannot converge and must report so.
        let res = agd(
            |x, g| {
                g.copy_from_slice(x);
                g[0] += 10.0;
            },
            &[5.0],
            0.1,
            0.1,
            1e-32,
            3,
        );
        assert!(!res.converged);
        assert_eq!(res.iterations, 3);
    }
}
