//! Distributed ℓ2-regularized logistic regression — the supplementary
//! experiment (Figure 4), run on a w2a-like LibSVM dataset.
//!
//! Local objective (paper, Section C):
//! ```text
//! f_i(x) = 1/m_i Σ_{l ∈ S_i} log(1 + exp(−b_l · a_lᵀ x)) + λ/2 ‖x‖²
//! ```
//! λ is chosen so the condition number of `f` equals a target (the paper
//! uses κ = 100): with `L₀ = λ_max((1/n) Σ (1/(4 m_i)) A_iᵀA_i)` the
//! data-smoothness upper bound, `λ = L₀/(κ − 1)` gives
//! `L/μ ≤ (L₀ + λ)/λ = κ`.
//!
//! `x*` is computed as in the paper: Nesterov AGD on the full objective
//! until `‖∇f(x)‖² ≤ 1e-28` (f64 floor of the paper's 1e-32).

use crate::data::{partition_evenly, SparseDataset, SparseRow};
use crate::linalg::{lambda_max, Mat, SpectralOpts};
use crate::problems::agd::agd;
use crate::problems::Problem;
use crate::util::rng::Pcg64;

pub struct Logistic {
    d: usize,
    n: usize,
    lambda: f64,
    /// rows per worker
    shards: Vec<Vec<SparseRow>>,
    l_i: Vec<f64>,
    l: f64,
    mu: f64,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

#[inline]
fn log1p_exp(t: f64) -> f64 {
    // numerically stable log(1 + e^t)
    if t > 30.0 {
        t
    } else if t < -30.0 {
        t.exp()
    } else {
        t.exp().ln_1p()
    }
}

#[inline]
fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

impl Logistic {
    /// Build from a LibSVM-style dataset with λ targeting condition number
    /// `kappa` (paper: 100).
    pub fn from_dataset(ds: &SparseDataset, n_workers: usize, kappa: f64, seed: u64) -> Self {
        assert!(kappa > 1.0);
        let d = ds.n_features;
        let mut part_rng = Pcg64::with_stream(seed, 0x109);
        let parts = partition_evenly(ds.len(), n_workers, &mut part_rng);
        let shards: Vec<Vec<SparseRow>> = parts
            .iter()
            .map(|rows| rows.iter().map(|&i| ds.rows[i].clone()).collect())
            .collect();

        // Data-smoothness: per-worker Gram of (1/(4 m_i)) A_iᵀA_i, and the
        // global average. d is small (≤ a few hundred) so dense Grams are
        // cheap and exact.
        let sopts = SpectralOpts::default();
        let mut global = Mat::zeros(d, d);
        let mut l0_i = Vec::with_capacity(n_workers);
        for shard in &shards {
            let m_i = shard.len() as f64;
            let mut gram = Mat::zeros(d, d);
            for row in shard {
                // gram += a aᵀ (sparse outer product)
                for (pi, &i) in row.indices.iter().enumerate() {
                    let vi = row.values[pi];
                    for (pj, &j) in row.indices.iter().enumerate() {
                        let vj = row.values[pj];
                        gram.data[i as usize * d + j as usize] += vi * vj;
                    }
                }
            }
            gram.scale(1.0 / (4.0 * m_i));
            l0_i.push(lambda_max(&gram, sopts));
            // accumulate into global average
            for (g, v) in global.data.iter_mut().zip(gram.data.iter()) {
                *g += v / n_workers as f64;
            }
        }
        let l0 = lambda_max(&global, sopts);
        let lambda = l0 / (kappa - 1.0);
        let l = l0 + lambda;
        let mu = lambda;
        let l_i: Vec<f64> = l0_i.iter().map(|&v| v + lambda).collect();

        let mut me = Self {
            d,
            n: n_workers,
            lambda,
            shards,
            l_i,
            l,
            mu,
            x_star: vec![0.0; d],
            grad_star: Vec::new(),
        };

        // Reference optimum via AGD (paper's procedure).
        let x0 = vec![0.0; d];
        let res = agd(
            |x, g| me.full_grad_into(x, g),
            &x0,
            l,
            mu,
            1e-28,
            2_000_000,
        );
        assert!(
            res.converged,
            "AGD failed to converge: ‖∇f‖² = {:.3e}",
            res.grad_norm_sq
        );
        me.x_star = res.x;

        let mut gs = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let mut g = vec![0.0; d];
            me.local_grad_raw(w, &me.x_star.clone(), &mut g);
            gs.push(g);
        }
        me.grad_star = gs;
        me
    }

    /// The paper-style setup on the synthetic w2a stand-in.
    pub fn w2a_default(n_workers: usize, seed: u64) -> Self {
        let ds = crate::data::synthetic_w2a(&crate::data::W2aOpts {
            seed,
            ..Default::default()
        });
        Self::from_dataset(&ds, n_workers, 100.0, seed)
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    fn local_grad_raw(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        let shard = &self.shards[worker];
        let m_i = shard.len() as f64;
        out.iter_mut().for_each(|v| *v = 0.0);
        for row in shard {
            let t = row.label * row.dot(x);
            // d/dx log(1+exp(−t)) = −b·σ(−t)·a
            let coeff = -row.label * sigmoid(-t) / m_i;
            row.axpy_into(coeff, out);
        }
        for j in 0..self.d {
            out[j] += self.lambda * x[j];
        }
    }

    fn full_grad_into(&self, x: &[f64], out: &mut [f64]) {
        let mut tmp = vec![0.0; self.d];
        out.iter_mut().for_each(|v| *v = 0.0);
        for w in 0..self.n {
            self.local_grad_raw(w, x, &mut tmp);
            crate::linalg::axpy(1.0 / self.n as f64, &tmp, out);
        }
    }
}

impl Problem for Logistic {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        self.local_grad_raw(worker, x, out);
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        let shard = &self.shards[worker];
        let m_i = shard.len() as f64;
        let mut s = 0.0;
        for row in shard {
            s += log1p_exp(-row.label * row.dot(x));
        }
        s / m_i + 0.5 * self.lambda * crate::linalg::nrm2_sq(x)
    }
    fn l_i(&self, worker: usize) -> f64 {
        self.l_i[worker]
    }
    fn l(&self) -> f64 {
        self.l
    }
    fn mu(&self) -> f64 {
        self.mu
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::W2aOpts;
    use crate::problems::test_util::{check_local_grads, check_stationarity};

    fn small_problem() -> Logistic {
        // Smaller corpus than the default for test speed.
        let ds = crate::data::synthetic_w2a(&W2aOpts {
            n_samples: 400,
            n_features: 60,
            seed: 3,
            ..Default::default()
        });
        Logistic::from_dataset(&ds, 5, 100.0, 3)
    }

    #[test]
    fn gradients_match_finite_differences() {
        let p = small_problem();
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal() * 0.5).collect();
        check_local_grads(&p, &x, 5e-5);
    }

    #[test]
    fn x_star_is_stationary_and_nontrivial() {
        let p = small_problem();
        check_stationarity(&p, 1e-10);
        assert!(crate::linalg::nrm2(p.x_star()) > 1e-3);
        assert!(!p.is_interpolating(1e-8));
    }

    #[test]
    fn condition_number_is_targeted() {
        let p = small_problem();
        let kappa = p.kappa();
        assert!(
            (kappa - 100.0).abs() < 1.0,
            "κ = {kappa}, expected ≈ 100 by construction"
        );
    }

    #[test]
    fn sigmoid_stability() {
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(log1p_exp(800.0).is_finite());
        assert!(log1p_exp(-800.0) >= 0.0);
    }

    #[test]
    fn smoothness_bounds_hold() {
        let p = small_problem();
        let mut rng = Pcg64::new(6);
        for w in 0..p.n_workers() {
            for _ in 0..3 {
                let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
                let mut gx = vec![0.0; p.dim()];
                let mut gy = vec![0.0; p.dim()];
                p.local_grad_into(w, &x, &mut gx);
                p.local_grad_into(w, &y, &mut gy);
                let lhs = crate::linalg::dist_sq(&gx, &gy).sqrt();
                let rhs = p.l_i(w) * crate::linalg::dist_sq(&x, &y).sqrt();
                assert!(lhs <= rhs * (1.0 + 1e-6), "worker {w}: {lhs} > {rhs}");
            }
        }
    }

    #[test]
    fn loss_decreases_toward_optimum() {
        let p = small_problem();
        let x0 = vec![0.0; p.dim()];
        assert!(p.loss(p.x_star()) < p.loss(&x0));
    }
}
