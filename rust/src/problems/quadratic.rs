//! Synthetic distributed quadratics — the controllable testbed used by unit
//! and property tests.
//!
//! `f_i(x) = 1/2 xᵀ H_i x − b_iᵀ x` with SPD `H_i`. Everything is exact:
//! `∇f_i = H_i x − b_i`, `L_i = λ_max(H_i)`, `x* = H̄⁻¹ b̄`.
//!
//! Two generators matter for the paper's story:
//! * [`Quadratic::random`] — heterogeneous `b_i` ⇒ `∇f_i(x*) ≠ 0` (the
//!   general, non-interpolating regime where plain DCGD stalls);
//! * [`Quadratic::interpolating`] — all workers share the minimizer
//!   (`b_i = H_i x̄`) ⇒ `∇f_i(x*) = 0` (the regime where DCGD already
//!   reaches the exact solution).

use crate::linalg::{cholesky_solve, lambda_max, lambda_min_psd, Mat, SpectralOpts};
use crate::problems::Problem;
use crate::util::rng::Pcg64;

pub struct Quadratic {
    d: usize,
    n: usize,
    h: Vec<Mat>,
    b: Vec<Vec<f64>>,
    l_i: Vec<f64>,
    l: f64,
    mu: f64,
    x_star: Vec<f64>,
    grad_star: Vec<Vec<f64>>,
}

impl Quadratic {
    /// Random SPD quadratics with spectrum in [mu_target, l_target].
    pub fn random(d: usize, n: usize, mu_target: f64, l_target: f64, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0x4a4d);
        let h: Vec<Mat> = (0..n)
            .map(|_| random_spd(d, mu_target, l_target, &mut rng))
            .collect();
        let b: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal() * 5.0).collect())
            .collect();
        Self::from_parts(h, b)
    }

    /// All workers share the same minimizer x̄: interpolation regime.
    pub fn interpolating(d: usize, n: usize, mu_target: f64, l_target: f64, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0x4a4e);
        let h: Vec<Mat> = (0..n)
            .map(|_| random_spd(d, mu_target, l_target, &mut rng))
            .collect();
        let shared_min: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<Vec<f64>> = h.iter().map(|hi| hi.matvec(&shared_min)).collect();
        Self::from_parts(h, b)
    }

    pub fn from_parts(h: Vec<Mat>, b: Vec<Vec<f64>>) -> Self {
        let n = h.len();
        assert!(n > 0 && b.len() == n);
        let d = h[0].rows;
        let sopts = SpectralOpts::default();
        let l_i: Vec<f64> = h.iter().map(|hi| lambda_max(hi, sopts)).collect();

        // Global: H̄ = mean(H_i), b̄ = mean(b_i).
        let mut h_bar = Mat::zeros(d, d);
        let mut b_bar = vec![0.0; d];
        for i in 0..n {
            for (o, v) in h_bar.data.iter_mut().zip(h[i].data.iter()) {
                *o += v / n as f64;
            }
            crate::linalg::axpy(1.0 / n as f64, &b[i], &mut b_bar);
        }
        let l = lambda_max(&h_bar, sopts);
        let mu = lambda_min_psd(&h_bar, sopts);
        let x_star = cholesky_solve(&h_bar, &b_bar).expect("mean Hessian must be SPD");

        let grad_star: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let mut g = h[i].matvec(&x_star);
                for j in 0..d {
                    g[j] -= b[i][j];
                }
                g
            })
            .collect();

        Self {
            d,
            n,
            h,
            b,
            l_i,
            l,
            mu,
            x_star,
            grad_star,
        }
    }
}

fn random_spd(d: usize, mu: f64, l: f64, rng: &mut Pcg64) -> Mat {
    // Random orthogonal-ish basis via QR-free construction: Householder
    // products are overkill; use G = B Bᵀ normalized then rescale spectrum
    // roughly into [mu, l] by diag embedding: H = Qᵀ D Q with Q from
    // Gram-Schmidt of a random matrix.
    let mut b = Mat::zeros(d, d);
    for v in b.data.iter_mut() {
        *v = rng.normal();
    }
    // Gram–Schmidt to get an orthonormal Q (rows).
    let mut q = b.clone();
    for i in 0..d {
        for j in 0..i {
            let proj = crate::linalg::dot(q.row(i), q.row(j));
            let (head, tail) = q.data.split_at_mut(i * d);
            let qi = &mut tail[..d];
            let qj = &head[j * d..j * d + d];
            for t in 0..d {
                qi[t] -= proj * qj[t];
            }
        }
        let norm = crate::linalg::nrm2(q.row(i));
        let qi = q.row_mut(i);
        for t in 0..d {
            qi[t] /= norm.max(1e-12);
        }
    }
    // spectrum log-uniform in [mu, l]
    let mut h = Mat::zeros(d, d);
    for e in 0..d {
        let lam = if d == 1 {
            l
        } else if e == 0 {
            mu
        } else if e == d - 1 {
            l
        } else {
            (mu.ln() + rng.f64() * (l.ln() - mu.ln())).exp()
        };
        // H += lam * q_e q_eᵀ
        let qe = q.row(e).to_vec();
        for i in 0..d {
            let qei = qe[i] * lam;
            if qei != 0.0 {
                let hrow = h.row_mut(i);
                for j in 0..d {
                    hrow[j] += qei * qe[j];
                }
            }
        }
    }
    h
}

impl Problem for Quadratic {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_workers(&self) -> usize {
        self.n
    }
    fn local_grad_into(&self, worker: usize, x: &[f64], out: &mut [f64]) {
        self.h[worker].matvec_into(x, out);
        for j in 0..self.d {
            out[j] -= self.b[worker][j];
        }
    }
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        let hx = self.h[worker].matvec(x);
        0.5 * crate::linalg::dot(x, &hx) - crate::linalg::dot(&self.b[worker], x)
    }
    fn l_i(&self, worker: usize) -> f64 {
        self.l_i[worker]
    }
    fn l(&self) -> f64 {
        self.l
    }
    fn mu(&self) -> f64 {
        self.mu
    }
    fn x_star(&self) -> &[f64] {
        &self.x_star
    }
    fn grad_star(&self, worker: usize) -> &[f64] {
        &self.grad_star[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::test_util::{check_local_grads, check_stationarity};

    #[test]
    fn random_quadratic_is_consistent() {
        let p = Quadratic::random(12, 4, 0.5, 20.0, 1);
        check_stationarity(&p, 1e-8);
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        check_local_grads(&p, &x, 2e-4);
        assert!(!p.is_interpolating(1e-6));
    }

    #[test]
    fn interpolating_quadratic_has_zero_local_grads() {
        let p = Quadratic::interpolating(10, 5, 1.0, 10.0, 7);
        check_stationarity(&p, 1e-7);
        assert!(p.is_interpolating(1e-7), "‖∇f_i(x*)‖ should all vanish");
        assert!(p.grad_star_second_moment() < 1e-14);
    }

    #[test]
    fn spectrum_within_targets() {
        let p = Quadratic::random(15, 3, 0.5, 20.0, 3);
        assert!(p.mu() >= 0.4, "mu {}", p.mu());
        assert!(p.l() <= 21.0, "l {}", p.l());
        for i in 0..3 {
            assert!(p.l_i(i) <= 20.5 && p.l_i(i) >= 0.4);
        }
    }

    #[test]
    fn kappa_matches_ratio() {
        let p = Quadratic::random(8, 2, 1.0, 50.0, 5);
        assert!((p.kappa() - p.l() / p.mu()).abs() < 1e-12);
    }
}
