//! `shiftcomp` CLI — leader entrypoint.
//!
//! Subcommands (see `shiftcomp help`):
//! * `run`      — run one algorithm on one problem, print/save the trace
//! * `figure`   — regenerate a paper figure (1, 2, 3, 4) into results/
//! * `table`    — regenerate Table 1 (theory + measured)
//! * `train-lm` — distributed compressed training of the transformer LM
//!                via the PJRT runtime (requires `make artifacts`)
//! * `list`     — list algorithms, compressors and shift rules (Table 2)

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(shiftcomp::harness::cli_main(&argv));
}
