//! The threaded coordinator must be **bit-identical** to the single-process
//! driver: same seed ⇒ same trajectory, same bits — for every method.

use std::sync::Arc;

use shiftcomp::algorithms::{Algorithm, DcgdShift, RunOpts};
use shiftcomp::compressors::{Compressor, NaturalDithering, RandK, TopK, ValPrec};
use shiftcomp::coordinator::{ClusterConfig, DistributedRunner, MethodKind};
use shiftcomp::net::LinkModel;
use shiftcomp::problems::{Problem, Ridge};

fn ridge() -> Arc<Ridge> {
    Arc::new(Ridge::paper_default(3))
}

fn assert_trajectories_match(
    mut single: DcgdShift,
    mut dist: DistributedRunner,
    p: &dyn Problem,
    rounds: usize,
) {
    let mut bits_single = 0u64;
    let mut bits_dist = 0u64;
    for k in 0..rounds {
        bits_single += single.step(p).bits_up;
        bits_dist += dist.step(p).bits_up;
        let xs = single.x();
        let xd = dist.x();
        assert_eq!(xs, xd, "iterates diverged at round {k}");
    }
    assert_eq!(bits_single, bits_dist, "bit accounting diverged");
}

#[test]
fn dcgd_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::dcgd(p.as_ref(), RandK::with_q(d, 0.3), 11);
    let gamma = single.gamma;
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Fixed,
            gamma,
            prec: ValPrec::F64,
            seed: 11,
            links: None,
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn diana_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::diana(p.as_ref(), NaturalDithering::l2(d, 4), None, 13);
    let gamma = single.gamma;
    // recover alpha from theory exactly as the constructor does
    let omega = NaturalDithering::l2(d, 4).omega().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![0.0; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(NaturalDithering::l2(d, 4)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: false,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 13,
            links: None,
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn diana_with_c_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let c: Box<dyn Compressor> = Box::new(TopK::with_q(d, 0.5));
    let single = DcgdShift::diana(p.as_ref(), RandK::with_q(d, 0.3), Some(c.clone_box()), 15);
    let gamma = single.gamma;
    let omega = RandK::with_q(d, 0.3).omega().unwrap();
    let delta = c.delta().unwrap();
    let ss = shiftcomp::theory::diana(p.as_ref(), &vec![omega; n], &vec![delta; n], 2.0);
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.3)) as Box<dyn Compressor>)
        .collect();
    let cs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(TopK::with_q(d, 0.5)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        Some(cs),
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::Diana {
                alpha: ss.alpha,
                with_c: true,
            },
            gamma,
            prec: ValPrec::F64,
            seed: 15,
            links: None,
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 50);
}

#[test]
fn rand_diana_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::rand_diana(p.as_ref(), RandK::with_q(d, 0.2), Some(0.2), 17);
    let gamma = single.gamma;
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.2)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        vec![vec![0.0; d]; n],
        ClusterConfig {
            method: MethodKind::RandDiana { p: 0.2 },
            gamma,
            prec: ValPrec::F64,
            seed: 17,
            links: None,
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 80);
}

#[test]
fn star_bit_identical() {
    let p = ridge();
    let d = p.dim();
    let n = p.n_workers();
    let single = DcgdShift::star(p.as_ref(), RandK::with_q(d, 0.4), None, 19);
    let gamma = single.gamma;
    let shifts: Vec<Vec<f64>> = (0..n).map(|i| p.grad_star(i).to_vec()).collect();
    let qs: Vec<Box<dyn Compressor>> = (0..n)
        .map(|_| Box::new(RandK::with_q(d, 0.4)) as Box<dyn Compressor>)
        .collect();
    let dist = DistributedRunner::new(
        p.clone(),
        qs,
        None,
        shifts,
        ClusterConfig {
            method: MethodKind::Star { with_c: false },
            gamma,
            prec: ValPrec::F64,
            seed: 19,
            links: None,
        },
    );
    assert_trajectories_match(single, dist, p.as_ref(), 60);
}

#[test]
fn network_accounting_reflects_straggler() {
    let p = ridge();
    let n = p.n_workers();
    let d = p.dim();
    // one worker 100× slower
    let mut links = vec![
        LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        n
    ];
    links[n - 1].up_bps = 1e7;
    let mut runner = DistributedRunner::rand_diana(
        p.clone(),
        RandK::with_q(d, 0.5),
        None,
        21,
        Some(links),
    );
    for _ in 0..20 {
        runner.step(p.as_ref());
    }
    let slow_time = runner.simulated_time();

    let fast_links = vec![
        LinkModel {
            up_bps: 1e9,
            down_bps: 1e9,
            latency: 0.0,
        };
        n
    ];
    let mut fast = DistributedRunner::rand_diana(
        p.clone(),
        RandK::with_q(d, 0.5),
        None,
        21,
        Some(fast_links),
    );
    for _ in 0..20 {
        fast.step(p.as_ref());
    }
    assert!(
        slow_time > fast.simulated_time() * 10.0,
        "straggler must dominate: {slow_time} vs {}",
        fast.simulated_time()
    );
}

#[test]
fn distributed_runner_survives_many_rounds() {
    let p = ridge();
    let d = p.dim();
    let mut runner = DistributedRunner::diana(p.clone(), RandK::with_q(d, 0.5), 23, None);
    let trace = runner.run(
        p.as_ref(),
        &RunOpts {
            max_rounds: 500,
            tol: 0.0,
            record_every: 50,
            ..Default::default()
        },
    );
    assert_eq!(trace.rounds(), 501);
    assert!(!trace.diverged);
}
